"""Analytic job-performance model calibrated the way the paper calibrates.

The paper measures per-workload JCTs on a real 2xA100 testbed and feeds them
to the simulator, then applies a constant x1.06 factor for concurrency
interference (§5.2).  Offline we cannot measure A100s, so the *measured JCT
table* is replaced by an analytic model with the same structure the paper's
job-level analysis exposes (§5.4):

  t_iter = t_compute(instance types) + t_comm(placement, transport)
  - compute rate scales with SM slices; 1g.10gb gives a 10-30% single-
    instance boost (size-aware prioritization evidence);
  - mixed instance types run at the slowest leaf (sync barrier);
  - all SHM traffic of a GPU's leaves shares that GPU's PCIe interface
    (bandwidth saturation -> Fig 9 placement skew);
  - NET (RDMA) bandwidth is shared cluster-wide by concurrent NET jobs
    (Fig 10b concurrency result).

Everything downstream (simulator, figures) only consumes JCT *ratios*, the
same way the paper's simulator consumes measured JCTs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

# --- hardware constants (A100-40GB PCIe testbed, Appendix B) --------------
# Transport bandwidths come from the runtime layer's canonical tier table
# so this model prices the same SHM/NET cliff the collectives implement.
from repro.parallel.transport import (NET_GBPS, PCIE_GBPS,  # noqa: E402
                                      SHM_STREAM_GBPS)

A100_TFLOPS = 312.0               # fp16 dense
LEAF_TFLOPS = A100_TFLOPS / 7.0   # one 1g slice
SYNC_OVERHEAD_FRAC = 0.04         # per-iteration barrier cost (of compute);
                                  # calibrated to the paper's ~4% avg one-to-
                                  # many JCT penalty (§5.3)
DDP_OVERLAP = 0.5                 # fraction of compute hiding the allreduce


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    """One Table-1 workload family."""
    name: str
    params_m: float               # millions of parameters (DDP allreduce)
    gflops_per_sample: float      # forward GFLOPs per sample
    mfu: float                    # achieved fraction of leaf peak
    mem_boost: float              # 1g.10gb single-instance speedup (1.1-1.3)
    train_batches: Tuple[int, ...]
    infer_batches: Tuple[int, ...]
    train_sizes: Tuple[int, ...]
    infer_sizes: Tuple[int, ...]


# Table 1 (paper) with public param counts / FLOPs.  ``mfu`` is the
# *achieved* fraction of slice peak — single-digit for these small models
# (latency/memory-bound; exactly the underutilization premise of §1), and
# mem_boost the measured 1g.10gb single-instance band (10-30%).
WORKLOADS: Dict[str, WorkloadModel] = {
    "resnet18": WorkloadModel("resnet18", 11.7, 1.8, 0.050, 1.12,
                              (128,), (32,), (1,), (1,)),
    "resnet34": WorkloadModel("resnet34", 21.8, 3.6, 0.060, 1.14,
                              (256,), (64,), (2,), (2,)),
    "resnet50": WorkloadModel("resnet50", 25.6, 4.1, 0.070, 1.22,
                              (196, 256), (64,), (4, 6), (4,)),
    "resnet101": WorkloadModel("resnet101", 44.5, 7.8, 0.080, 1.25,
                               (256,), (), (8,), ()),
    "mobilenetv3-small": WorkloadModel("mobilenetv3-small", 2.5, 0.06,
                                       0.012, 1.10, (256, 512), (64, 128),
                                       (1, 2), (1, 2)),
    "mobilenetv3-large": WorkloadModel("mobilenetv3-large", 5.4, 0.22,
                                       0.018, 1.12, (64, 512), (32, 128),
                                       (1, 6), (1, 4)),
    "efficientnet-b0": WorkloadModel("efficientnet-b0", 5.3, 0.39, 0.025,
                                     1.15, (32, 256), (16, 64),
                                     (1, 6), (1, 4)),
    "efficientnet-b2": WorkloadModel("efficientnet-b2", 9.1, 0.68, 0.030,
                                     1.18, (32, 256), (8, 32),
                                     (1, 8), (1, 4)),
    "distilbert": WorkloadModel("distilbert", 66.0, 5.7, 0.050, 1.20,
                                (8, 64), (4, 16), (1, 6), (1, 4)),
    "bert-base": WorkloadModel("bert-base", 110.0, 11.2, 0.060, 1.28,
                               (4, 32), (2, 8), (1, 6), (1, 4)),
    "t5-small": WorkloadModel("t5-small", 60.0, 8.0, 0.050, 1.22,
                              (16, 128), (8, 32), (1, 8), (1, 4)),
}


@dataclasses.dataclass(frozen=True)
class PlacementView:
    """What the JCT model needs to know about a placement."""
    instance_types: Tuple[str, ...]          # e.g. ("1g.5gb","1g.10gb",...)
    leaves_per_gpu: Tuple[int, ...]          # e.g. (3, 3) for 3-3
    transport: str                           # "SHM" | "NET" | "NONE"
    sm_slices: Optional[int] = None          # one-to-one profile slices
    concurrent_net_jobs: int = 0


def _compute_time(w: WorkloadModel, batch: int, view: PlacementView,
                  train: bool) -> float:
    mult = 3.0 if train else 1.0             # fwd+bwd ~ 3x fwd
    flops = w.gflops_per_sample * 1e9 * batch * mult
    if view.sm_slices is not None:           # one-to-one: single instance
        rate = LEAF_TFLOPS * 1e12 * view.sm_slices * w.mfu
        return flops / rate
    n = len(view.instance_types)
    # mixed types -> barrier at the slowest leaf (paper §3.2 observation)
    boosts = [w.mem_boost if t == "1g.10gb" else 1.0
              for t in view.instance_types]
    slowest = min(boosts) if n > 1 else max(boosts)
    rate = LEAF_TFLOPS * 1e12 * w.mfu * slowest
    return flops / (rate * n)                # data-parallel split


def _comm_time(w: WorkloadModel, view: PlacementView, train: bool) -> float:
    n = len(view.instance_types)
    if view.sm_slices is not None or n <= 1:
        return 0.0
    bytes_param = w.params_m * 1e6 * 2       # fp16 grads
    if train:
        per_leaf = 2.0 * (n - 1) / n * bytes_param   # ring allreduce
    else:
        per_leaf = 0.05 * bytes_param                # result allgather
    if view.transport == "SHM":
        # every leaf's stream traverses its GPU's PCIe interface; leaves
        # sharing a GPU share that interface (Fig 9)
        worst_share = max(view.leaves_per_gpu)
        bw = min(SHM_STREAM_GBPS, PCIE_GBPS / max(worst_share, 1))
    else:                                     # NET: NIC shared by all jobs
        bw = NET_GBPS / max(1, view.concurrent_net_jobs)
    return per_leaf / (bw * 1e9)


def iteration_time(model: str, batch: int, view: PlacementView, *,
                   train: bool) -> float:
    w = WORKLOADS[model]
    comp = _compute_time(w, batch, view, train)
    comm = _comm_time(w, view, train)
    # DDP buckets overlap the allreduce with backward; only the exposed
    # remainder and a small per-iteration barrier are visible.
    exposed = max(0.0, comm - DDP_OVERLAP * comp)
    n = len(view.instance_types)
    sync = SYNC_OVERHEAD_FRAC * comp if (n > 1 and
                                         view.sm_slices is None) else 0.0
    return comp + exposed + sync


def reference_view(size: int, n_gpus: int = 2) -> PlacementView:
    """The paper's reference placement: size leaves spread evenly, SHM."""
    if size == 1:
        return PlacementView(("1g.10gb",), (1,), "NONE")
    per = [size // n_gpus] * n_gpus
    for i in range(size % n_gpus):
        per[i] += 1
    return PlacementView(("1g.5gb",) * size, tuple(per), "SHM")


def jct_scale(model: str, batch: int, size: int, view: PlacementView, *,
              train: bool) -> float:
    """JCT(view) / JCT(reference) — scales a trace's base duration."""
    ref = iteration_time(model, batch, reference_view(size), train=train)
    cur = iteration_time(model, batch, view, train=train)
    return cur / ref


# ---------------------------------------------------------------------------
# bucketed gradient-sync schedule (serial vs software-pipelined)
# ---------------------------------------------------------------------------

def bucket_sync_times(bucket_numels: Sequence[int], *, nf: int, ns: int,
                      fast_bps: float, slow_bps: float,
                      bytes_per_elem: float = 4.0,
                      slow_bytes_per_elem: Optional[float] = None
                      ) -> Tuple[List[float], List[float], List[float]]:
    """Per-bucket (fast reduce-scatter, slow hop, fast all-gather) times.

    Ring costs: the fast stages move ``(F-1)/F`` of the bucket over the
    fast tier; the slow hop all-reduces each rank's ``1/F`` shard over
    the ``ns``-way slow tier (``2(S-1)/S`` ring bytes).
    ``slow_bytes_per_elem`` prices slow-hop compression (1.0 for int8 on
    f32 buckets); either tier degenerates to zero time when its axis is
    trivial — mirroring ``hier_reduce_bucket_shards``'s identity paths.
    """
    sb = (slow_bytes_per_elem if slow_bytes_per_elem is not None
          else bytes_per_elem)
    fast_s, slow_s, drain_s = [], [], []
    for n in bucket_numels:
        full = n * bytes_per_elem
        shard = full / max(nf, 1)
        hop = (full - shard) / fast_bps if nf > 1 else 0.0
        slow = (2.0 * (n / max(nf, 1)) * sb * (ns - 1) / ns / slow_bps
                if ns > 1 else 0.0)
        fast_s.append(hop)
        slow_s.append(slow)
        drain_s.append(hop)
    return fast_s, slow_s, drain_s


def hier_sync_makespan(fast_s: Sequence[float], slow_s: Sequence[float],
                       drain_s: Sequence[float], *,
                       overlap: bool) -> float:
    """Makespan of the k-bucket hierarchical sync on a two-channel model.

    Serial: every stage of every bucket sits on the critical path.
    Overlapped: the fast tier streams reduce-scatters ahead (the
    software pipeline issues bucket i+1's before bucket i's slow hop),
    the slow tier pipelines hops back-to-back behind them, and the
    all-gathers drain in bucket order once both their shard's slow hop
    and the fast channel are free.  This is the quantity the overlapped
    train schedule exposes; ``serial - overlapped`` is the slow-tier
    latency the pipeline hides.
    """
    if not overlap:
        return float(sum(fast_s) + sum(slow_s) + sum(drain_s))
    t_fast = 0.0
    t_slow = 0.0
    slow_done = []
    for f, s in zip(fast_s, slow_s):
        t_fast += f
        t_slow = max(t_slow, t_fast) + s
        slow_done.append(t_slow)
    for d, done in zip(drain_s, slow_done):
        t_fast = max(t_fast, done) + d
    return float(max(t_fast, t_slow))


def exposed_slow_fraction(fast_s: Sequence[float],
                          slow_s: Sequence[float],
                          drain_s: Sequence[float], *,
                          overlap: bool) -> float:
    """Fraction of the slow tier's total busy time left on the critical
    path (1.0 = fully exposed, as in the serial schedule)."""
    total_slow = float(sum(slow_s))
    if total_slow <= 0.0:
        return 0.0
    span = hier_sync_makespan(fast_s, slow_s, drain_s, overlap=overlap)
    fast_busy = float(sum(fast_s) + sum(drain_s))
    return max(0.0, span - fast_busy) / total_slow


# ---------------------------------------------------------------------------
# reconfiguration cost model (drain vs software-coordinated handoff)
# ---------------------------------------------------------------------------

# fp16 params + f32 master/mu/nu per parameter — the ZeRO-1 training
# state a reconfiguring job must move (matches what the sharded
# checkpoint actually writes: repro.ckpt)
STATE_BYTES_PER_PARAM = 2 + 3 * 4

# default handoff calibration: conservative local-disk rank throughput
# and a reduced-config jit recompile.  benchmarks/elastic_bench.py
# replaces these with *measured* sharded save/restore/recompile
# wallclock (ReconfigCostModel.from_measurements); the defaults only
# exist so the simulator is usable before a bench run.
DEFAULT_SAVE_BPS = 1.0e9
DEFAULT_RESTORE_BPS = 1.5e9
DEFAULT_RECOMPILE_S = 8.0
DEFAULT_COORD_S = 2.0


def ckpt_state_bytes(model: str) -> float:
    """Bytes of training state a reconfiguration must carry for one job
    of this Table-1 workload (params + ZeRO-1 f32 optimizer state)."""
    return WORKLOADS[model].params_m * 1e6 * STATE_BYTES_PER_PARAM


@dataclasses.dataclass(frozen=True)
class ReconfigCostModel:
    """Prices what a reconfiguration event charges a suspended job.

    ``mode='drain'``: the incumbent drain-required cycle (C4) — the job
    is stopped for the full :class:`~repro.core.modes.ReconfigPlan`
    duration (mig-manager reconfigure + checkpoint save/load + pod
    churn), exactly what the simulator always charged.

    ``mode='handoff'``: the paper's software-coordinated handoff — each
    affected job performs a committed *sharded* save on its old (pod,
    data) mesh, reshard-restores onto the new factorization and re-jits
    (``repro.elastic_driver`` executes this cycle for real).  The charge
    is ``save + restore + recompile + coordination``, parameterized by
    the job's state bytes and how many ranks share the I/O on each side
    (per-rank bytes are 1/F of the flat state), and calibrated from
    measured wallclock via :meth:`from_measurements`.

    A handoff never charges more than the drain it replaces: a
    coordinator that measures its handoff slower than a drain would
    simply drain, so the cap is part of the operational model (and the
    property the calibration tests pin).
    """

    mode: str = "drain"
    save_bps: float = DEFAULT_SAVE_BPS      # sharded save bytes/s per rank
    restore_bps: float = DEFAULT_RESTORE_BPS
    recompile_s: float = DEFAULT_RECOMPILE_S
    coord_s: float = DEFAULT_COORD_S

    def __post_init__(self):
        if self.mode not in ("drain", "handoff"):
            raise ValueError(f"unknown reconfig mode {self.mode!r}; "
                             f"known: ('drain', 'handoff')")
        if min(self.save_bps, self.restore_bps) <= 0:
            raise ValueError("save/restore throughput must be positive")

    def handoff_s(self, state_bytes: float, *, n_ranks_old: int = 1,
                  n_ranks_new: int = 1) -> float:
        """Uncapped handoff wallclock for one job's state."""
        save = state_bytes / max(n_ranks_old, 1) / self.save_bps
        restore = state_bytes / max(n_ranks_new, 1) / self.restore_bps
        return save + restore + self.recompile_s + self.coord_s

    def job_suspension_s(self, state_bytes: float, *, drain_s: float,
                         n_ranks_old: int = 1,
                         n_ranks_new: int = 1) -> float:
        """What the simulator charges one suspended job for this event."""
        if self.mode == "drain":
            return drain_s
        return min(drain_s, self.handoff_s(state_bytes,
                                           n_ranks_old=n_ranks_old,
                                           n_ranks_new=n_ranks_new))

    def failure_restart_s(self, state_bytes: float, *,
                          drain_restart_s: float,
                          n_ranks_new: int = 1) -> float:
        """What restarting one job from its last committed checkpoint
        costs after an *unplanned* failure.

        No save happens (the failed host took the in-memory state with
        it); the charge is the restore side only.  Under ``drain`` the
        incumbent stack reloads a gathered checkpoint and re-admits the
        job through the full churn path (``drain_restart_s`` — the
        simulator passes its CKPT_LOAD + churn constant); under
        ``handoff`` the survivors reshard-restore their 1/F shares and
        re-jit, capped at the drain restart for the same reason planned
        handoffs are capped (a slower recovery path would simply not be
        used).  Lost work since the last commit is charged separately
        by the simulator — it is a property of the checkpoint cadence,
        not of the recovery mechanism.
        """
        if self.mode == "drain":
            return drain_restart_s
        restore = (state_bytes / max(n_ranks_new, 1) / self.restore_bps)
        return min(drain_restart_s,
                   restore + self.recompile_s + self.coord_s)

    def geometry_s(self, *, base_s: float, drain_s: float) -> float:
        """How long the GPU geometry change blocks the *waiting* job.

        Under drains the whole per-job save/load/churn serializes with
        the mig-manager cycle (the full plan duration); under handoffs
        the affected jobs save/restore concurrently with it, so only the
        reconfigure cycle itself remains.  A handed-off job's own
        suspension is deliberately *not* floored at this cycle: the
        handoff relocates the job (sharded save, reshard-restore onto
        other resources — the cycle ``repro.elastic_driver`` executes,
        where the restore lands on a different factorization), so it
        resumes as soon as its own save/restore/recompile completes,
        while the vacated GPU repartitions behind it."""
        return drain_s if self.mode == "drain" else base_s

    @classmethod
    def from_measurements(cls, measurements, *, mode: str = "handoff",
                          coord_s: float = 0.0) -> "ReconfigCostModel":
        """Calibrate from measured handoff cycles.

        ``measurements``: iterable of mappings with ``save_s``,
        ``restore_s``, ``compile_s`` and the total bytes the measuring
        process moved, ``save_bytes`` / ``restore_bytes`` (what
        :class:`repro.elastic_driver.HandoffMeasurement` records).
        Throughputs are medians of per-event bytes/s — the storage
        throughput one writer achieved; :meth:`handoff_s` then divides
        each rank's 1/F share by it, projecting the measured single-host
        cycle (one process moves every rank's shards serially) onto the
        concurrent per-rank writers of a real elastic cluster.
        Recompile is the median measured re-jit wallclock plus the
        new-mesh state build (``setup_s``) — the non-I/O part of the
        cycle.
        """
        import numpy as np
        ms = [dict(m) for m in measurements]
        if not ms:
            raise ValueError("cannot calibrate from zero measurements")
        save_bps = float(np.median(
            [m["save_bytes"] / max(m["save_s"], 1e-9) for m in ms]))
        restore_bps = float(np.median(
            [m["restore_bytes"] / max(m["restore_s"], 1e-9)
             for m in ms]))
        recompile = float(np.median(
            [m["compile_s"] + m.get("setup_s", 0.0) for m in ms]))
        return cls(mode=mode, save_bps=save_bps, restore_bps=restore_bps,
                   recompile_s=recompile, coord_s=coord_s)


def summarize_by_size(measurements) -> List[Dict[str, float]]:
    """Group handoff measurements by job size — ``(state_bytes,
    n_ranks)`` — and take per-group medians.

    The cluster runtime measures handoffs across *several* co-scheduled
    jobs of different widths and model sizes; this summary is what a
    multi-size calibration reports (``BENCH_cluster.json``'s
    ``by_size``), so the dependence of save/restore/recompile wallclock
    on state bytes and rank count is visible rather than averaged away.
    Measurements are mappings shaped like
    :meth:`repro.elastic_driver.HandoffMeasurement.to_dict` with
    ``n_ranks`` (``to_shape`` product) either present or derivable.
    """
    import numpy as np
    groups: Dict[Tuple[int, int], List[Dict]] = {}
    for m in measurements:
        m = dict(m)
        n_ranks = int(m.get("n_ranks")
                      or int(np.prod(m.get("to_shape", (1,)))))
        key = (int(m.get("state_bytes", 0)), n_ranks)
        groups.setdefault(key, []).append(m)
    out: List[Dict[str, float]] = []
    for (state_bytes, n_ranks), ms in sorted(groups.items()):
        med = lambda k: float(np.median([m.get(k, 0.0) for m in ms]))
        out.append({
            "state_bytes": float(state_bytes), "n_ranks": float(n_ranks),
            "n": float(len(ms)), "save_s": med("save_s"),
            "restore_s": med("restore_s"), "setup_s": med("setup_s"),
            "compile_s": med("compile_s"),
            "save_bytes": med("save_bytes"),
            "restore_bytes": med("restore_bytes"),
        })
    return out


# ---------------------------------------------------------------------------
# calibration (§5.2)
# ---------------------------------------------------------------------------

CALIBRATION_FACTOR = 1.06


def calibrated(t: float, *, concurrent: bool, calibrate: bool) -> float:
    """Apply the paper's constant concurrency-interference factor."""
    if calibrate and concurrent:
        return t * CALIBRATION_FACTOR
    return t


def interference_ground_truth(t: float, *, concurrent: bool,
                              rng) -> float:
    """'Real testbed' stand-in: mild stochastic contention (used by the
    Fig. 6 parity benchmark as the measurement the simulator is validated
    against)."""
    if not concurrent:
        return t
    return t * float(rng.uniform(1.03, 1.09))
