"""Synthetic trace generation (§5.1).

Three orthogonal dimensions, assumed independent:
  (i)  execution-time distribution — short (600-1800 s) / medium
       (1800-3600 s) / long (3600-7200 s) buckets with mixes derived from
       the four public traces (Helios Earth/Venus, Philly, Alibaba);
  (ii) workload-size distribution — small-dominant / balanced /
       large-dominant (paper Table 2);
  (iii) workload type — training-only / inference-only / 50:50 mixed.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.jct_model import WORKLOADS
from repro.core.job import DEFAULT_TENANT, TIER_NORMAL, Job

DURATION_BUCKETS = {
    "short": (600.0, 1800.0),
    "medium": (1800.0, 3600.0),
    "long": (3600.0, 7200.0),
}

# bucket mixes approximating the empirical duration skew of each source
# trace (single-GPU / 0.5-1-GPU jobs).
DURATION_SOURCES: Dict[str, Tuple[float, float, float]] = {
    "helios_earth": (0.55, 0.25, 0.20),
    "helios_venus": (0.45, 0.30, 0.25),
    "philly": (0.60, 0.25, 0.15),
    "alibaba": (0.70, 0.20, 0.10),
}

# Table 2: jobs per workload size.
TRAIN_SIZES = (1, 2, 4, 6, 8)
INFER_SIZES = (1, 2, 4)
SIZE_DISTS: Dict[str, Dict[str, Tuple[int, ...]]] = {
    "small": {"train": (16, 8, 4, 2, 1), "infer": (16, 8, 4)},
    "balanced": {"train": (8, 8, 8, 4, 4), "infer": (10, 10, 10)},
    "large": {"train": (4, 4, 12, 8, 4), "infer": (8, 8, 16)},
}

TYPE_MIXES = ("train", "inference", "mixed")


def _sizes_in_range(lo_hi: Tuple[int, ...], pool: Tuple[int, ...]
                    ) -> Tuple[int, ...]:
    if len(lo_hi) == 1:
        return (lo_hi[0],) if lo_hi[0] in pool or True else ()
    lo, hi = lo_hi
    return tuple(s for s in pool if lo <= s <= hi)


def models_for(kind: str, size: int) -> List[str]:
    out = []
    for name, w in WORKLOADS.items():
        sizes = w.train_sizes if kind == "train" else w.infer_sizes
        batches = w.train_batches if kind == "train" else w.infer_batches
        if not sizes or not batches:
            continue
        pool = TRAIN_SIZES if kind == "train" else INFER_SIZES
        if size in _sizes_in_range(sizes, pool):
            out.append(name)
    return out


def _pick_batch(model: str, kind: str, rng) -> int:
    w = WORKLOADS[model]
    br = w.train_batches if kind == "train" else w.infer_batches
    if len(br) == 1:
        return br[0]
    lo, hi = br
    opts = [b for b in (4, 8, 16, 32, 64, 128, 196, 256, 512)
            if lo <= b <= hi]
    return int(rng.choice(opts)) if opts else lo


@dataclasses.dataclass(frozen=True)
class TraceCategory:
    duration_source: str
    size_dist: str
    type_mix: str

    @property
    def name(self) -> str:
        return f"{self.duration_source}/{self.size_dist}/{self.type_mix}"


ALL_CATEGORIES: Tuple[TraceCategory, ...] = tuple(
    TraceCategory(d, s, t)
    for d, s, t in itertools.product(DURATION_SOURCES, SIZE_DISTS,
                                     TYPE_MIXES))


def generate_trace(cat: TraceCategory, *, seed: int = 0,
                   double: bool = False, max_size: Optional[int] = None,
                   mean_interarrival: float = 30.0,
                   n_tenants: int = 1) -> List[Job]:
    """One synthetic trace for a category.

    ``double=True`` doubles the Table-2 job counts (§5.1 Metrics).
    ``max_size`` folds larger sizes down (Fig. 7 uses max 4 so SM is
    comparable).  Arrivals are open-loop (exponential interarrivals).

    ``n_tenants > 1`` assigns jobs round-robin (by arrival index) to
    tenants ``t0..t{n-1}``.  The assignment consumes no rng draws, so a
    multi-tenant trace is the single-tenant trace with tenant labels
    painted on — every other field, and therefore every quota-free
    replay, is bit-identical.
    """
    rng = np.random.default_rng(seed)
    mix = DURATION_SOURCES[cat.duration_source]
    dist = SIZE_DISTS[cat.size_dist]

    specs: List[Tuple[str, int]] = []      # (kind, size)
    mult = 2 if double else 1

    def add(kind: str, sizes: Tuple[int, ...], counts: Tuple[int, ...],
            scale: float = 1.0):
        for size, count in zip(sizes, counts):
            n = max(1, round(count * mult * scale)) if count else 0
            if max_size is not None and size > max_size:
                size = max_size
            specs.extend([(kind, size)] * n)

    if cat.type_mix == "train":
        add("train", TRAIN_SIZES, dist["train"])
    elif cat.type_mix == "inference":
        add("inference", INFER_SIZES, dist["infer"])
    else:
        add("train", TRAIN_SIZES, dist["train"], 0.5)
        add("inference", INFER_SIZES, dist["infer"], 0.5)

    rng.shuffle(specs)
    jobs: List[Job] = []
    t = 0.0
    for i, (kind, size) in enumerate(specs):
        bucket = rng.choice(("short", "medium", "long"), p=mix)
        lo, hi = DURATION_BUCKETS[bucket]
        duration = float(rng.uniform(lo, hi))
        choices = models_for(kind, size)
        model = str(rng.choice(choices)) if choices else "efficientnet-b2"
        batch = _pick_batch(model, kind, rng)
        t += float(rng.exponential(mean_interarrival))
        tenant = (f"t{i % n_tenants}" if n_tenants > 1
                  else DEFAULT_TENANT)
        jobs.append(Job(job_id=f"j{i:04d}", model=model, kind=kind,
                        size=size, batch=batch, base_duration=duration,
                        submit_time=t, tenant=tenant))
    return jobs


# ---------------------------------------------------------------------------
# fleet-scale synthetic traces (the bake-off's 10-100x host regime)
# ---------------------------------------------------------------------------

# fleet job-size mix: the Table-2 balanced train/infer distributions
# merged 50:50 (same mass the DEFAULT_FRAG_DEMAND scoring assumes)
FLEET_SIZES: Tuple[int, ...] = (1, 2, 4, 6, 8)
FLEET_SIZE_WEIGHTS: Tuple[float, ...] = (18.0, 18.0, 18.0, 4.0, 4.0)


def generate_fleet_trace(n_jobs: int, *, seed: int = 0,
                         mean_interarrival: float = 30.0,
                         pareto_alpha: float = 1.8,
                         n_tenants: int = 8,
                         max_size: Optional[int] = None,
                         duration_source: str = "philly") -> List[Job]:
    """A fleet-scale open-loop trace: ``n_jobs`` mixed train+serve jobs
    with heavy-tailed interarrivals and multi-tenant labels.

    Unlike :func:`generate_trace` (whose job counts are pinned to the
    paper's Table-2 category totals), this scales to millions of jobs:

    - **arrivals** are Pareto(``pareto_alpha``) interarrivals rescaled
      to ``mean_interarrival`` — heavy-tailed bursts, the regime where
      placement policy actually differentiates (exponential arrivals
      rarely build deep queues at fixed utilization);
    - **sizes** follow the Table-2 balanced train+infer mix
      (:data:`FLEET_SIZES`), folded down by ``max_size`` like the
      figure traces;
    - **kinds** alternate train/serve 50:50 (inference jobs keep the
      DM no-drain semantics, so the mix exercises both paths);
    - **tenants** are painted round-robin by arrival index exactly as
      :func:`generate_trace` does — zero extra rng draws.

    All draws are vectorized; generating 500k jobs takes seconds, not
    the minutes a per-job ``rng.choice`` loop costs.
    """
    if n_jobs < 1:
        raise ValueError(f"n_jobs must be >= 1, got {n_jobs}")
    if pareto_alpha <= 1.0:
        raise ValueError("pareto_alpha must be > 1 (finite mean)")
    rng = np.random.default_rng(seed)
    w = np.asarray(FLEET_SIZE_WEIGHTS) / sum(FLEET_SIZE_WEIGHTS)
    sizes = rng.choice(np.asarray(FLEET_SIZES), size=n_jobs, p=w)
    if max_size is not None:
        sizes = np.minimum(sizes, max_size)
    mix = np.asarray(DURATION_SOURCES[duration_source])
    buckets = rng.choice(3, size=n_jobs, p=mix)
    lows = np.asarray([DURATION_BUCKETS[b][0]
                       for b in ("short", "medium", "long")])
    highs = np.asarray([DURATION_BUCKETS[b][1]
                        for b in ("short", "medium", "long")])
    durations = rng.uniform(lows[buckets], highs[buckets])
    # Pareto(a) has mean a/(a-1) (for the numpy Lomax form, 1/(a-1));
    # rescale the empirical-mean-free analytic mean to the target
    inter = rng.pareto(pareto_alpha, size=n_jobs) * (
        mean_interarrival * (pareto_alpha - 1.0))
    arrivals = np.cumsum(inter)
    kinds = np.where(np.arange(n_jobs) % 2 == 0, "train", "inference")
    # model/batch pools per (kind, size): drawn by index so one
    # vectorized integer draw covers every job of the group
    jobs: List[Job] = [None] * n_jobs              # type: ignore
    idx = np.arange(n_jobs)
    for kind in ("train", "inference"):
        for size in sorted(set(int(s) for s in sizes)):
            sel = idx[(kinds == kind) & (sizes == size)]
            if not len(sel):
                continue
            pool = models_for(kind, size) or ["efficientnet-b2"]
            picks = rng.integers(len(pool), size=len(sel))
            batches = {m: _pick_batch(m, kind, rng) for m in pool}
            for i, p in zip(sel, picks):
                model = pool[p]
                jobs[i] = Job(
                    job_id=f"f{i:07d}", model=model, kind=kind,
                    size=int(sizes[i]), batch=batches[model],
                    base_duration=float(durations[i]),
                    submit_time=float(arrivals[i]),
                    tenant=(f"t{i % n_tenants}" if n_tenants > 1
                            else DEFAULT_TENANT))
    return jobs


# ---------------------------------------------------------------------------
# trace files (CSV) — the executable cluster runtime's input format
# ---------------------------------------------------------------------------

# required columns, in canonical order; ``tenant`` and ``priority_tier``
# are optional trailing columns (absent in every pre-multi-tenant trace
# file, whose rows keep parsing to byte-identical Jobs)
TRACE_COLUMNS = ("job_id", "model", "kind", "size", "batch",
                 "base_duration", "submit_time")
TRACE_OPTIONAL_COLUMNS = ("tenant", "priority_tier")


def parse_trace(text: str) -> List[Job]:
    """Parse a CSV trace (header + rows) into :class:`Job` records.

    The header must name every column in :data:`TRACE_COLUMNS` and may
    additionally name ``tenant`` / ``priority_tier``; rows without the
    optional columns get the single-tenant defaults, so loading an old
    trace file replays bit-identically.
    """
    import csv
    import io

    rows = list(csv.reader(io.StringIO(text)))
    rows = [r for r in rows if r and any(c.strip() for c in r)]
    if not rows:
        return []
    header = [c.strip() for c in rows[0]]
    missing = [c for c in TRACE_COLUMNS if c not in header]
    if missing:
        raise ValueError(f"trace header is missing columns {missing}; "
                         f"got {header}")
    unknown = [c for c in header
               if c not in TRACE_COLUMNS + TRACE_OPTIONAL_COLUMNS]
    if unknown:
        raise ValueError(f"trace header has unknown columns {unknown}")
    idx = {c: header.index(c) for c in header}
    jobs: List[Job] = []
    for lineno, row in enumerate(rows[1:], start=2):
        if len(row) != len(header):
            raise ValueError(
                f"trace line {lineno}: {len(row)} fields, header has "
                f"{len(header)}")

        def col(name, default=None):
            return row[idx[name]].strip() if name in idx else default

        jobs.append(Job(
            job_id=col("job_id"), model=col("model"), kind=col("kind"),
            size=int(col("size")), batch=int(col("batch")),
            base_duration=float(col("base_duration")),
            submit_time=float(col("submit_time")),
            tenant=col("tenant", DEFAULT_TENANT) or DEFAULT_TENANT,
            priority_tier=int(col("priority_tier", TIER_NORMAL)
                              or TIER_NORMAL)))
    return jobs


def load_trace(path: str) -> List[Job]:
    with open(path) as f:
        return parse_trace(f.read())


def trace_to_csv(jobs: List[Job], *,
                 include_tenancy: Optional[bool] = None) -> str:
    """Serialize jobs as a CSV trace (round-trips with
    :func:`parse_trace`).  ``include_tenancy=None`` auto-detects: the
    tenant/priority columns are written only when some job departs from
    the single-tenant defaults, so single-tenant traces keep the
    original column set."""
    if include_tenancy is None:
        include_tenancy = any(j.tenant != DEFAULT_TENANT
                              or j.priority_tier != TIER_NORMAL
                              for j in jobs)
    cols = TRACE_COLUMNS + (TRACE_OPTIONAL_COLUMNS if include_tenancy
                            else ())
    lines = [",".join(cols)]
    for j in jobs:
        row = [j.job_id, j.model, j.kind, str(j.size), str(j.batch),
               repr(j.base_duration), repr(j.submit_time)]
        if include_tenancy:
            row += [j.tenant, str(j.priority_tier)]
        lines.append(",".join(row))
    return "\n".join(lines) + "\n"
