"""Job Executor (§4.1.2): bridges scheduling decisions to runtime launch.

Builds the pod-spec analogue — the environment that restricts a worker's
visibility to its assigned leaves (``NVIDIA_VISIBLE_DEVICES`` = MIG UUIDs)
— and performs the per-process init of §4.2 (export to
``CUDA_VISIBLE_DEVICES`` + ``NCCL_MIG_ID``), then forms the communicator
through the MIG-aware registry.  This is the end-to-end wiring the paper's
Fig. 4/5 describe, runnable in-process.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.job import Job, Placement
from repro.core.registry import (PeerInfo, env_to_peer, form_communicator,
                                 select_transport)


@dataclasses.dataclass
class PodSpec:
    job_id: str
    env: Dict[str, str]
    n_workers: int
    entrypoint: str = "python -m repro.launch.train"


@dataclasses.dataclass
class LaunchedJob:
    pod: PodSpec
    peers: List[PeerInfo]
    transports: Dict[tuple, str]


class JobExecutor:
    """Prepares pod specs and launches distributed workers."""

    def pod_spec(self, job: Job, placement: Placement) -> PodSpec:
        uuids = ",".join(i.uuid for i in placement.instances)
        return PodSpec(
            job_id=job.job_id,
            env={"NVIDIA_VISIBLE_DEVICES": uuids},
            n_workers=len(placement.instances),
        )

    def launch(self, job: Job, placement: Placement,
               *, mig_aware: bool = True) -> LaunchedJob:
        pod = self.pod_spec(job, placement)
        uuids = pod.env["NVIDIA_VISIBLE_DEVICES"].split(",")
        peers: List[PeerInfo] = []
        for local_rank, (uuid, inst) in enumerate(
                zip(uuids, placement.instances)):
            # per-process init (§4.2): LOCAL_RANK selects this worker's UUID
            worker_env = dict(pod.env)
            worker_env["NVIDIA_VISIBLE_DEVICES"] = uuid
            gpu_bus = f"00:{0x40 + inst.gpu_id:02X}:00.0"
            peers.append(env_to_peer(
                local_rank, worker_env,
                host_hash=hash(("host", inst.host_id)) & 0xffffffff,
                pid_hash=local_rank + 1000,
                pcie_bus_id=gpu_bus))
        # communicator setup with the Flex-MIG NCCL modifications
        form_communicator(peers, mig_aware=mig_aware,
                          synthetic_labeling=mig_aware)
        transports = {}
        for a in peers:
            for b in peers:
                if a.rank < b.rank:
                    transports[(a.rank, b.rank)] = select_transport(a, b)
        return LaunchedJob(pod=pod, peers=peers, transports=transports)
