"""Wait queue + scheduling policies (§4.1.1, §5.1).

FIFO examines only the queue head; Aggressive Backfilling examines up to
``depth`` candidates (14 in the paper's configuration) and places any that
fit.  The scheduler is mode-agnostic: modes answer placement queries.

Multi-tenant extension (cluster runtime): a scheduler may be armed with
per-tenant device quotas (``quotas``) and then filters candidates whose
tenant is at quota given the caller's current ``usage``; priority tiers
(:attr:`repro.core.job.Job.priority_tier`) order the candidate window
highest tier first.  Both are strictly opt-in — without quotas and with
all jobs on the default tier, ``candidates`` returns exactly what it
always returned (the ordering sort is stable), so every existing golden
replay is bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional

from repro.core.job import TIER_NORMAL, Job


@dataclasses.dataclass
class WaitQueue:
    jobs: List[Job] = dataclasses.field(default_factory=list)

    def push(self, job: Job) -> None:
        self.jobs.append(job)

    def remove(self, job: Job) -> None:
        self.jobs.remove(job)

    def __len__(self) -> int:
        return len(self.jobs)

    def __bool__(self) -> bool:
        return bool(self.jobs)


class Scheduler:
    """policy='fifo' | 'backfill'.

    ``quotas`` maps tenant -> maximum concurrently-held device count
    (job sizes).  A job whose tenant would exceed its quota is invisible
    to :meth:`candidates` for that pass; tenants without an entry are
    unrestricted.  Quota filtering only happens when the caller supplies
    ``usage`` (tenant -> devices currently held), so pure replay paths
    that never pass usage are unaffected.
    """

    def __init__(self, policy: str = "fifo", depth: int = 14,
                 quotas: Optional[Mapping[str, int]] = None):
        assert policy in ("fifo", "backfill")
        self.policy = policy
        self.depth = depth
        self.quotas: Dict[str, int] = dict(quotas) if quotas else {}

    def admissible(self, job: Job, usage: Mapping[str, int]) -> bool:
        """Would starting ``job`` keep its tenant within quota?"""
        quota = self.quotas.get(job.tenant)
        if quota is None:
            return True
        return usage.get(job.tenant, 0) + job.size <= quota

    def candidates(self, queue: WaitQueue,
                   usage: Optional[Mapping[str, int]] = None) -> List[Job]:
        if not queue:
            return []
        jobs = queue.jobs
        if usage is not None and self.quotas:
            jobs = [j for j in jobs if self.admissible(j, usage)]
        # highest priority tier first; stable, so the all-default-tier
        # case preserves submission order exactly (goldens unchanged) —
        # and skips the sort entirely, keeping the common single-tier
        # replay path at its original slice cost
        if any(j.priority_tier != TIER_NORMAL for j in jobs):
            jobs = sorted(jobs, key=lambda j: j.priority_tier)
        if self.policy == "fifo":
            return jobs[:1]
        return jobs[:self.depth]
