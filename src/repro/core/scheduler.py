"""Wait queue + scheduling policies (§4.1.1, §5.1).

FIFO examines only the queue head; Aggressive Backfilling examines up to
``depth`` candidates (14 in the paper's configuration) and places any that
fit.  The scheduler is mode-agnostic: modes answer placement queries.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.job import Job


@dataclasses.dataclass
class WaitQueue:
    jobs: List[Job] = dataclasses.field(default_factory=list)

    def push(self, job: Job) -> None:
        self.jobs.append(job)

    def remove(self, job: Job) -> None:
        self.jobs.remove(job)

    def __len__(self) -> int:
        return len(self.jobs)

    def __bool__(self) -> bool:
        return bool(self.jobs)


class Scheduler:
    """policy='fifo' | 'backfill'."""

    def __init__(self, policy: str = "fifo", depth: int = 14):
        assert policy in ("fifo", "backfill")
        self.policy = policy
        self.depth = depth

    def candidates(self, queue: WaitQueue) -> List[Job]:
        if not queue:
            return []
        if self.policy == "fifo":
            return [queue.jobs[0]]
        return queue.jobs[:self.depth]
