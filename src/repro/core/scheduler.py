"""Wait queue + scheduling policies (§4.1.1, §5.1).

FIFO examines only the queue head; Aggressive Backfilling examines up to
``depth`` candidates (14 in the paper's configuration) and places any that
fit.  The scheduler is mode-agnostic: modes answer placement queries.

Multi-tenant extension (cluster runtime): a scheduler may be armed with
per-tenant device quotas (``quotas``) and then filters candidates whose
tenant is at quota given the caller's current ``usage``; priority tiers
(:attr:`repro.core.job.Job.priority_tier`) order the candidate window
highest tier first.  Both are strictly opt-in — without quotas and with
all jobs on the default tier, ``candidates`` returns exactly what it
always returned (the ordering sort is stable), so every existing golden
replay is bit-identical.

Fleet-scale hardening: the queue used to be a bare list, making
``remove`` O(queue) and the all-default-tier check in ``candidates`` an
O(queue) scan *per scheduling pass* — together the dominant superlinear
term on million-event traces (measured: 60% of wall-clock at 8k jobs,
growing with queue depth).  The queue is now an insertion-ordered dict
keyed by ``job_id`` (O(1) push/remove, same iteration order as the list
it replaces) carrying a live count of non-default-tier members, so the
single-tier fast path peeks only ``depth`` jobs per pass.  Candidate
*order* is unchanged in every case.
"""
from __future__ import annotations

import itertools
from collections import OrderedDict
from typing import Dict, Iterator, List, Mapping, Optional

from repro.core.job import TIER_NORMAL, Job


class WaitQueue:
    """FIFO-ordered wait queue with O(1) push/remove.

    Iteration order is insertion (submission) order, exactly as the
    plain-list implementation it replaced.  ``jobs`` materializes that
    order as a list for callers that want a snapshot (the cluster
    runtime's introspection paths); hot paths iterate instead.
    """

    def __init__(self, jobs: Optional[List[Job]] = None):
        # OrderedDict, not dict: FIFO drains delete from the FRONT, and
        # a plain dict's iteration then re-skips the dead leading slots
        # on every head() peek until a resize compacts them — measured
        # superlinear (15us/peek at 64k jobs).  OrderedDict's linked
        # list makes head access O(1) regardless of deletion history.
        self._jobs: "OrderedDict[str, Job]" = OrderedDict()
        self._n_special = 0           # members not on TIER_NORMAL
        for j in jobs or ():
            self.push(j)

    @property
    def jobs(self) -> List[Job]:
        return list(self._jobs.values())

    @property
    def has_special_tiers(self) -> bool:
        return self._n_special > 0

    def push(self, job: Job) -> None:
        if job.job_id in self._jobs:
            raise ValueError(f"{job.job_id} already queued")
        self._jobs[job.job_id] = job
        if job.priority_tier != TIER_NORMAL:
            self._n_special += 1

    def remove(self, job: Job) -> None:
        if job.job_id not in self._jobs:
            raise ValueError(f"{job.job_id} not in queue")
        del self._jobs[job.job_id]
        if job.priority_tier != TIER_NORMAL:
            self._n_special -= 1

    def head(self, n: int) -> List[Job]:
        """First ``n`` jobs in queue order without materializing the
        whole queue (the single-tier scheduling fast path)."""
        return list(itertools.islice(self._jobs.values(), n))

    def __iter__(self) -> Iterator[Job]:
        return iter(self._jobs.values())

    def __len__(self) -> int:
        return len(self._jobs)

    def __bool__(self) -> bool:
        return bool(self._jobs)


class Scheduler:
    """policy='fifo' | 'backfill'.

    ``quotas`` maps tenant -> maximum concurrently-held device count
    (job sizes).  A job whose tenant would exceed its quota is invisible
    to :meth:`candidates` for that pass; tenants without an entry are
    unrestricted.  Quota filtering only happens when the caller supplies
    ``usage`` (tenant -> devices currently held), so pure replay paths
    that never pass usage are unaffected.
    """

    def __init__(self, policy: str = "fifo", depth: int = 14,
                 quotas: Optional[Mapping[str, int]] = None):
        assert policy in ("fifo", "backfill")
        self.policy = policy
        self.depth = depth
        self.quotas: Dict[str, int] = dict(quotas) if quotas else {}

    def admissible(self, job: Job, usage: Mapping[str, int]) -> bool:
        """Would starting ``job`` keep its tenant within quota?"""
        quota = self.quotas.get(job.tenant)
        if quota is None:
            return True
        return usage.get(job.tenant, 0) + job.size <= quota

    def candidates(self, queue: WaitQueue,
                   usage: Optional[Mapping[str, int]] = None) -> List[Job]:
        if not queue:
            return []
        limit = 1 if self.policy == "fifo" else self.depth
        if usage is None or not self.quotas:
            # single-tier fast path: no full-queue scan.  With special
            # tiers present the sort must see the whole queue; it is
            # stable, so the all-default-tier outcome is unchanged (and
            # sorting an all-normal queue is the identity — the tier
            # counter only short-circuits the cost, never the order).
            if not queue.has_special_tiers:
                return queue.head(limit)
            jobs = sorted(queue, key=lambda j: j.priority_tier)
            return jobs[:limit]
        jobs = [j for j in queue if self.admissible(j, usage)]
        # highest priority tier first; stable, so the all-default-tier
        # case preserves submission order exactly (goldens unchanged)
        if any(j.priority_tier != TIER_NORMAL for j in jobs):
            jobs = sorted(jobs, key=lambda j: j.priority_tier)
        return jobs[:limit]
