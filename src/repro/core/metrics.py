"""Cross-mode comparison utilities (the ratios plotted in Figs. 7-8)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.simulator import SimResult


@dataclasses.dataclass
class ModeComparison:
    """Numerator/denominator metric ratios (<1 favours the numerator)."""
    jct_ratio: float
    wait_ratio: float
    makespan_ratio: float
    util_ratio: float

    @staticmethod
    def of(num: SimResult, den: SimResult) -> "ModeComparison":
        def safe(a, b):
            return a / b if b > 0 else float("nan")
        return ModeComparison(
            jct_ratio=safe(num.avg_jct, den.avg_jct),
            wait_ratio=safe(num.avg_wait, den.avg_wait),
            makespan_ratio=safe(num.makespan, den.makespan),
            util_ratio=safe(num.utilization, den.utilization),
        )


def summarize(ratios: List[ModeComparison]) -> Dict[str, float]:
    return {
        "jct_ratio_mean": float(np.mean([r.jct_ratio for r in ratios])),
        "wait_ratio_mean": float(np.mean([r.wait_ratio for r in ratios])),
        "makespan_ratio_mean": float(
            np.mean([r.makespan_ratio for r in ratios])),
        "makespan_ratio_min": float(
            np.min([r.makespan_ratio for r in ratios])),
        "util_ratio_mean": float(np.mean([r.util_ratio for r in ratios])),
    }
