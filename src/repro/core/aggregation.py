"""Logical aggregation of leaves into JAX meshes (one-to-many on TPU).

This is the runtime half of the one-to-many model on TPU hardware: a job is
given an arbitrary set of leaves (chips) — possibly non-contiguous, spanning
hosts and pods — and we build a ``jax.sharding.Mesh`` whose device order
implements the paper's *topology-aware placement*: leaves are round-robined
across hosts so the collective-heavy mesh axes land on the fast intra-host/
intra-pod fabric (the SHM analogue) and only the outermost axis crosses the
slow boundary (the NET analogue).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.core.leaves import TpuLeaf


def round_robin_order(leaves: Sequence[TpuLeaf]) -> List[TpuLeaf]:
    """Topology-aware (round-robin across hosts) leaf ordering (§3.2)."""
    by_host = {}
    for leaf in leaves:
        by_host.setdefault((leaf.pod, leaf.host), []).append(leaf)
    for v in by_host.values():
        v.sort(key=lambda l: l.chip)
    hosts = sorted(by_host)
    out: List[TpuLeaf] = []
    cursors = {h: 0 for h in hosts}
    while len(out) < len(leaves):
        progressed = False
        for h in hosts:
            if cursors[h] < len(by_host[h]):
                out.append(by_host[h][cursors[h]])
                cursors[h] += 1
                progressed = True
        assert progressed
    return out


def packed_order(leaves: Sequence[TpuLeaf]) -> List[TpuLeaf]:
    """Naive pack-host-first ordering (the Fig. 9 ablation baseline)."""
    return sorted(leaves, key=lambda l: (l.pod, l.host, l.chip))


def grouped_order(leaves: Sequence[TpuLeaf]) -> List[TpuLeaf]:
    """Fast-axis-contiguous ordering: chips of one host stay adjacent so
    the *innermost* mesh axis is intra-host (used to map 'model' onto the
    fastest links)."""
    return packed_order(leaves)


def choose_leaves(all_leaves: Sequence[TpuLeaf], n: int, *,
                  busy: Optional[set] = None) -> List[TpuLeaf]:
    """Allocate ``n`` idle leaves, spreading across hosts (one-to-many)."""
    busy = busy or set()
    idle = [l for l in all_leaves if l.uuid not in busy]
    if len(idle) < n:
        raise RuntimeError(f"need {n} leaves, only {len(idle)} idle")
    return round_robin_order(idle)[:n]


def leaves_to_mesh(leaves: Sequence[TpuLeaf], shape: Tuple[int, ...],
                   axis_names: Tuple[str, ...], *,
                   devices: Optional[Sequence] = None,
                   order: str = "grouped") -> Mesh:
    """Build a Mesh over the job's leaves.

    ``devices``: the jax devices backing each leaf (same length/order as
    ``leaves``); defaults to ``jax.devices()[:len(leaves)]`` which is only
    meaningful with fake host devices (dry-run) or a real multichip runtime.

    ``order``: 'grouped' keeps hosts contiguous on the innermost axis
    (fast-axis collectives stay intra-host); 'round_robin' spreads them
    (the placement the paper's Fig. 9 *evaluates*, optimal for PCIe-bound
    GPU leaves); 'packed' is the naive baseline.
    """
    assert math.prod(shape) == len(leaves), (shape, len(leaves))
    if order == "round_robin":
        ordered = round_robin_order(leaves)
    elif order == "packed":
        ordered = packed_order(leaves)
    else:
        ordered = grouped_order(leaves)
    if devices is None:
        devices = jax.devices()[:len(leaves)]
    index = {l: i for i, l in enumerate(leaves)}
    dev_arr = np.array([devices[index[l]] for l in ordered],
                       dtype=object).reshape(shape)
    return Mesh(dev_arr, axis_names)
