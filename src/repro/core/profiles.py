"""MIG profile table and tree-constrained layout (paper Table 3 / Fig. 3).

An A100-40GB exposes 7 compute slices and 8 memory slices (5 GB each).
Profiles occupy a *specific* set of compute slices (the tree constraint C2:
only slice-sets sharing a parent are valid) plus a memory-slice budget.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Tuple

N_COMPUTE_SLICES = 7
N_MEMORY_SLICES = 8
MEMORY_PER_SLICE_GB = 5


@dataclasses.dataclass(frozen=True)
class Profile:
    name: str
    sm_slices: int               # compute slices (i in ig.jgb)
    mem_gb: int
    mem_slices: int
    max_per_gpu: int
    # tree-valid compute-slice placements (C2)
    placements: Tuple[FrozenSet[int], ...]


def _fz(*xs) -> FrozenSet[int]:
    return frozenset(xs)


# A100-40GB PCIe profile tree (paper Appendix A + NVIDIA MIG user guide).
PROFILES: Dict[str, Profile] = {
    "1g.5gb": Profile("1g.5gb", 1, 5, 1, 7,
                      tuple(_fz(i) for i in range(7))),
    "1g.10gb": Profile("1g.10gb", 1, 10, 2, 4,
                       (_fz(0), _fz(2), _fz(4), _fz(6))),
    "2g.10gb": Profile("2g.10gb", 2, 10, 2, 3,
                       (_fz(0, 1), _fz(2, 3), _fz(4, 5))),
    "3g.20gb": Profile("3g.20gb", 3, 20, 4, 2,
                       (_fz(0, 1, 2), _fz(4, 5, 6))),
    "4g.20gb": Profile("4g.20gb", 4, 20, 4, 1,
                       (_fz(0, 1, 2, 3),)),
    "7g.40gb": Profile("7g.40gb", 7, 40, 8, 1,
                       (_fz(0, 1, 2, 3, 4, 5, 6),)),
}

# Flex-MIG fixed partition (§3): 6 x 1g.5gb + 1 x 1g.10gb fills all 40 GB.
FLEXMIG_PARTITION: Tuple[str, ...] = ("1g.5gb",) * 6 + ("1g.10gb",)

# Static-MIG fixed partition (§5.1 baselines).
STATIC_PARTITION: Tuple[str, ...] = ("1g.10gb", "2g.10gb", "4g.20gb")

# one-to-one rounding (I1): workload size -> smallest covering profile.
SIZE_TO_PROFILE: Dict[int, str] = {
    1: "1g.5gb", 2: "2g.10gb", 3: "4g.20gb", 4: "4g.20gb",
    5: "7g.40gb", 6: "7g.40gb", 7: "7g.40gb", 8: "7g.40gb",
}


def round_up_profile(size: int) -> str:
    """One-to-one allocation model rounding (over-provisioning, Fig. 2)."""
    if size not in SIZE_TO_PROFILE:
        raise ValueError(f"workload size {size} unsupported")
    return SIZE_TO_PROFILE[size]


def overprovision_slices(size: int) -> int:
    """Wasted compute slices when rounding size -> profile (Fig. 2)."""
    return PROFILES[round_up_profile(size)].sm_slices - size


def mergeable(slice_a: int, slice_b: int) -> bool:
    """Fig. 3a: two adjacent 1g slices merge into 2g only if they share a
    2g parent node in the tree."""
    return frozenset((slice_a, slice_b)) in PROFILES["2g.10gb"].placements
