"""Operation modes: Flex-MIG (FM), Dynamic-MIG (DM), Static-MIG (SM).

Each mode implements ``try_place`` / ``release``.  DM may answer with a
``ReconfigPlan`` — the drain-required path (C4) whose costs the simulator
charges: checkpoint save + MIG reconfigure (100-120 s, §2.3.3) + restore +
pod churn.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

from repro.core import policy
from repro.core.job import Job, Placement
from repro.core.leaves import Cluster, GPUState
from repro.core.profiles import (FLEXMIG_PARTITION, PROFILES,
                                 STATIC_PARTITION, round_up_profile)

# §2.3.3 measured overheads
RECONFIGURE_S = 110.0            # mig-manager cycle: 100-120 s end to end
CKPT_SAVE_S = 3.0                # "a few seconds" per save
CKPT_LOAD_S = 3.0
POD_CHURN_S = 4.0                # pod delete/create


@dataclasses.dataclass
class ReconfigPlan:
    """Drain-required reconfiguration of one GPU for a pending job.

    Cost structure per §2.3.3: the mig-manager reconfigure cycle (100-120 s
    end-to-end) plus, for every running job on the GPU, checkpoint save +
    load and pod delete/create churn.
    """
    host_id: int
    gpu_id: int
    job: Job
    affected_jobs: Tuple[str, ...]

    @property
    def duration(self) -> float:
        per_job = CKPT_SAVE_S + CKPT_LOAD_S + POD_CHURN_S
        return RECONFIGURE_S + per_job * len(self.affected_jobs)

    @property
    def base_duration(self) -> float:
        """The mig-manager reconfigure cycle alone — what remains of the
        geometry change when affected jobs hand off concurrently instead
        of serializing their save/load/churn into the drain."""
        return RECONFIGURE_S


PlaceResult = Union[Placement, ReconfigPlan, None]


class OperationMode:
    name = "base"
    one_to_many = False

    def setup(self, cluster: Cluster) -> None:
        raise NotImplementedError

    def try_place(self, job: Job, cluster: Cluster) -> PlaceResult:
        raise NotImplementedError

    def release(self, placement: Placement, cluster: Cluster) -> None:
        for inst in placement.instances:
            cluster.mark_idle(inst)
        if self.name == "DM":
            # dynamic mode tears idle instances down lazily at next place
            pass

    # helper -----------------------------------------------------------
    @staticmethod
    def _bind(placement: Placement, job: Job,
              cluster: Cluster) -> Placement:
        # busy flips go through the cluster so its O(hosts) idle-leaf
        # accounting stays exact (see Cluster.mark_busy)
        for inst in placement.instances:
            cluster.mark_busy(inst, job.job_id)
        return placement


class FlexMIG(OperationMode):
    """One-to-many over fixed minimal leaves (the paper's system).

    ``placement`` selects the host/leaf scoring: ``"default"`` is the
    paper's policy (most-idle host, round-robin leaves per Fig. 9);
    ``"frag_aware"`` scores candidates by the idle fragments they
    strand and takes the minimum-fragmentation feasible one
    (policy.frag_aware_choose_host / frag_aware_select_instances).
    """
    name = "FM"
    one_to_many = True

    PLACEMENTS = ("default", "frag_aware")

    def __init__(self, *, round_robin: bool = True,
                 placement: str = "default"):
        if placement not in self.PLACEMENTS:
            raise ValueError(f"unknown FM placement {placement!r}; "
                             f"one of {self.PLACEMENTS}")
        self.round_robin = round_robin
        self.placement = placement

    def setup(self, cluster: Cluster) -> None:
        cluster.partition_all(FLEXMIG_PARTITION)

    def try_place(self, job: Job, cluster: Cluster) -> PlaceResult:
        if self.placement == "frag_aware":
            host = policy.frag_aware_choose_host(cluster, job.size)
            if host is None:
                return None
            chosen = policy.frag_aware_select_instances(cluster, host,
                                                        job.size)
        else:
            host = policy.choose_host(cluster, job.size)
            if host is None:
                return None
            chosen = policy.select_instances(cluster, host, job.size,
                                             round_robin=self.round_robin)
        if chosen is None:
            return None
        transport = "NONE" if job.size == 1 else "SHM"
        return self._bind(Placement(job.job_id, chosen, transport), job,
                          cluster)


class StaticMIG(OperationMode):
    """Fixed [1g.10gb, 2g.10gb, 4g.20gb]; upgrade-to-larger rule."""
    name = "SM"
    one_to_many = False

    def setup(self, cluster: Cluster) -> None:
        cluster.partition_all(STATIC_PARTITION)

    def try_place(self, job: Job, cluster: Cluster) -> PlaceResult:
        if job.size > 4:
            return None            # unsupported by the static partition
        want = {1: "1g.10gb", 2: "2g.10gb", 3: "4g.20gb",
                4: "4g.20gb"}[job.size]
        order = {"1g.10gb": 0, "2g.10gb": 1, "4g.20gb": 2}
        # exact fit first, then any larger idle instance (MIG 2025 rule)
        candidates = [i for i in cluster.idle_instances()
                      if order[i.profile] >= order[want]]
        if not candidates:
            return None
        candidates.sort(key=lambda i: order[i.profile])
        inst = candidates[0]
        pl = Placement(job.job_id, [inst], "NONE", one_to_one=True)
        return self._bind(pl, job, cluster)


class DynamicMIG(OperationMode):
    """On-demand reconfiguration with drains (the incumbent model)."""
    name = "DM"
    one_to_many = False

    def setup(self, cluster: Cluster) -> None:
        pass                       # starts unpartitioned

    def try_place(self, job: Job, cluster: Cluster) -> PlaceResult:
        profile = round_up_profile(job.size)
        # 1. an idle instance of the right profile already exists — the
        # only drain-free path (no geometry change).
        for inst in cluster.idle_instances(profile=profile):
            if cluster.gpus[(inst.host_id, inst.gpu_id)].draining:
                continue
            pl = Placement(job.job_id, [inst], "NONE", one_to_one=True)
            return self._bind(pl, job, cluster)
        # 2. any geometry change is a mig-manager reconfigure (C4).  Prefer
        # a GPU with no running jobs (reconfig latency only, no
        # suspend/resume), else drain one whose running jobs can coexist
        # with the new profile.  Inference jobs must not be drained.
        best: Optional[ReconfigPlan] = None
        for gpu in cluster.all_gpus():
            if gpu.draining:
                continue
            if not gpu.could_fit_after_repartition(profile):
                continue
            affected = gpu.running_jobs()
            if self._has_inference(affected, cluster):
                continue
            plan = ReconfigPlan(gpu.host_id, gpu.gpu_id, job,
                                tuple(affected))
            if best is None or len(plan.affected_jobs) < \
                    len(best.affected_jobs):
                best = plan
        return best

    def apply_reconfig(self, plan: ReconfigPlan,
                       cluster: Cluster) -> Placement:
        gpu = cluster.gpus[(plan.host_id, plan.gpu_id)]
        profile = round_up_profile(plan.job.size)
        inst = gpu.repartition_for(profile, _uuid(cluster))
        cluster.invalidate_cache()   # structural: instances re-laid-out
        pl = Placement(plan.job.job_id, [inst], "NONE", one_to_one=True)
        return self._bind(pl, plan.job, cluster)

    # inference jobs cannot be drained (service interruption, §5.1)
    _inference_jobs: set = set()

    def register_inference(self, job_ids) -> None:
        self._inference_jobs = set(job_ids)

    def _has_inference(self, job_ids, cluster) -> bool:
        return any(j in self._inference_jobs for j in job_ids)


def _uuid(cluster: Cluster) -> str:
    return cluster.next_uuid()


def make_mode(name: str, **kw) -> OperationMode:
    return {"FM": FlexMIG, "DM": DynamicMIG, "SM": StaticMIG}[name](**kw)
