"""Job and placement records shared by the orchestration layer."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.leaves import Instance


# priority tiers (numerically lower = more important).  Tier 0 jobs are
# latency/SLA-sensitive: the cluster runtime places them for best
# transport (single-host SHM when they fit) and lets them trigger
# consolidation repacks of lower-tier jobs; tier 1 is the default
# best-effort tier; higher numbers yield to everything above them.
TIER_HIGH = 0
TIER_NORMAL = 1

# tenant every job belongs to unless a trace says otherwise — keeps the
# single-tenant replay paths (and their goldens) bit-identical
DEFAULT_TENANT = "default"


@dataclasses.dataclass
class Job:
    job_id: str
    model: str                    # Table-1 workload name
    kind: str                     # "train" | "inference"
    size: int                     # workload size (leaves / slices)
    batch: int
    base_duration: float          # JCT on the reference placement (seconds)
    submit_time: float = 0.0

    # multi-tenancy: which tenant owns the job (per-tenant quotas are
    # enforced by the scheduler when armed) and its priority tier.
    # Defaults reproduce the single-tenant, single-tier behavior every
    # existing trace and golden replay encodes.
    tenant: str = DEFAULT_TENANT
    priority_tier: int = TIER_NORMAL

    # runtime bookkeeping
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    suspended_overhead: float = 0.0
    ckpt_bytes: float = 0.0
    # failure-recovery bookkeeping (simulator MTBF events): fraction of
    # the job's work still to run (shrinks only by checkpoint-saved
    # progress — work since the last save is lost and redone), the
    # restart charge to pay when next placed, and how often this job was
    # killed by a host failure
    remaining_frac: float = 1.0
    pending_recovery_s: float = 0.0
    n_failures: int = 0

    @property
    def train(self) -> bool:
        return self.kind == "train"


@dataclasses.dataclass
class Placement:
    job_id: str
    instances: List[Instance]
    transport: str                # "SHM" | "NET" | "NONE"
    one_to_one: bool = False

    def instance_types(self) -> Tuple[str, ...]:
        return tuple(i.profile for i in self.instances)

    def leaves_per_gpu(self) -> Tuple[int, ...]:
        counts = {}
        for inst in self.instances:
            key = (inst.host_id, inst.gpu_id)
            counts[key] = counts.get(key, 0) + 1
        return tuple(counts.values())

    def hosts(self) -> Tuple[int, ...]:
        return tuple(sorted({i.host_id for i in self.instances}))
