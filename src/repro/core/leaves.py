"""Cluster resource model: hosts, GPUs, MIG instances / leaves.

The GPU state machine enforces the hardware constraints the paper builds on:
C1 (fixed profiles), C2 (tree-constrained placement) — see profiles.py — and
C3 (no cross-GPU aggregation) which is a property of *allocation*, enforced
in core/allocation.py for the one-to-one model and deliberately lifted by
the Flex-MIG one-to-many model.

Also provides the TPU-slice analogue used by the runtime layer (DESIGN.md
§2): hosts of 4 chips, "leaves" = chips, pods of hosts.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.profiles import (MEMORY_PER_SLICE_GB, N_COMPUTE_SLICES,
                                 N_MEMORY_SLICES, PROFILES, Profile)


@dataclasses.dataclass
class Instance:
    """A concrete MIG instance on a GPU."""
    uuid: str
    profile: str
    gpu_id: int
    host_id: int
    slices: FrozenSet[int]
    mem_slices: int
    job_id: Optional[str] = None

    @property
    def busy(self) -> bool:
        return self.job_id is not None


@dataclasses.dataclass
class GPUState:
    host_id: int
    gpu_id: int
    instances: List[Instance] = dataclasses.field(default_factory=list)
    pcie_bus_id: str = ""
    draining: bool = False        # drain-required reconfigure in flight

    def __post_init__(self):
        if not self.pcie_bus_id:
            self.pcie_bus_id = f"00:{0x40 + self.gpu_id:02X}:00.0"

    # ------------------------------------------------------------ queries
    def used_slices(self) -> FrozenSet[int]:
        out: FrozenSet[int] = frozenset()
        for inst in self.instances:
            out |= inst.slices
        return out

    def used_mem_slices(self) -> int:
        return sum(inst.mem_slices for inst in self.instances)

    def free_compute_slices(self) -> int:
        return N_COMPUTE_SLICES - len(self.used_slices())

    def free_mem_slices(self) -> int:
        return N_MEMORY_SLICES - self.used_mem_slices()

    def has_running_jobs(self) -> bool:
        return any(i.busy for i in self.instances)

    def running_jobs(self) -> List[str]:
        return [i.job_id for i in self.instances if i.busy]

    # --------------------------------------------------------- placement
    def valid_placement(self, profile: str) -> Optional[FrozenSet[int]]:
        """First tree-valid free slice-set for ``profile`` (C1+C2)."""
        p = PROFILES[profile]
        if p.mem_slices > self.free_mem_slices():
            return None
        used = self.used_slices()
        for cand in p.placements:
            if not (cand & used):
                return cand
        return None

    def create_instance(self, profile: str, uuid: str) -> Instance:
        cand = self.valid_placement(profile)
        if cand is None:
            raise RuntimeError(
                f"no tree-valid placement for {profile} on gpu {self.gpu_id}")
        inst = Instance(uuid=uuid, profile=profile, gpu_id=self.gpu_id,
                        host_id=self.host_id, slices=cand,
                        mem_slices=PROFILES[profile].mem_slices)
        self.instances.append(inst)
        return inst

    def destroy_idle_instances(self):
        self.instances = [i for i in self.instances if i.busy]

    def could_fit_after_repartition(self, profile: str) -> bool:
        """Would ``profile`` fit if idle instances were destroyed and the
        GPU repartitioned (the drain-required path, C4)?  Running jobs keep
        their profiles."""
        p = PROFILES[profile]
        running = [i for i in self.instances if i.busy]
        run_slices = sum(PROFILES[i.profile].sm_slices for i in running)
        run_mem = sum(i.mem_slices for i in running)
        if run_slices + p.sm_slices > N_COMPUTE_SLICES:
            return False
        if run_mem + p.mem_slices > N_MEMORY_SLICES:
            return False
        # conservative feasibility: try to re-lay-out running profiles plus
        # the new one on an empty tree (greedy largest-first).
        profs = sorted([i.profile for i in running] + [profile],
                       key=lambda q: -PROFILES[q].sm_slices)
        return _layout_feasible(profs)

    def repartition_for(self, profile: str, uuid: str) -> Instance:
        """Drain-style repartition: destroy idle instances, re-lay-out
        running instances, create ``profile``.  Caller accounts C4 costs."""
        running = [i for i in self.instances if i.busy]
        profs = sorted(running + [None],
                       key=lambda i: -PROFILES[i.profile].sm_slices
                       if i else -PROFILES[profile].sm_slices)
        self.instances = []
        layout = _layout([i.profile if i else profile for i in profs])
        assert layout is not None
        new_inst: Optional[Instance] = None
        for inst, slices in zip(profs, layout):
            if inst is None:
                new_inst = Instance(uuid=uuid, profile=profile,
                                    gpu_id=self.gpu_id, host_id=self.host_id,
                                    slices=slices,
                                    mem_slices=PROFILES[profile].mem_slices)
                self.instances.append(new_inst)
            else:
                inst.slices = slices
                self.instances.append(inst)
        assert new_inst is not None
        return new_inst


def _layout(profs: Sequence[str]) -> Optional[List[FrozenSet[int]]]:
    """Greedy backtracking layout of profiles onto an empty tree."""
    out: List[FrozenSet[int]] = []

    def rec(i: int, used: FrozenSet[int], mem: int) -> bool:
        if i == len(profs):
            return True
        p = PROFILES[profs[i]]
        if mem + p.mem_slices > N_MEMORY_SLICES:
            return False
        for cand in p.placements:
            if not (cand & used):
                out.append(cand)
                if rec(i + 1, used | cand, mem + p.mem_slices):
                    return True
                out.pop()
        return False

    return out if rec(0, frozenset(), 0) else None


def _layout_feasible(profs: Sequence[str]) -> bool:
    return _layout(profs) is not None


@dataclasses.dataclass
class Cluster:
    """A multi-tenant cluster: hosts x GPUs (paper testbed: 1 host, 2 GPUs).

    Scales to arbitrary host/GPU counts for the 1000-node experiments.

    Fleet-scale accounting: the cluster keeps per-host idle-leaf counts
    and idle/free slice totals as an incrementally-maintained cache so
    the scheduler hot path (host choice, idle-slice sums) is O(hosts)
    instead of O(hosts x GPUs x leaves) per query.  Busy flips MUST go
    through :meth:`mark_busy` / :meth:`mark_idle` (the operation modes
    do); structural changes (partitioning, repartition) call
    :meth:`invalidate_cache`, and the cache rebuilds lazily on next
    query.  Standalone :class:`GPUState` mutation in tests never touches
    a cluster, so it cannot go stale.
    """
    n_hosts: int = 1
    gpus_per_host: int = 2
    gpus: Dict[Tuple[int, int], GPUState] = dataclasses.field(
        default_factory=dict)
    _uuid_counter: int = 0

    def __post_init__(self):
        if not self.gpus:
            for h in range(self.n_hosts):
                for g in range(self.gpus_per_host):
                    self.gpus[(h, g)] = GPUState(host_id=h, gpu_id=g)
        self._cache_dirty = True
        self._idle_by_host: List[int] = []
        self._idle_sm_total = 0
        self._free_compute_total = 0

    # ------------------------------------------------ idle-leaf accounting
    def invalidate_cache(self) -> None:
        """Structural change (instances created/destroyed/re-laid-out):
        drop the idle accounting; it rebuilds on next query."""
        self._cache_dirty = True

    def _ensure_cache(self) -> None:
        if not self._cache_dirty:
            return
        by_host = [0] * self.n_hosts
        sm_total = 0
        free_compute = 0
        for (h, _), gpu in self.gpus.items():
            free_compute += gpu.free_compute_slices()
            for inst in gpu.instances:
                if not inst.busy:
                    by_host[h] += 1
                    sm_total += PROFILES[inst.profile].sm_slices
        self._idle_by_host = by_host
        self._idle_sm_total = sm_total
        self._free_compute_total = free_compute
        self._cache_dirty = False

    def mark_busy(self, inst: Instance, job_id: str) -> None:
        """Bind ``inst`` to a job, maintaining the idle accounting."""
        was_idle = not inst.busy
        inst.job_id = job_id
        if was_idle and not self._cache_dirty:
            self._idle_by_host[inst.host_id] -= 1
            self._idle_sm_total -= PROFILES[inst.profile].sm_slices

    def mark_idle(self, inst: Instance) -> None:
        """Release ``inst``, maintaining the idle accounting."""
        was_busy = inst.busy
        inst.job_id = None
        if was_busy and not self._cache_dirty:
            self._idle_by_host[inst.host_id] += 1
            self._idle_sm_total += PROFILES[inst.profile].sm_slices

    def idle_leaf_count(self, host: int) -> int:
        self._ensure_cache()
        return self._idle_by_host[host]

    def idle_leaf_counts(self) -> List[int]:
        """Idle leaves per host (do not mutate the returned list)."""
        self._ensure_cache()
        return self._idle_by_host

    def idle_sm_slices(self) -> int:
        """Total compute slices held by idle instances."""
        self._ensure_cache()
        return self._idle_sm_total

    def free_compute_total(self) -> int:
        """Total un-partitioned compute slices (no instance over them).
        Changes only on structural ops, never on busy flips."""
        self._ensure_cache()
        return self._free_compute_total

    def next_uuid(self) -> str:
        self._uuid_counter += 1
        return f"MIG-{self._uuid_counter:08x}"

    def host_gpus(self, host: int) -> List[GPUState]:
        return [self.gpus[(host, g)] for g in range(self.gpus_per_host)]

    def all_gpus(self) -> List[GPUState]:
        return list(self.gpus.values())

    def partition_all(self, partition: Sequence[str]):
        """Statically partition every GPU (FM / SM setup).

        Profiles are placed largest-first so tree-valid slice-sets remain
        available (e.g. 4g.20gb must claim {0..3} before 2g takes {2,3}).
        """
        ordered = sorted(partition,
                         key=lambda p: -PROFILES[p].sm_slices)
        for gpu in self.gpus.values():
            assert not gpu.instances
            for prof in ordered:
                gpu.create_instance(prof, self.next_uuid())
        self.invalidate_cache()

    def idle_instances(self, host: Optional[int] = None,
                       profile: Optional[str] = None) -> List[Instance]:
        out = []
        for (h, g), gpu in self.gpus.items():
            if host is not None and h != host:
                continue
            for inst in gpu.instances:
                if not inst.busy and (profile is None
                                      or inst.profile == profile):
                    out.append(inst)
        return out

    def total_leaves(self) -> int:
        return sum(len(g.instances) for g in self.gpus.values())


# ---------------------------------------------------------------------------
# TPU-slice analogue (runtime layer; DESIGN.md §2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TpuLeaf:
    """A TPU 'leaf' = one chip.  uuid plays the role of the MIG UUID."""
    pod: int
    host: int
    chip: int

    @property
    def uuid(self) -> str:
        return f"TPU-{self.pod}-{self.host}-{self.chip}"


@dataclasses.dataclass(frozen=True)
class TpuSliceTopology:
    """Pods of hosts of chips; fixed minimal leaves (the one-to-many
    flattening applied to TPU slices)."""
    n_pods: int = 2
    hosts_per_pod: int = 64
    chips_per_host: int = 4

    def leaves(self) -> List[TpuLeaf]:
        return [TpuLeaf(p, h, c)
                for p in range(self.n_pods)
                for h in range(self.hosts_per_pod)
                for c in range(self.chips_per_host)]

    @property
    def chips_per_pod(self) -> int:
        return self.hosts_per_pod * self.chips_per_host
