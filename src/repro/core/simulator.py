"""Calibrated discrete-event simulator (§5: the paper's evaluation vehicle).

Replays a trace of jobs against a cluster under one of the three operation
modes (FM/DM/SM) and a scheduling policy (FIFO / aggressive backfilling),
charging the paper's measured cost structure: placement-dependent JCT
scaling (core/jct_model.py), drain-required reconfiguration (C4) with
checkpoint save/load + pod churn, and the x1.06 concurrency calibration.

``ground_truth=True`` turns the simulator into the "real testbed" stand-in
(stochastic interference instead of the constant factor) against which the
Fig. 6 parity plots validate the calibrated simulator.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import jct_model
from repro.core.job import Job, Placement
from repro.core.leaves import Cluster
from repro.core.modes import (DynamicMIG, OperationMode, PlaceResult,
                              ReconfigPlan, make_mode)
from repro.core.profiles import N_COMPUTE_SLICES, PROFILES
from repro.core.scheduler import Scheduler, WaitQueue


@dataclasses.dataclass(frozen=True)
class ReconfigRecord:
    """One geometry-change event as the simulator charged it."""
    t: float                      # when the reconfiguration started
    kind: str                     # "reshape" | "drain" | "handoff"
    n_affected: int               # running jobs suspended by it
    charged_s: float              # total suspension charged across them
    gpu: Tuple[int, int]          # (host_id, gpu_id)


@dataclasses.dataclass
class SimResult:
    mode: str
    makespan: float
    avg_jct: float
    avg_wait: float
    avg_ext_frag_delay: float
    utilization: float
    n_reconfigs: int
    n_drains: int
    n_jobs: int
    jct_by_job: Dict[str, float]
    wait_by_job: Dict[str, float]
    # drain-vs-handoff accounting (reconfig cost model; defaults keep
    # pre-existing constructors working)
    n_handoffs: int = 0
    drain_cost_s: float = 0.0     # suspension charged under drains
    handoff_cost_s: float = 0.0   # suspension charged under handoffs
    reconfig_events: List[ReconfigRecord] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class _Running:
    job: Job
    placement: Placement
    finish_version: int = 0
    finish_at: float = 0.0        # absolute time of the live finish event


class Simulation:
    def __init__(self, jobs: List[Job], mode: OperationMode, *,
                 n_hosts: int = 1, gpus_per_host: int = 2,
                 scheduler: Optional[Scheduler] = None,
                 calibrate: bool = True, ground_truth: bool = False,
                 reconfig_cost: Optional[jct_model.ReconfigCostModel]
                 = None,
                 seed: int = 0):
        self.jobs = {j.job_id: j for j in jobs}
        self.mode = mode
        self.cluster = Cluster(n_hosts=n_hosts, gpus_per_host=gpus_per_host)
        mode.setup(self.cluster)
        if isinstance(mode, DynamicMIG):
            mode.register_inference(
                [j.job_id for j in jobs if not j.train])
        self.scheduler = scheduler or Scheduler("fifo")
        self.calibrate = calibrate
        self.ground_truth = ground_truth
        self.rng = np.random.default_rng(seed)

        self.queue = WaitQueue()
        self.running: Dict[str, _Running] = {}
        self.events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.n_reconfigs = 0      # all geometry changes (C4 events)
        self.n_drains = 0         # geometry changes suspending live jobs
        self.n_handoffs = 0       # suspensions priced as handoffs instead
        self.drain_cost_s = 0.0
        self.handoff_cost_s = 0.0
        self.reconfig_records: List[ReconfigRecord] = []
        self.reconfig_cost = (reconfig_cost if reconfig_cost is not None
                              else jct_model.ReconfigCostModel())
        self.reconfig_pending: Dict[str, ReconfigPlan] = {}
        self.frag_since: Dict[str, float] = {}
        self.ext_frag: Dict[str, float] = {}
        # utilization integral
        self._busy_slices = 0
        self._last_t = 0.0
        self._busy_integral = 0.0
        self._first_start: Optional[float] = None
        self._last_finish = 0.0

        for j in jobs:
            self._push(j.submit_time, "arrive", j)

    # ------------------------------------------------------------ events
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self.events, (t, next(self._seq), kind, payload))

    def _advance(self, t: float) -> None:
        self._busy_integral += self._busy_slices * (t - self._last_t)
        self._last_t = t
        self.now = t

    # --------------------------------------------------------------- run
    def run(self) -> SimResult:
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            self._advance(t)
            if kind == "arrive":
                self.queue.push(payload)
            elif kind == "finish":
                job_id, version = payload
                rec = self.running.get(job_id)
                if rec is None or rec.finish_version != version:
                    continue        # stale (rescheduled by a drain)
                self._finish(rec)
            elif kind == "reconfig_done":
                self._reconfig_done(payload)
            self._schedule_pass()
        return self._result()

    # ---------------------------------------------------------- placement
    def _schedule_pass(self) -> None:
        placed_any = True
        while placed_any:
            placed_any = False
            # the cluster is immutable until a candidate places, which
            # restarts the while-loop — so the idle-slice sum is computed
            # at most once per round instead of per blocked candidate
            # (quadratic on large traces otherwise)
            idle_slices: Optional[int] = None
            for job in list(self.scheduler.candidates(self.queue)):
                res = self.mode.try_place(job, self.cluster)
                if isinstance(res, Placement):
                    self.queue.remove(job)
                    self._note_frag_end(job)
                    self._start(job, res)
                    placed_any = True
                    break           # re-evaluate candidates from the top
                if isinstance(res, ReconfigPlan):
                    self.queue.remove(job)
                    self._note_frag_end(job)
                    self._start_reconfig(res)
                    placed_any = True
                    break
                if idle_slices is None:
                    idle_slices = self._idle_slice_sum()
                self._note_frag(job, idle_slices)
                if self.scheduler.policy == "fifo":
                    break

    def _idle_slice_sum(self) -> int:
        idle = sum(PROFILES[i.profile].sm_slices
                   for i in self.cluster.idle_instances())
        if self.mode.name == "DM":
            idle += sum(
                g.free_compute_slices() for g in self.cluster.all_gpus())
        return idle

    def _note_frag(self, job: Job, idle_slices: int) -> None:
        """External-fragmentation bookkeeping: enough idle capacity in
        total, but no placement (I2)."""
        blocked_with_capacity = idle_slices >= job.size
        if blocked_with_capacity and job.job_id not in self.frag_since:
            self.frag_since[job.job_id] = self.now
        elif not blocked_with_capacity and job.job_id in self.frag_since:
            self._note_frag_end(job)

    def _note_frag_end(self, job: Job) -> None:
        t0 = self.frag_since.pop(job.job_id, None)
        if t0 is not None:
            self.ext_frag[job.job_id] = (self.ext_frag.get(job.job_id, 0.0)
                                         + (self.now - t0))

    def _jct(self, job: Job, placement: Placement) -> float:
        if placement.one_to_one:
            inst = placement.instances[0]
            view = jct_model.PlacementView(
                (inst.profile,), (1,), "NONE",
                sm_slices=PROFILES[inst.profile].sm_slices)
        else:
            net_jobs = sum(1 for r in self.running.values()
                           if r.placement.transport == "NET")
            view = jct_model.PlacementView(
                placement.instance_types(), placement.leaves_per_gpu(),
                placement.transport, concurrent_net_jobs=net_jobs + 1)
        scale = jct_model.jct_scale(job.model, job.batch, job.size, view,
                                    train=job.train)
        base = job.base_duration * scale
        concurrent = bool(self.running)
        if self.ground_truth:
            return jct_model.interference_ground_truth(
                base, concurrent=concurrent, rng=self.rng)
        return jct_model.calibrated(base, concurrent=concurrent,
                                    calibrate=self.calibrate)

    def _start(self, job: Job, placement: Placement) -> None:
        job.start_time = self.now
        if self._first_start is None:
            self._first_start = self.now
        dur = self._jct(job, placement)
        rec = _Running(job, placement, finish_at=self.now + dur)
        self.running[job.job_id] = rec
        self._busy_slices += sum(PROFILES[i.profile].sm_slices
                                 for i in placement.instances)
        self._push(rec.finish_at, "finish", (job.job_id, 0))

    def _finish(self, rec: _Running) -> None:
        job = rec.job
        job.finish_time = self.now
        self._last_finish = max(self._last_finish, self.now)
        self._busy_slices -= sum(PROFILES[i.profile].sm_slices
                                 for i in rec.placement.instances)
        self.mode.release(rec.placement, self.cluster)
        del self.running[job.job_id]

    # ------------------------------------------------------ reconfig (DM)
    def _start_reconfig(self, plan: ReconfigPlan) -> None:
        cm = self.reconfig_cost
        handoff = cm.mode == "handoff"
        self.n_reconfigs += 1
        if plan.affected_jobs:
            if handoff:
                self.n_handoffs += 1
            else:
                self.n_drains += 1
        gpu = self.cluster.gpus[(plan.host_id, plan.gpu_id)]
        gpu.draining = True
        # suspend affected jobs: push their finish events out by what the
        # cost model charges — the full drain duration under the
        # incumbent model, the (calibrated, measured) sharded
        # save + reshard-restore + recompile under the paper's handoff
        charged_total = 0.0
        for job_id in plan.affected_jobs:
            rec = self.running.get(job_id)
            if rec is None:
                continue
            remaining = self._remaining_until_finish(rec)
            n_ranks = max(rec.job.size, 1)
            charged = cm.job_suspension_s(
                jct_model.ckpt_state_bytes(rec.job.model),
                drain_s=plan.duration,
                n_ranks_old=n_ranks, n_ranks_new=n_ranks)
            charged_total += charged
            rec.finish_version += 1
            rec.job.suspended_overhead += charged
            rec.finish_at = self.now + remaining + charged
            self._push(rec.finish_at, "finish",
                       (job_id, rec.finish_version))
        if handoff:
            self.handoff_cost_s += charged_total
        else:
            self.drain_cost_s += charged_total
        kind = ("reshape" if not plan.affected_jobs
                else "handoff" if handoff else "drain")
        self.reconfig_records.append(ReconfigRecord(
            t=self.now, kind=kind, n_affected=len(plan.affected_jobs),
            charged_s=charged_total, gpu=(plan.host_id, plan.gpu_id)))
        done_in = cm.geometry_s(base_s=plan.base_duration,
                                drain_s=plan.duration)
        self._push(self.now + done_in, "reconfig_done", plan)

    def _remaining_until_finish(self, rec: _Running) -> float:
        """Time left on the currently-live finish event of ``rec``.

        O(1): ``finish_at`` mirrors the live (version-matching) finish
        event — stale events from earlier drains are superseded, never
        removed, so scanning the heap for it was O(events) per drained
        job."""
        return max(0.0, rec.finish_at - self.now)

    def _reconfig_done(self, plan: ReconfigPlan) -> None:
        gpu = self.cluster.gpus[(plan.host_id, plan.gpu_id)]
        gpu.draining = False
        assert isinstance(self.mode, DynamicMIG)
        placement = self.mode.apply_reconfig(plan, self.cluster)
        self._start(plan.job, placement)

    # ------------------------------------------------------------ result
    def _result(self) -> SimResult:
        done = [j for j in self.jobs.values() if j.finish_time is not None]
        jcts = {j.job_id: j.finish_time - j.start_time for j in done}
        waits = {j.job_id: j.start_time - j.submit_time for j in done}
        t0 = self._first_start or 0.0
        makespan = self._last_finish - min(
            (j.submit_time for j in self.jobs.values()), default=0.0)
        total_slices = (len(self.cluster.gpus) * N_COMPUTE_SLICES)
        util_span = max(self._last_finish - t0, 1e-9)
        util = self._busy_integral / (total_slices * util_span)
        frag = list(self.ext_frag.values())
        return SimResult(
            mode=self.mode.name,
            makespan=makespan,
            avg_jct=float(np.mean(list(jcts.values()))) if jcts else 0.0,
            avg_wait=float(np.mean(list(waits.values()))) if waits else 0.0,
            avg_ext_frag_delay=float(np.mean(frag)) if frag else 0.0,
            utilization=util,
            n_reconfigs=self.n_reconfigs,
            n_drains=self.n_drains,
            n_jobs=len(done),
            jct_by_job=jcts,
            wait_by_job=waits,
            n_handoffs=self.n_handoffs,
            drain_cost_s=self.drain_cost_s,
            handoff_cost_s=self.handoff_cost_s,
            reconfig_events=list(self.reconfig_records),
        )


def simulate(jobs: List[Job], mode_name: str, *, n_hosts: int = 1,
             gpus_per_host: int = 2, policy: str = "fifo",
             backfill_depth: int = 14, calibrate: bool = True,
             ground_truth: bool = False, seed: int = 0,
             round_robin: bool = True,
             reconfig_mode: Optional[str] = None,
             reconfig_cost: Optional[jct_model.ReconfigCostModel] = None
             ) -> SimResult:
    """Replay ``jobs`` under operation mode ``mode_name``.

    ``reconfig_mode='handoff'`` prices geometry changes with the paper's
    software-coordinated handoff instead of the drain-required cycle
    (``reconfig_cost`` supplies a calibrated
    :class:`~repro.core.jct_model.ReconfigCostModel`, e.g. built from
    ``BENCH_elastic.json`` measurements; without one the default
    calibration is used).  The cost model's own mode governs the
    charging, so passing *both* arguments with disagreeing modes is an
    error rather than a silently mislabeled replay.  The default (no
    mode, no cost model) is the incumbent drain behavior, bit-identical
    to the pre-cost-model simulator.
    """
    import copy
    jobs = copy.deepcopy(jobs)
    kw = {"round_robin": round_robin} if mode_name == "FM" else {}
    if reconfig_cost is None:
        reconfig_cost = jct_model.ReconfigCostModel(
            mode=reconfig_mode or "drain")
    elif reconfig_mode is not None and reconfig_cost.mode != reconfig_mode:
        raise ValueError(
            f"reconfig_mode={reconfig_mode!r} conflicts with the given "
            f"cost model's mode={reconfig_cost.mode!r}")
    sim = Simulation(jobs, make_mode(mode_name, **kw),
                     n_hosts=n_hosts, gpus_per_host=gpus_per_host,
                     scheduler=Scheduler(policy, depth=backfill_depth),
                     calibrate=calibrate, ground_truth=ground_truth,
                     reconfig_cost=reconfig_cost, seed=seed)
    return sim.run()
