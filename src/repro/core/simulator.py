"""Calibrated discrete-event simulator (§5: the paper's evaluation vehicle).

Replays a trace of jobs against a cluster under one of the three operation
modes (FM/DM/SM) and a scheduling policy (FIFO / aggressive backfilling),
charging the paper's measured cost structure: placement-dependent JCT
scaling (core/jct_model.py), drain-required reconfiguration (C4) with
checkpoint save/load + pod churn, and the x1.06 concurrency calibration.

``ground_truth=True`` turns the simulator into the "real testbed" stand-in
(stochastic interference instead of the constant factor) against which the
Fig. 6 parity plots validate the calibrated simulator.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import jct_model, policy
from repro.core.job import Job, Placement
from repro.core.leaves import Cluster, TpuLeaf
from repro.core.modes import (CKPT_LOAD_S, POD_CHURN_S, DynamicMIG,
                              OperationMode, PlaceResult, ReconfigPlan,
                              make_mode)
from repro.core.profiles import N_COMPUTE_SLICES, PROFILES
from repro.core.scheduler import Scheduler, WaitQueue


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Seeded MTBF-style host failures for the simulator.

    Failure arrivals are exponential with mean ``mtbf_s`` (a dedicated
    rng stream, so enabling failures never perturbs the ground-truth
    interference draws).  Each arrival strikes one uniformly-chosen host
    currently running placements; every job with an instance there is
    killed: its work since the last periodic checkpoint
    (``ckpt_interval_s`` cadence) is lost and redone, it pays a
    restart-from-checkpoint charge priced by the active
    :class:`~repro.core.jct_model.ReconfigCostModel`
    (``failure_restart_s`` — restore + recompile under handoffs, the
    incumbent reload constant under drains), and it is requeued.
    ``max_failures`` bounds the arrival count so a pathological
    mtbf << JCT configuration thrashes finitely instead of never
    terminating.
    """
    mtbf_s: float
    ckpt_interval_s: float = 600.0
    max_failures: int = 1000

    def __post_init__(self):
        if self.mtbf_s <= 0:
            raise ValueError("mtbf_s must be positive")
        if self.ckpt_interval_s <= 0:
            raise ValueError("ckpt_interval_s must be positive")
        if self.max_failures < 1:
            raise ValueError("max_failures must be >= 1")


@dataclasses.dataclass(frozen=True)
class ReconfigRecord:
    """One geometry-change event as the simulator charged it."""
    t: float                      # when the reconfiguration started
    kind: str                     # "reshape" | "drain" | "handoff"
    n_affected: int               # running jobs suspended by it
    charged_s: float              # total suspension charged across them
    gpu: Tuple[int, int]          # (host_id, gpu_id)


@dataclasses.dataclass
class SimResult:
    mode: str
    makespan: float
    avg_jct: float
    avg_wait: float
    avg_ext_frag_delay: float
    utilization: float
    n_reconfigs: int
    n_drains: int
    n_jobs: int
    jct_by_job: Dict[str, float]
    wait_by_job: Dict[str, float]
    # drain-vs-handoff accounting (reconfig cost model; defaults keep
    # pre-existing constructors working)
    n_handoffs: int = 0
    drain_cost_s: float = 0.0     # suspension charged under drains
    handoff_cost_s: float = 0.0   # suspension charged under handoffs
    reconfig_events: List[ReconfigRecord] = dataclasses.field(
        default_factory=list)
    # failure-recovery accounting (zero without a FailureModel)
    n_failures: int = 0           # failure events that killed >= 1 job
    n_recoveries: int = 0         # restarts-from-checkpoint consumed
    failure_lost_work_s: float = 0.0   # work redone (since-last-save)
    failure_restart_cost_s: float = 0.0
    goodput: float = 1.0          # useful / total busy job-seconds
    # fleet-scale bookkeeping (pure additions; no golden checks them):
    # heap events processed, and the time-integral of the cluster's
    # stranded-fragment score (policy.cluster_frag) — what the
    # frag-aware bake-off policies minimize
    n_events: int = 0
    frag_slice_seconds: float = 0.0    # integral of stranded frag over time
    avg_frag_slices: float = 0.0       # integral / active span


@dataclasses.dataclass
class _Running:
    job: Job
    placement: Placement
    finish_version: int = 0
    finish_at: float = 0.0        # absolute time of the live finish event
    # segment bookkeeping for failure-recovery math (a "segment" is one
    # continuous placement of the job; restarts begin a new segment)
    seg_start: float = 0.0        # when this segment started
    seg_work: float = 0.0         # JCT-scaled work seconds in the segment
    seg_overhead: float = 0.0     # drain/recovery charges inside finish_at
    seg_frac: float = 1.0         # job.remaining_frac at segment start


class Simulation:
    def __init__(self, jobs: List[Job], mode: OperationMode, *,
                 n_hosts: int = 1, gpus_per_host: int = 2,
                 scheduler: Optional[Scheduler] = None,
                 calibrate: bool = True, ground_truth: bool = False,
                 reconfig_cost: Optional[jct_model.ReconfigCostModel]
                 = None,
                 failure_model: Optional[FailureModel] = None,
                 seed: int = 0):
        self.jobs = {j.job_id: j for j in jobs}
        self.mode = mode
        self.cluster = Cluster(n_hosts=n_hosts, gpus_per_host=gpus_per_host)
        mode.setup(self.cluster)
        if isinstance(mode, DynamicMIG):
            mode.register_inference(
                [j.job_id for j in jobs if not j.train])
        self.scheduler = scheduler or Scheduler("fifo")
        self.calibrate = calibrate
        self.ground_truth = ground_truth
        self.rng = np.random.default_rng(seed)

        self.queue = WaitQueue()
        self.running: Dict[str, _Running] = {}
        self.events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.n_reconfigs = 0      # all geometry changes (C4 events)
        self.n_drains = 0         # geometry changes suspending live jobs
        self.n_handoffs = 0       # suspensions priced as handoffs instead
        self.drain_cost_s = 0.0
        self.handoff_cost_s = 0.0
        self.reconfig_records: List[ReconfigRecord] = []
        self.reconfig_cost = (reconfig_cost if reconfig_cost is not None
                              else jct_model.ReconfigCostModel())
        self.reconfig_pending: Dict[str, ReconfigPlan] = {}
        # failure plane: its own rng stream (enabling failures must not
        # perturb the ground-truth interference draws from self.rng)
        self.failure_model = failure_model
        self.failure_rng = np.random.default_rng([seed, 0xFA11])
        self.n_failures = 0
        self.n_recoveries = 0
        self.failure_lost_work_s = 0.0
        self.failure_restart_cost_s = 0.0
        self._failures_scheduled = 0
        # per-job finish-event version counters, monotone across
        # restarts: without them a stale finish event from a killed
        # segment (same job_id, version 0) would match the restarted
        # segment's fresh version-0 record and finish it early
        self._finish_versions: Dict[str, int] = {}
        self.frag_since: Dict[str, float] = {}
        self.ext_frag: Dict[str, float] = {}
        # utilization integral
        self._busy_slices = 0
        self._last_t = 0.0
        self._busy_integral = 0.0
        self._first_start: Optional[float] = None
        self._last_finish = 0.0
        self.n_events = 0
        # running placements with cross-host ("NET") transport — the JCT
        # model's concurrency term; maintained as a counter so _jct no
        # longer scans self.running per placement (O(running) x
        # O(placements) was superlinear on fleet traces)
        self._net_running = 0
        # stranded-fragment integral (policy.cluster_frag over time),
        # maintained per-host so each placement/release is O(hosts
        # touched) not O(hosts)
        self._frag_by_host = [0.0] * self.cluster.n_hosts
        self._frag_total = 0.0
        self._frag_integral = 0.0
        self._rebuild_frag()

        for j in jobs:
            self._push(j.submit_time, "arrive", j)
        if failure_model is not None:
            self._schedule_next_failure()

    # ------------------------------------------------------------ events
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self.events, (t, next(self._seq), kind, payload))

    def _advance(self, t: float) -> None:
        dt = t - self._last_t
        self._busy_integral += self._busy_slices * dt
        self._frag_integral += self._frag_total * dt
        self._last_t = t
        self.now = t

    # -------------------------------------------------- frag bookkeeping
    def _rebuild_frag(self) -> None:
        self._frag_by_host = [
            policy.stranded_frag(idle)
            for idle in self.cluster.idle_leaf_counts()]
        self._frag_total = sum(self._frag_by_host)

    def _update_frag(self, placement: Placement) -> None:
        """Refresh the stranded-frag contribution of every host the
        placement touches (idle counts changed there)."""
        for h in {i.host_id for i in placement.instances}:
            new = policy.stranded_frag(self.cluster.idle_leaf_count(h))
            self._frag_total += new - self._frag_by_host[h]
            self._frag_by_host[h] = new

    # --------------------------------------------------------------- run
    def run(self) -> SimResult:
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            self.n_events += 1
            self._advance(t)
            if kind == "arrive":
                self.queue.push(payload)
            elif kind == "finish":
                job_id, version = payload
                rec = self.running.get(job_id)
                if rec is None or rec.finish_version != version:
                    continue        # stale (rescheduled by a drain)
                self._finish(rec)
            elif kind == "reconfig_done":
                self._reconfig_done(payload)
            elif kind == "failure":
                self._host_failure()
            self._schedule_pass()
        return self._result()

    # ---------------------------------------------------------- placement
    def _schedule_pass(self) -> None:
        placed_any = True
        while placed_any:
            placed_any = False
            # the cluster is immutable until a candidate places, which
            # restarts the while-loop — so the idle-slice sum is computed
            # at most once per round instead of per blocked candidate
            # (quadratic on large traces otherwise)
            idle_slices: Optional[int] = None
            # per-tenant device usage, computed only when the scheduler
            # is armed with quotas (the default replay path never builds
            # it, keeping the quota-free simulator bit-identical)
            usage: Optional[Dict[str, int]] = None
            if self.scheduler.quotas:
                usage = {}
                for rec in self.running.values():
                    usage[rec.job.tenant] = (
                        usage.get(rec.job.tenant, 0) + rec.job.size)
            for job in list(self.scheduler.candidates(self.queue,
                                                      usage=usage)):
                res = self.mode.try_place(job, self.cluster)
                if isinstance(res, Placement):
                    self.queue.remove(job)
                    self._note_frag_end(job)
                    self._start(job, res)
                    placed_any = True
                    break           # re-evaluate candidates from the top
                if isinstance(res, ReconfigPlan):
                    self.queue.remove(job)
                    self._note_frag_end(job)
                    self._start_reconfig(res)
                    placed_any = True
                    break
                if idle_slices is None:
                    idle_slices = self._idle_slice_sum()
                self._note_frag(job, idle_slices)
                if self.scheduler.policy == "fifo":
                    break

    def _idle_slice_sum(self) -> int:
        # cluster-cached totals: the per-instance scan here was charged
        # once per blocked scheduling pass — O(events x leaves) overall
        idle = self.cluster.idle_sm_slices()
        if self.mode.name == "DM":
            idle += self.cluster.free_compute_total()
        return idle

    def _note_frag(self, job: Job, idle_slices: int) -> None:
        """External-fragmentation bookkeeping: enough idle capacity in
        total, but no placement (I2)."""
        blocked_with_capacity = idle_slices >= job.size
        if blocked_with_capacity and job.job_id not in self.frag_since:
            self.frag_since[job.job_id] = self.now
        elif not blocked_with_capacity and job.job_id in self.frag_since:
            self._note_frag_end(job)

    def _note_frag_end(self, job: Job) -> None:
        t0 = self.frag_since.pop(job.job_id, None)
        if t0 is not None:
            self.ext_frag[job.job_id] = (self.ext_frag.get(job.job_id, 0.0)
                                         + (self.now - t0))

    def _jct(self, job: Job, placement: Placement) -> float:
        if placement.one_to_one:
            inst = placement.instances[0]
            view = jct_model.PlacementView(
                (inst.profile,), (1,), "NONE",
                sm_slices=PROFILES[inst.profile].sm_slices)
        else:
            view = jct_model.PlacementView(
                placement.instance_types(), placement.leaves_per_gpu(),
                placement.transport,
                concurrent_net_jobs=self._net_running + 1)
        scale = jct_model.jct_scale(job.model, job.batch, job.size, view,
                                    train=job.train)
        base = job.base_duration * scale
        concurrent = bool(self.running)
        if self.ground_truth:
            return jct_model.interference_ground_truth(
                base, concurrent=concurrent, rng=self.rng)
        return jct_model.calibrated(base, concurrent=concurrent,
                                    calibrate=self.calibrate)

    def _start(self, job: Job, placement: Placement) -> None:
        if job.start_time is None:    # set-once: restarts keep the
            job.start_time = self.now  # original wait-time accounting
        if self._first_start is None:
            self._first_start = self.now
        # a restarted job reruns only its unsaved remainder; the restart
        # charge (restore + recompile, priced at failure time) is paid
        # now, when the job actually reoccupies resources
        work = self._jct(job, placement) * job.remaining_frac
        recovery = job.pending_recovery_s
        if recovery:
            self.n_recoveries += 1
            self.failure_restart_cost_s += recovery
            job.suspended_overhead += recovery
            job.pending_recovery_s = 0.0
        version = self._finish_versions.get(job.job_id, 0)
        rec = _Running(job, placement, finish_version=version,
                       finish_at=self.now + work + recovery,
                       seg_start=self.now, seg_work=work,
                       seg_overhead=recovery, seg_frac=job.remaining_frac)
        self.running[job.job_id] = rec
        self._busy_slices += sum(PROFILES[i.profile].sm_slices
                                 for i in placement.instances)
        if placement.transport == "NET":
            self._net_running += 1
        self._update_frag(placement)
        self._push(rec.finish_at, "finish", (job.job_id, version))

    def _finish(self, rec: _Running) -> None:
        job = rec.job
        job.finish_time = self.now
        self._last_finish = max(self._last_finish, self.now)
        self._busy_slices -= sum(PROFILES[i.profile].sm_slices
                                 for i in rec.placement.instances)
        if rec.placement.transport == "NET":
            self._net_running -= 1
        self.mode.release(rec.placement, self.cluster)
        self._update_frag(rec.placement)
        del self.running[job.job_id]

    # ------------------------------------------------------ reconfig (DM)
    def _start_reconfig(self, plan: ReconfigPlan) -> None:
        cm = self.reconfig_cost
        handoff = cm.mode == "handoff"
        self.n_reconfigs += 1
        if plan.affected_jobs:
            if handoff:
                self.n_handoffs += 1
            else:
                self.n_drains += 1
        gpu = self.cluster.gpus[(plan.host_id, plan.gpu_id)]
        gpu.draining = True
        # suspend affected jobs: push their finish events out by what the
        # cost model charges — the full drain duration under the
        # incumbent model, the (calibrated, measured) sharded
        # save + reshard-restore + recompile under the paper's handoff
        charged_total = 0.0
        for job_id in plan.affected_jobs:
            rec = self.running.get(job_id)
            if rec is None:
                continue
            remaining = self._remaining_until_finish(rec)
            n_ranks = max(rec.job.size, 1)
            charged = cm.job_suspension_s(
                jct_model.ckpt_state_bytes(rec.job.model),
                drain_s=plan.duration,
                n_ranks_old=n_ranks, n_ranks_new=n_ranks)
            charged_total += charged
            rec.finish_version += 1
            self._finish_versions[job_id] = rec.finish_version
            rec.job.suspended_overhead += charged
            rec.seg_overhead += charged
            rec.finish_at = self.now + remaining + charged
            self._push(rec.finish_at, "finish",
                       (job_id, rec.finish_version))
        if handoff:
            self.handoff_cost_s += charged_total
        else:
            self.drain_cost_s += charged_total
        kind = ("reshape" if not plan.affected_jobs
                else "handoff" if handoff else "drain")
        self.reconfig_records.append(ReconfigRecord(
            t=self.now, kind=kind, n_affected=len(plan.affected_jobs),
            charged_s=charged_total, gpu=(plan.host_id, plan.gpu_id)))
        done_in = cm.geometry_s(base_s=plan.base_duration,
                                drain_s=plan.duration)
        self._push(self.now + done_in, "reconfig_done", plan)

    def _remaining_until_finish(self, rec: _Running) -> float:
        """Time left on the currently-live finish event of ``rec``.

        O(1): ``finish_at`` mirrors the live (version-matching) finish
        event — stale events from earlier drains are superseded, never
        removed, so scanning the heap for it was O(events) per drained
        job."""
        return max(0.0, rec.finish_at - self.now)

    def _reconfig_done(self, plan: ReconfigPlan) -> None:
        gpu = self.cluster.gpus[(plan.host_id, plan.gpu_id)]
        gpu.draining = False
        assert isinstance(self.mode, DynamicMIG)
        placement = self.mode.apply_reconfig(plan, self.cluster)
        self._start(plan.job, placement)

    # ----------------------------------------------------- host failures
    def _schedule_next_failure(self) -> None:
        fm = self.failure_model
        if fm is None or self._failures_scheduled >= fm.max_failures:
            return
        self._failures_scheduled += 1
        dt = float(self.failure_rng.exponential(fm.mtbf_s))
        self._push(self.now + dt, "failure", None)

    def _host_failure(self) -> None:
        """One MTBF arrival: kill every placement on a random busy host.

        Each killed job loses its work since the last periodic
        checkpoint (``ckpt_interval_s`` cadence within the segment),
        carries a restart charge priced by the reconfig cost model's
        ``failure_restart_s`` (drain: the incumbent reload constant;
        handoff: the survivors' reshard-restore + recompile, capped at
        the drain figure), and goes back to the queue.  The host's
        resources return to the pool immediately — the model charges
        the *jobs* for the failure, not the hardware's repair time.
        """
        fm = self.failure_model
        if fm is None:
            return
        if any(j.finish_time is None for j in self.jobs.values()):
            self._schedule_next_failure()
        hosts = sorted({i.host_id for rec in self.running.values()
                        for i in rec.placement.instances})
        if not hosts:
            return                   # nothing running: harmless strike
        victim_host = hosts[int(self.failure_rng.integers(len(hosts)))]
        victims = [rec for rec in self.running.values()
                   if any(i.host_id == victim_host
                          for i in rec.placement.instances)]
        if not victims:
            return
        self.n_failures += 1
        cm = self.reconfig_cost
        drain_restart = CKPT_LOAD_S + POD_CHURN_S
        for rec in victims:
            job = rec.job
            # work completed this segment, net of suspension charges
            # that extended finish_at without advancing the job
            elapsed = self.now - rec.seg_start
            done = min(max(elapsed - rec.seg_overhead, 0.0),
                       rec.seg_work)
            saved = (done // fm.ckpt_interval_s) * fm.ckpt_interval_s
            lost = done - saved
            self.failure_lost_work_s += lost
            if rec.seg_work > 0:
                job.remaining_frac = rec.seg_frac * (
                    1.0 - saved / rec.seg_work)
            job.n_failures += 1
            # how many ranks reshard-restore concurrently: repack the
            # job's leaves around the dead host (the runtime's
            # elastic.repack_on_failure policy); no viable repack means
            # a full same-width restart once resources free up
            from repro.elastic import repack_on_failure
            leaves, chip = [], {}
            for i in rec.placement.instances:
                k = chip.get((i.host_id, i.gpu_id), 0)
                chip[(i.host_id, i.gpu_id)] = k + 1
                leaves.append(TpuLeaf(pod=i.host_id, host=i.gpu_id,
                                      chip=k))
            failed = sorted({(i.host_id, i.gpu_id)
                             for i in rec.placement.instances
                             if i.host_id == victim_host})
            plan = repack_on_failure(leaves, failed, model_parallel=1)
            n_ranks_new = (int(np.prod(plan.mesh_shape))
                           if plan is not None else max(job.size, 1))
            job.pending_recovery_s = cm.failure_restart_s(
                jct_model.ckpt_state_bytes(job.model),
                drain_restart_s=drain_restart,
                n_ranks_new=max(n_ranks_new, 1))
            # invalidate the live finish event and release the placement
            rec.finish_version += 1
            self._finish_versions[job.job_id] = rec.finish_version
            self._busy_slices -= sum(PROFILES[i.profile].sm_slices
                                     for i in rec.placement.instances)
            if rec.placement.transport == "NET":
                self._net_running -= 1
            self.mode.release(rec.placement, self.cluster)
            self._update_frag(rec.placement)
            del self.running[job.job_id]
            self.queue.push(job)

    # ------------------------------------------------------------ result
    def _result(self) -> SimResult:
        done = [j for j in self.jobs.values() if j.finish_time is not None]
        jcts = {j.job_id: j.finish_time - j.start_time for j in done}
        # goodput: of all job-seconds between start and finish, the
        # fraction that was neither suspension/restart overhead nor
        # work redone after a failure (1.0 on an overhead-free run)
        busy = sum(jcts.values())
        wasted = (sum(j.suspended_overhead for j in done)
                  + self.failure_lost_work_s)
        goodput = (max(0.0, busy - wasted) / busy) if busy > 0 else 1.0
        waits = {j.job_id: j.start_time - j.submit_time for j in done}
        t0 = self._first_start or 0.0
        makespan = self._last_finish - min(
            (j.submit_time for j in self.jobs.values()), default=0.0)
        total_slices = (len(self.cluster.gpus) * N_COMPUTE_SLICES)
        util_span = max(self._last_finish - t0, 1e-9)
        util = self._busy_integral / (total_slices * util_span)
        frag = list(self.ext_frag.values())
        return SimResult(
            mode=self.mode.name,
            makespan=makespan,
            avg_jct=float(np.mean(list(jcts.values()))) if jcts else 0.0,
            avg_wait=float(np.mean(list(waits.values()))) if waits else 0.0,
            avg_ext_frag_delay=float(np.mean(frag)) if frag else 0.0,
            utilization=util,
            n_reconfigs=self.n_reconfigs,
            n_drains=self.n_drains,
            n_jobs=len(done),
            jct_by_job=jcts,
            wait_by_job=waits,
            n_handoffs=self.n_handoffs,
            drain_cost_s=self.drain_cost_s,
            handoff_cost_s=self.handoff_cost_s,
            reconfig_events=list(self.reconfig_records),
            n_failures=self.n_failures,
            n_recoveries=self.n_recoveries,
            failure_lost_work_s=self.failure_lost_work_s,
            failure_restart_cost_s=self.failure_restart_cost_s,
            goodput=goodput,
            n_events=self.n_events,
            frag_slice_seconds=self._frag_integral,
            avg_frag_slices=self._frag_integral / util_span,
        )


def simulate(jobs: List[Job], mode_name: str, *, n_hosts: int = 1,
             gpus_per_host: int = 2, policy: str = "fifo",
             backfill_depth: int = 14, calibrate: bool = True,
             ground_truth: bool = False, seed: int = 0,
             round_robin: bool = True, placement: str = "default",
             reconfig_mode: Optional[str] = None,
             reconfig_cost: Optional[jct_model.ReconfigCostModel] = None,
             failure_model: Optional[FailureModel] = None,
             tenant_quotas: Optional[Dict[str, int]] = None
             ) -> SimResult:
    """Replay ``jobs`` under operation mode ``mode_name``.

    ``reconfig_mode='handoff'`` prices geometry changes with the paper's
    software-coordinated handoff instead of the drain-required cycle
    (``reconfig_cost`` supplies a calibrated
    :class:`~repro.core.jct_model.ReconfigCostModel`, e.g. built from
    ``BENCH_elastic.json`` measurements; without one the default
    calibration is used).  The cost model's own mode governs the
    charging, so passing *both* arguments with disagreeing modes is an
    error rather than a silently mislabeled replay.  The default (no
    mode, no cost model) is the incumbent drain behavior, bit-identical
    to the pre-cost-model simulator.

    ``failure_model`` arms seeded MTBF host failures (see
    :class:`FailureModel`); without one the run is bit-identical to the
    failure-free simulator — the failure plane is strictly opt-in.

    ``tenant_quotas`` maps tenant -> max concurrently-held devices; a
    job whose tenant is at quota waits even when resources are free.
    Strictly opt-in like the failure plane: ``None`` (the default)
    never computes usage and replays bit-identically.

    ``placement`` selects the FM host/leaf scoring: ``"default"`` (the
    paper's most-idle + round-robin policy) or ``"frag_aware"``
    (minimum-stranded-fragmentation placement, the bake-off
    challenger).  Ignored by DM/SM, whose one-to-one model has no
    placement freedom beyond the profile rules.
    """
    import copy
    # per-job shallow copies: Job holds only immutable scalar fields, so
    # this is equivalent to the deepcopy it replaces at a fraction of
    # the cost on million-job traces (deepcopy was ~2% of a fleet run)
    jobs = [copy.copy(j) for j in jobs]
    kw: Dict[str, object] = {}
    if mode_name == "FM":
        kw = {"round_robin": round_robin, "placement": placement}
    elif placement != "default":
        raise ValueError(
            f"placement={placement!r} only applies to FM; {mode_name} "
            f"has no placement freedom")
    if reconfig_cost is None:
        reconfig_cost = jct_model.ReconfigCostModel(
            mode=reconfig_mode or "drain")
    elif reconfig_mode is not None and reconfig_cost.mode != reconfig_mode:
        raise ValueError(
            f"reconfig_mode={reconfig_mode!r} conflicts with the given "
            f"cost model's mode={reconfig_cost.mode!r}")
    sim = Simulation(jobs, make_mode(mode_name, **kw),
                     n_hosts=n_hosts, gpus_per_host=gpus_per_host,
                     scheduler=Scheduler(policy, depth=backfill_depth,
                                         quotas=tenant_quotas),
                     calibrate=calibrate, ground_truth=ground_truth,
                     reconfig_cost=reconfig_cost,
                     failure_model=failure_model, seed=seed)
    return sim.run()
