"""Executable model of the paper's NCCL modifications (§4.2).

NCCL identifies GPUs by PCIe bus ID.  All MIG instances of one GPU share a
bus ID, so stock peer discovery (a) aborts on a false duplicate-GPU check
and (b) collapses distinct instances into one topology node.  Flex-MIG fixes
this with (1) a ``mig_id`` field in peer metadata compared during dedup, and
(2) *synthetic bus-ID labeling* during topology construction
(``00:4B:00.0 -> 00:4B:00.1``) with a restoration routine stripping the
suffix before any driver call.

We reproduce the failing logic and both fixes verbatim over simulated rank
metadata; tests assert the stock path fails exactly the way the paper
describes and the fixed path yields communicator == ranks.  The *effect* of
the fix (fast-path collectives between same-host leaves) is implemented
natively in ``repro.collectives.hierarchical``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


class DuplicateGpuError(RuntimeError):
    """NCCL 'Duplicate GPU detected' abort (paper §2.5, failure point 1)."""


class TopologyMismatchError(RuntimeError):
    """Topology has fewer devices than ranks (failure point 2)."""


class InvalidBusIdError(RuntimeError):
    """A synthetic bus ID leaked to a driver call without restoration."""


@dataclasses.dataclass(frozen=True)
class PeerInfo:
    """Rank metadata exchanged during NCCL bootstrap (paper Fig. 5)."""
    rank: int
    device_id: int
    host_hash: int
    pid_hash: int
    pcie_bus_id: str
    mig_id: Optional[str] = None    # the Flex-MIG addition (NCCL_MIG_ID)


def env_to_peer(rank: int, env: Dict[str, str], *, host_hash: int,
                pid_hash: int, pcie_bus_id: str) -> PeerInfo:
    """Runtime-layer env plumbing (§4.2): NVIDIA_VISIBLE_DEVICES ->
    CUDA_VISIBLE_DEVICES + NCCL_MIG_ID -> peer metadata."""
    mig_uuid = env.get("NVIDIA_VISIBLE_DEVICES")
    derived = dict(env)
    if mig_uuid:
        derived["CUDA_VISIBLE_DEVICES"] = mig_uuid
        derived["NCCL_MIG_ID"] = mig_uuid
    return PeerInfo(rank=rank, device_id=0, host_hash=host_hash,
                    pid_hash=pid_hash, pcie_bus_id=pcie_bus_id,
                    mig_id=derived.get("NCCL_MIG_ID"))


# ---------------------------------------------------------------------------
# peer discovery (failure point 1 + fix 1)
# ---------------------------------------------------------------------------

def peer_discovery(peers: List[PeerInfo], *, mig_aware: bool) -> None:
    """NCCL duplicate-GPU check during rank info exchange.

    Stock NCCL (mig_aware=False): two ranks on the same host with the same
    bus ID are classified as double-binding one GPU -> abort.
    Flex-MIG (mig_aware=True): additionally compare ``mig_id``; identical
    (host, bus_id) with different mig_id is legal.  Double-binding the
    *same* instance is still detected (mig_id equal).
    """
    seen: Dict[Tuple[int, str], PeerInfo] = {}
    for p in peers:
        key = (p.host_hash, p.pcie_bus_id)
        if key in seen:
            other = seen[key]
            if not mig_aware:
                raise DuplicateGpuError(
                    f"Duplicate GPU detected: rank {p.rank} and rank "
                    f"{other.rank} both report busId {p.pcie_bus_id}")
            if p.mig_id is None or other.mig_id is None \
                    or p.mig_id == other.mig_id:
                raise DuplicateGpuError(
                    f"rank {p.rank} and rank {other.rank} bind the same "
                    f"MIG instance {p.mig_id}")
            # distinct mig_id: same physical GPU, different instances - OK
        else:
            seen[key] = p


# ---------------------------------------------------------------------------
# topology construction (failure point 2 + fix 2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TopoNode:
    label: str                     # (possibly synthetic) bus id
    rank: int
    host_hash: int


SYNTH_SEP = "."


def _with_suffix(bus_id: str, count: int) -> str:
    # "00:4B:00.0" -> "00:4B:00.<count>"  (paper's example transformation)
    base, _, _fn = bus_id.rpartition(SYNTH_SEP)
    return f"{base}{SYNTH_SEP}{count}"


def restore_bus_id(label: str) -> str:
    """Restoration routine: strip synthetic suffix before driver use."""
    base, _, fn = label.rpartition(SYNTH_SEP)
    if fn != "0":
        return f"{base}{SYNTH_SEP}0"
    return label


def is_synthetic(label: str) -> bool:
    return label.rpartition(SYNTH_SEP)[2] != "0"


def driver_call_guard(label: str) -> str:
    """Any path passing a bus id to the driver goes through here."""
    restored = restore_bus_id(label)
    if is_synthetic(restored):
        raise InvalidBusIdError(f"synthetic bus id leaked: {label}")
    return restored


def build_topology(peers: List[PeerInfo], *,
                   synthetic_labeling: bool) -> List[TopoNode]:
    """NCCL system-topology registration.

    Stock (synthetic_labeling=False): devices registered incrementally; a
    bus ID already present is *deduplicated* -> distinct MIG instances
    collapse into one node and node count < ranks.
    Flex-MIG: keep a (bus_id -> count) ``mig_list``; re-registrations get a
    synthetic suffix so each rank becomes a unique node.
    """
    nodes: List[TopoNode] = []
    mig_list: Dict[Tuple[int, str], int] = {}
    for p in peers:
        key = (p.host_hash, p.pcie_bus_id)
        if key not in mig_list:
            mig_list[key] = 0
            nodes.append(TopoNode(label=p.pcie_bus_id, rank=p.rank,
                                  host_hash=p.host_hash))
        else:
            if not synthetic_labeling:
                continue           # stock NCCL: silently deduplicated
            mig_list[key] += 1
            nodes.append(TopoNode(
                label=_with_suffix(p.pcie_bus_id, mig_list[key]),
                rank=p.rank, host_hash=p.host_hash))
    return nodes


def form_communicator(peers: List[PeerInfo], *, mig_aware: bool,
                      synthetic_labeling: bool) -> List[TopoNode]:
    """Full bootstrap: peer discovery then topology; returns topo nodes.

    Raises the same class of failures the paper observes when run without
    the Flex-MIG modifications.
    """
    peer_discovery(peers, mig_aware=mig_aware)
    nodes = build_topology(peers, synthetic_labeling=synthetic_labeling)
    if len(nodes) != len(peers):
        raise TopologyMismatchError(
            f"topology has {len(nodes)} devices for {len(peers)} ranks "
            f"(MIG instances collapsed)")
    # every node label must round-trip the driver guard
    for n in nodes:
        driver_call_guard(n.label)
    return nodes


def select_transport(a: PeerInfo, b: PeerInfo) -> str:
    """NCCL transport selection under MIG (§2.5): no P2P/NVLink across MIG;
    same host -> SHM, different host -> NET."""
    if a.host_hash == b.host_hash:
        return "SHM"
    return "NET"
