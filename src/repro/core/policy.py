"""Flex-MIG instance-selection policy (§3.2) + fragmentation-aware
placement scoring (the online frag-aware MIG schedulers, arXiv
2512.16099 / 2511.18906).

Two Flex-MIG heuristics:
1. *Size-aware instance prioritization* — ``1g.10gb`` for size-1 jobs
   (10-30% JCT win), ``1g.5gb`` for size>=2 (sync caps at the slowest leaf,
   so the bigger-memory leaf is wasted there).
2. *Topology-aware placement* — round-robin leaves across physical GPUs of
   the host (uneven packing saturates a single GPU's PCIe interface, Fig 9).

Fragmentation-aware scoring (the bake-off challengers): score each
candidate placement by the idle-leaf *fragments it strands* against a
job-size demand distribution, and pick the minimum-fragmentation
feasible candidate.  Following the FGD-style measure both cited
schedulers build on, a host left with ``idle`` free leaves strands all
of them with respect to any demanded size ``s > idle`` (an ``s``-job
cannot use that host at all), so the host's fragmentation is the
demand-weighted expectation

    F(idle) = idle * P[demand size > idle]        (:func:`stranded_frag`)

— zero for an exact-fit placement (``idle == 0``) and monotone under
pointwise dominance of the per-size stranded counts.
:func:`frag_aware_choose_host` is the exact argmin of F over feasible
hosts; :func:`frag_aware_select_instances` applies the same idea at
leaf/GPU granularity (consume already-fragmented GPUs before breaking
pristine ones).

The cluster-runtime half (:mod:`repro.cluster`) reuses the same
ideas at host granularity: :func:`cluster_placement` maps a job's
priority tier to a device-pool placement strategy (optionally the
frag-aware one), and :func:`defrag_victims` orders which running jobs a
fragmentation-driven repack may move.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.job import TIER_HIGH, Job
from repro.core.leaves import Cluster, Instance

# Canonical job-size demand distribution for the fragmentation measure:
# Table-2 "balanced" train+infer mix (sizes 1..8 with the paper's
# balanced per-size job counts), normalized.  Callers may pass their own
# ``demand`` (e.g. measured from the live queue); every scoring function
# threads it through.
DEFAULT_FRAG_DEMAND: Tuple[Tuple[int, float], ...] = (
    (1, 18 / 62), (2, 18 / 62), (4, 18 / 62), (6, 4 / 62), (8, 4 / 62))


def size_aware_priority(size: int) -> List[str]:
    """Preferred instance types, best first."""
    if size == 1:
        return ["1g.10gb", "1g.5gb"]
    return ["1g.5gb", "1g.10gb"]


def select_instances(cluster: Cluster, host: int, size: int,
                     *, round_robin: bool = True) -> Optional[List[Instance]]:
    """Pick ``size`` idle leaves on ``host`` under the §3.2 policy.

    Returns None if the host lacks idle leaves.  ``round_robin=False``
    reproduces the naive pack-one-GPU-first policy (Fig. 9 ablation).
    """
    prefs = size_aware_priority(size)
    # idle leaves per gpu, preferred types first within a gpu
    per_gpu: List[List[Instance]] = []
    for gpu in cluster.host_gpus(host):
        idle = [i for i in gpu.instances if not i.busy
                and i.profile in prefs]
        idle.sort(key=lambda i: prefs.index(i.profile))
        per_gpu.append(idle)

    total_idle = sum(len(g) for g in per_gpu)
    if total_idle < size:
        return None

    chosen: List[Instance] = []
    if round_robin:
        # breadth-first across GPUs -> most even leaves_per_gpu split
        cursors = [0] * len(per_gpu)
        while len(chosen) < size:
            progressed = False
            for g, idle in enumerate(per_gpu):
                if len(chosen) == size:
                    break
                if cursors[g] < len(idle):
                    chosen.append(idle[cursors[g]])
                    cursors[g] += 1
                    progressed = True
            if not progressed:
                return None
        if size == 1:
            # size-aware prioritization dominates placement for size 1
            all_idle = [i for g in per_gpu for i in g]
            all_idle.sort(key=lambda i: prefs.index(i.profile))
            chosen = [all_idle[0]]
    else:
        for idle in per_gpu:
            for inst in idle:
                if len(chosen) == size:
                    break
                chosen.append(inst)
    return chosen if len(chosen) == size else None


def choose_host(cluster: Cluster, size: int) -> Optional[int]:
    """Pick the host with the most idle leaves that can fit the job.

    Tie-breaking is explicitly deterministic: among hosts with equal
    idle-leaf counts the LOWEST host id wins (strict ``>`` keeps the
    first maximum).  The golden bake-off tables key on this ordering —
    changing it silently re-keys every (policy, trace) row.

    Uses the cluster's O(hosts) cached idle counts; the old per-host
    ``idle_instances`` scan was O(hosts^2 x leaves) per placement
    attempt, the second-largest superlinear term on fleet traces.
    """
    best, best_idle = None, -1
    for h, idle in enumerate(cluster.idle_leaf_counts()):
        if idle >= size and idle > best_idle:
            best, best_idle = h, idle
    return best


# ---------------------------------------------------------------------------
# fragmentation-aware scoring (arXiv 2512.16099 / 2511.18906 bake-off)
# ---------------------------------------------------------------------------

def stranded_frag(idle: int,
                  demand: Sequence[Tuple[int, float]] = DEFAULT_FRAG_DEMAND
                  ) -> float:
    """Demand-weighted stranded idle leaves of a host left with ``idle``
    free leaves: ``idle * P[demand size > idle]``.

    Per demanded size ``s``, all ``idle`` leaves are stranded when
    ``idle < s`` (an ``s``-job cannot run there), none otherwise; the
    score is the demand-probability-weighted sum of those per-size
    stranded counts.  Zero at ``idle == 0`` (exact fit) and monotone
    under pointwise dominance: if placement A strands at least as many
    leaves as B for every demanded size, ``F(A) >= F(B)``.
    """
    if idle < 0:
        raise ValueError(f"idle leaf count must be >= 0, got {idle}")
    return idle * sum(p for s, p in demand if idle < s)


def frag_score_host(cluster: Cluster, host: int, size: int,
                    demand: Sequence[Tuple[int, float]]
                    = DEFAULT_FRAG_DEMAND) -> float:
    """Fragmentation the candidate assignment (``size`` leaves on
    ``host``) would strand: the host's post-placement F(idle)."""
    return stranded_frag(cluster.idle_leaf_count(host) - size, demand)


def cluster_frag(cluster: Cluster,
                 demand: Sequence[Tuple[int, float]] = DEFAULT_FRAG_DEMAND
                 ) -> float:
    """Total stranded fragmentation across hosts (the simulator's
    frag-integral metric samples this)."""
    return sum(stranded_frag(idle, demand)
               for idle in cluster.idle_leaf_counts())


def frag_aware_choose_host(cluster: Cluster, size: int,
                           demand: Sequence[Tuple[int, float]]
                           = DEFAULT_FRAG_DEMAND) -> Optional[int]:
    """Minimum-fragmentation feasible host: the exact argmin of
    post-placement F over hosts with ``idle >= size``.

    Deterministic tie-breaking, in order: (1) lowest post-placement
    fragmentation; (2) fewest leftover idle leaves (tightest fit — two
    idle counts can score identically, e.g. both above the largest
    demanded size); (3) lowest host id.  Documented because the golden
    tables bake this ordering in.
    """
    best: Optional[int] = None
    best_key: Optional[Tuple[float, int]] = None
    for h, idle in enumerate(cluster.idle_leaf_counts()):
        if idle < size:
            continue
        key = (stranded_frag(idle - size, demand), idle - size)
        if best_key is None or key < best_key:
            best, best_key = h, key
    return best


def frag_aware_select_instances(cluster: Cluster, host: int, size: int
                                ) -> Optional[List[Instance]]:
    """Leaf-granularity fragmentation-aware selection on ``host``.

    GPU-level analogue of the host score: idle leaves on a *partially
    busy* GPU are stranded fragments (they can never again be part of a
    whole-GPU block), so the policy consumes already-fragmented GPUs
    first — ascending idle count (tightest fit first), pristine
    fully-idle GPUs last, lowest gpu id on ties — leaving as many
    pristine GPUs intact as the job size allows.  Within a GPU, leaves
    follow the same size-aware profile preference as the default
    policy.  Returns None if the host lacks idle leaves.
    """
    prefs = size_aware_priority(size)
    gpus = []
    for gpu in cluster.host_gpus(host):
        idle = [i for i in gpu.instances if not i.busy
                and i.profile in prefs]
        idle.sort(key=lambda i: prefs.index(i.profile))
        if idle:
            gpus.append((bool(gpu.has_running_jobs()), len(idle),
                         gpu.gpu_id, idle))
    if sum(g[1] for g in gpus) < size:
        return None
    # fragmented (partially busy) GPUs first, tightest first, id-stable
    gpus.sort(key=lambda g: (not g[0], g[1], g[2]))
    chosen: List[Instance] = []
    for _, _, _, idle in gpus:
        for inst in idle:
            if len(chosen) == size:
                return chosen
            chosen.append(inst)
    return chosen if len(chosen) == size else None


# ---------------------------------------------------------------------------
# cluster-runtime placement policy (host-level analogue of the above)
# ---------------------------------------------------------------------------

def cluster_placement(priority_tier: int, size: int,
                      devices_per_host: int, *,
                      frag_aware: bool = False
                      ) -> Tuple[str, Optional[int]]:
    """Device-pool placement for one cluster job: ``(strategy,
    required host span)``.

    - Tier-0 (high/SLA) jobs that fit on one host are *pinned* to a
      single host (span 1): single-host transport is the latency tier
      they pay for, so a cross-host placement is not an acceptable
      fallback — they queue (and force a defrag repack) instead.
      Frag-aware mode keeps the pin but scores WHICH host by stranded
      fragments (``frag_aware`` strategy at span 1).
    - Everyone else spreads round-robin across hosts (the Fig.-9
      balanced default: widest equal per-host split) — or, frag-aware,
      takes the minimum-stranding feasible span/host combination
      (:meth:`repro.cluster.pool.DevicePool.plan` scoring).
    """
    if priority_tier == TIER_HIGH and size <= devices_per_host:
        return ("frag_aware" if frag_aware else "packed"), 1
    if frag_aware:
        return "frag_aware", None
    return "round_robin", None


def defrag_victims(running: Sequence[Job], requester: Job) -> List[Job]:
    """Which running jobs a defrag repack may move to admit
    ``requester``, best victim first.

    Only jobs at the requester's priority tier or below are movable (a
    repack must never perturb a *higher*-priority tenant on behalf of a
    lower one); among those, lowest priority first, then smallest state
    (size) — the cheapest checkpoint/restore cycle.

    Tie-breaking is explicitly deterministic: the sort is stable and
    keyed only on ``(-priority_tier, size)``, so jobs with equal keys
    keep the exact order of the ``running`` sequence the caller passed.
    The cluster runtime passes its insertion-ordered running ledger
    (admission order), which is itself deterministic — NOT an arbitrary
    set/dict order.  Golden tables and the repack tests pin this: a
    final ``job_id`` tie-break would look safer but would silently
    re-order equal victims admitted under non-lexicographic ids.
    """
    eligible = [j for j in running
                if j.priority_tier >= requester.priority_tier]
    return sorted(eligible, key=lambda j: (-j.priority_tier, j.size))
