"""Flex-MIG instance-selection policy (§3.2).

Two heuristics:
1. *Size-aware instance prioritization* — ``1g.10gb`` for size-1 jobs
   (10-30% JCT win), ``1g.5gb`` for size>=2 (sync caps at the slowest leaf,
   so the bigger-memory leaf is wasted there).
2. *Topology-aware placement* — round-robin leaves across physical GPUs of
   the host (uneven packing saturates a single GPU's PCIe interface, Fig 9).

The cluster-runtime half (:mod:`repro.cluster`) reuses the same two
ideas at host granularity: :func:`cluster_placement` maps a job's
priority tier to a device-pool placement strategy, and
:func:`defrag_victims` orders which running jobs a fragmentation-driven
repack may move.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.job import TIER_HIGH, Job
from repro.core.leaves import Cluster, Instance


def size_aware_priority(size: int) -> List[str]:
    """Preferred instance types, best first."""
    if size == 1:
        return ["1g.10gb", "1g.5gb"]
    return ["1g.5gb", "1g.10gb"]


def select_instances(cluster: Cluster, host: int, size: int,
                     *, round_robin: bool = True) -> Optional[List[Instance]]:
    """Pick ``size`` idle leaves on ``host`` under the §3.2 policy.

    Returns None if the host lacks idle leaves.  ``round_robin=False``
    reproduces the naive pack-one-GPU-first policy (Fig. 9 ablation).
    """
    prefs = size_aware_priority(size)
    # idle leaves per gpu, preferred types first within a gpu
    per_gpu: List[List[Instance]] = []
    for gpu in cluster.host_gpus(host):
        idle = [i for i in gpu.instances if not i.busy
                and i.profile in prefs]
        idle.sort(key=lambda i: prefs.index(i.profile))
        per_gpu.append(idle)

    total_idle = sum(len(g) for g in per_gpu)
    if total_idle < size:
        return None

    chosen: List[Instance] = []
    if round_robin:
        # breadth-first across GPUs -> most even leaves_per_gpu split
        cursors = [0] * len(per_gpu)
        while len(chosen) < size:
            progressed = False
            for g, idle in enumerate(per_gpu):
                if len(chosen) == size:
                    break
                if cursors[g] < len(idle):
                    chosen.append(idle[cursors[g]])
                    cursors[g] += 1
                    progressed = True
            if not progressed:
                return None
        if size == 1:
            # size-aware prioritization dominates placement for size 1
            all_idle = [i for g in per_gpu for i in g]
            all_idle.sort(key=lambda i: prefs.index(i.profile))
            chosen = [all_idle[0]]
    else:
        for idle in per_gpu:
            for inst in idle:
                if len(chosen) == size:
                    break
                chosen.append(inst)
    return chosen if len(chosen) == size else None


def choose_host(cluster: Cluster, size: int) -> Optional[int]:
    """Pick the host with the most idle leaves that can fit the job."""
    best, best_idle = None, -1
    for h in range(cluster.n_hosts):
        idle = len(cluster.idle_instances(host=h))
        if idle >= size and idle > best_idle:
            best, best_idle = h, idle
    return best


# ---------------------------------------------------------------------------
# cluster-runtime placement policy (host-level analogue of the above)
# ---------------------------------------------------------------------------

def cluster_placement(priority_tier: int, size: int,
                      devices_per_host: int
                      ) -> Tuple[str, Optional[int]]:
    """Device-pool placement for one cluster job: ``(strategy,
    required host span)``.

    - Tier-0 (high/SLA) jobs that fit on one host are *pinned* to a
      single host (span 1): single-host transport is the latency tier
      they pay for, so a cross-host placement is not an acceptable
      fallback — they queue (and force a defrag repack) instead.
    - Everyone else spreads round-robin across hosts (the Fig.-9
      balanced default: widest equal per-host split).
    """
    if priority_tier == TIER_HIGH and size <= devices_per_host:
        return "packed", 1
    return "round_robin", None


def defrag_victims(running: Sequence[Job], requester: Job) -> List[Job]:
    """Which running jobs a defrag repack may move to admit
    ``requester``, best victim first.

    Only jobs at the requester's priority tier or below are movable (a
    repack must never perturb a *higher*-priority tenant on behalf of a
    lower one); among those, lowest priority first, then smallest state
    (size) — the cheapest checkpoint/restore cycle.  Stable, so equal
    candidates keep arrival order.
    """
    eligible = [j for j in running
                if j.priority_tier >= requester.priority_tier]
    return sorted(eligible, key=lambda j: (-j.priority_tier, j.size))
