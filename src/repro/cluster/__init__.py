"""Multi-tenant elastic cluster runtime — co-scheduled jobs on one
shared device pool.

Layers (bottom up):

- :mod:`repro.cluster.pool` — the :class:`DevicePool` ledger: disjoint
  per-job device subsets, geometry-valid placements, fragmentation and
  defrag planning;
- :mod:`repro.cluster.worker` — the per-segment subprocess entry point
  (one :class:`~repro.elastic_driver.ElasticDriver` segment per child);
- :mod:`repro.cluster.manager` — :class:`JobManager`, one job's segment
  subprocess lifecycle (launch/poll/crash bookkeeping);
- :mod:`repro.cluster.runtime` — :class:`ClusterRuntime`, the
  scheduler-driven co-scheduling loop (quotas, priority tiers, defrag
  and rebalance repacks, crash-restart, handoff-cost measurement).
"""
from repro.cluster.manager import (ClusterJobSpec, JobManager,
                                   SegmentResult)
from repro.cluster.pool import (Allocation, DefragMove, DevicePool,
                                PoolError)
from repro.cluster.runtime import (ClusterError, ClusterJobOutcome,
                                   ClusterRunResult, ClusterRuntime,
                                   RepackEvent)

__all__ = [
    "Allocation", "DefragMove", "DevicePool", "PoolError",
    "ClusterJobSpec", "JobManager", "SegmentResult",
    "ClusterError", "ClusterJobOutcome", "ClusterRunResult",
    "ClusterRuntime", "RepackEvent",
]
