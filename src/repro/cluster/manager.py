"""Per-job subprocess lifecycle for the cluster runtime.

A :class:`JobManager` wraps ONE training job as a sequence of segment
subprocesses (:mod:`repro.cluster.worker`), each sized to the job's
current :class:`~repro.cluster.pool.Allocation`: the child's
``XLA_FLAGS`` force exactly ``size`` fake host devices, ``REPRO_JOB_ID``
names the job for namespaced fault plans, and the per-job checkpoint
directory carries state across segments (and across crash relaunches —
the PR-7 restart-resume path).

The manager is deliberately dumb: it launches what the
:class:`~repro.cluster.runtime.ClusterRuntime` tells it to and reports
``("ok", SegmentResult)`` / ``("crash", returncode)``.  All scheduling,
placement, and repack policy live in the runtime.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from typing import Any, Dict, List, Optional, Tuple

import repro
from repro.core.job import DEFAULT_TENANT, TIER_NORMAL, Job
from repro.faults.plan import ENV_VAR as FAULT_ENV_VAR
from repro.faults.plan import JOB_ENV_VAR

# repro may be a namespace package (__file__ is None) — __path__ works
# either way
_SRC_DIR = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


@dataclasses.dataclass(frozen=True)
class ClusterJobSpec:
    """Everything the runtime needs to co-schedule one training job."""
    job_id: str
    size: int                          # device width (constant for life)
    n_steps: int                       # total training steps
    segment_steps: int = 2             # handoff boundary cadence
    arch: str = "llama3.2-1b"
    tenant: str = DEFAULT_TENANT
    priority_tier: int = TIER_NORMAL
    seed: int = 0
    bucket_bytes: int = 64 << 10
    seq_len: int = 16
    global_batch: int = 8
    # arrival gating: enter the wait queue only once the named job has
    # STARTED — a deterministic stand-in for wallclock submit times, so
    # contention scenarios (job arrives into a fragmented pool) replay
    # identically every run
    after: Optional[str] = None

    def __post_init__(self):
        if self.size < 1 or self.n_steps < 1 or self.segment_steps < 1:
            raise ValueError(f"bad spec for {self.job_id}: size/steps "
                             f"must be >= 1")

    def to_job(self) -> Job:
        """The :class:`repro.core.job.Job` record the scheduler sees."""
        return Job(job_id=self.job_id, model=self.arch, kind="train",
                   size=self.size, batch=self.global_batch,
                   base_duration=float(self.n_steps), submit_time=0.0,
                   tenant=self.tenant, priority_tier=self.priority_tier)


@dataclasses.dataclass
class SegmentResult:
    """Parsed worker output for one completed segment."""
    job_id: str
    segment: int
    attempt: int
    start_step: int
    end_step: int
    shape: Tuple[int, int]
    losses: List[float]
    steady_step_s: float
    first_step_s: float
    state_bytes: int
    final_save_s: float
    final_save_bytes: int
    resume_restore_s: float
    resume_restore_bytes: int
    resume_setup_s: float
    recovered_step: Optional[int]


class JobManager:
    """Launch/poll one job's segment subprocesses."""

    def __init__(self, spec: ClusterJobSpec, work_dir: str, *,
                 python: str = sys.executable,
                 env_extra: Optional[Dict[str, str]] = None):
        self.spec = spec
        self.work_dir = os.path.join(work_dir, spec.job_id)
        self.ckpt_dir = os.path.join(self.work_dir, "ckpt")
        os.makedirs(self.ckpt_dir, exist_ok=True)
        self.python = python
        self.env_extra = dict(env_extra or {})
        self.proc: Optional[subprocess.Popen] = None
        self.segment = 0               # index of the NEXT/RUNNING segment
        self.attempt = 0               # relaunches of the current segment
        self.restarts = 0              # total crash relaunches
        self.done_step = 0             # last committed boundary
        self.results: List[SegmentResult] = []
        self._result_path: Optional[str] = None
        self._log_path: Optional[str] = None

    # ------------------------------------------------------------- state
    @property
    def running(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    @property
    def finished(self) -> bool:
        return self.done_step >= self.spec.n_steps

    def next_run_to(self) -> int:
        return min(self.done_step + self.spec.segment_steps,
                   self.spec.n_steps)

    # ------------------------------------------------------------ launch
    def launch(self, shape: Tuple[int, int], *,
               fault_env: Optional[str] = None) -> None:
        """Start the next segment (or relaunch the current one after a
        crash) on mesh ``shape``.  ``fault_env`` is forwarded only on a
        job's very first launch: fault-plan arrival counters are
        per-process, so re-arming the plan on a relaunch would make a
        one-shot crash spec fire forever."""
        if self.running:
            raise RuntimeError(f"{self.spec.job_id}: segment already "
                               f"running")
        s = self.spec
        if shape[0] * shape[1] != s.size:
            raise ValueError(f"{s.job_id}: shape {shape} is not a "
                             f"factorization of width {s.size}")
        run_to = self.next_run_to()
        tag = f"seg{self.segment:03d}_a{self.attempt}"
        spec_path = os.path.join(self.work_dir, f"{tag}.spec.json")
        self._result_path = os.path.join(self.work_dir,
                                         f"{tag}.result.json")
        self._log_path = os.path.join(self.work_dir, f"{tag}.log")
        with open(spec_path, "w") as f:
            json.dump({
                "job_id": s.job_id, "arch": s.arch,
                "shape": list(shape), "base_dir": self.ckpt_dir,
                "run_to": run_to, "total_steps": s.n_steps,
                "seed": s.seed, "resume": self.done_step > 0
                                          or self.attempt > 0,
                "final_save": run_to < s.n_steps,
                "bucket_bytes": s.bucket_bytes, "seq_len": s.seq_len,
                "global_batch": s.global_batch,
            }, f)
        env = dict(os.environ)
        env.update(self.env_extra)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{s.size}")
        env["PYTHONPATH"] = (_SRC_DIR + os.pathsep
                             + env.get("PYTHONPATH", ""))
        env[JOB_ENV_VAR] = s.job_id
        env.pop(FAULT_ENV_VAR, None)
        if fault_env is not None and self.segment == 0 \
                and self.attempt == 0:
            env[FAULT_ENV_VAR] = fault_env
        log = open(self._log_path, "w")
        self.proc = subprocess.Popen(
            [self.python, "-m", "repro.cluster.worker",
             "--spec", spec_path, "--result", self._result_path],
            stdout=log, stderr=subprocess.STDOUT, env=env)
        log.close()

    # -------------------------------------------------------------- poll
    def poll(self) -> Optional[Tuple[str, Any]]:
        """None while running; ``("ok", SegmentResult)`` when the
        segment completed; ``("crash", returncode)`` when the child died
        without a complete result file."""
        if self.proc is None:
            return None
        rc = self.proc.poll()
        if rc is None:
            return None
        self.proc = None
        if rc == 0 and os.path.exists(self._result_path):
            with open(self._result_path) as f:
                d = json.load(f)
            res = SegmentResult(
                job_id=d["job_id"], segment=self.segment,
                attempt=self.attempt, start_step=d["start_step"],
                end_step=d["end_step"], shape=tuple(d["shape"]),
                losses=list(d["losses"]),
                steady_step_s=d["steady_step_s"],
                first_step_s=d["first_step_s"],
                state_bytes=int(d["state_bytes"]),
                final_save_s=d["final_save_s"],
                final_save_bytes=int(d["final_save_bytes"]),
                resume_restore_s=d["resume_restore_s"],
                resume_restore_bytes=int(d["resume_restore_bytes"]),
                resume_setup_s=d["resume_setup_s"],
                recovered_step=d.get("recovered_step"))
            self.results.append(res)
            self.done_step = res.end_step
            self.segment += 1
            self.attempt = 0
            return ("ok", res)
        return ("crash", rc)

    def note_crash(self) -> None:
        """Bookkeeping after the runtime decides to relaunch."""
        self.attempt += 1
        self.restarts += 1

    def tail_log(self, n: int = 2000) -> str:
        if self._log_path and os.path.exists(self._log_path):
            with open(self._log_path) as f:
                return f.read()[-n:]
        return ""
