"""Shared device-pool ledger for the multi-tenant cluster runtime.

One :class:`DevicePool` owns the cluster's fake host devices — ``n_hosts
× devices_per_host`` global device ids, host ``h`` holding the
contiguous block ``[h*dph, (h+1)*dph)`` — and the ledger of which
*disjoint* subset each running job occupies.  Placement is
geometry-constrained by the bitwise elastic invariant: a job of width
``size`` runs an SPMD mesh of shape ``(span, size // span)`` — one mesh
row per spanned host, equal device counts per host — so every placement
the pool plans is a valid (pod, data) factorization the
:class:`~repro.elastic_driver.ElasticDriver` can hand off between.

Two strategies mirror :func:`repro.core.policy.cluster_placement`:

- ``round_robin`` spreads across as many hosts as possible (widest
  equal split — the paper's Fig.-9 balanced default), onto the
  emptiest hosts first;
- ``packed`` minimizes host span (fills the fullest hosts first), the
  shape defrag repacks squeeze victims into and the single-host SLA
  tier requires (``require_span=1``);
- ``frag_aware`` scores every feasible (span, host set) by the
  demand-weighted stranded-fragment measure
  (:func:`repro.core.policy.stranded_frag`) summed over the touched
  hosts' post-placement free counts, and takes the minimum — the
  host-granularity analogue of the leaf-level frag-aware placement.

The pool also answers the two scheduling questions that drive repacks:
:meth:`fragmented_for` — is a job blocked *only* by fragmentation (free
capacity exists but no valid placement)? — and :meth:`defrag_plan` —
which single victim, re-placed packed, admits it?
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.policy import DEFAULT_FRAG_DEMAND, stranded_frag


@dataclasses.dataclass(frozen=True)
class Allocation:
    """One job's slice of the pool: global device ids + mesh shape."""
    job_id: str
    devices: Tuple[int, ...]          # sorted global device ids
    shape: Tuple[int, int]            # (pod = hosts spanned, data = per host)

    @property
    def size(self) -> int:
        return len(self.devices)


@dataclasses.dataclass(frozen=True)
class DefragMove:
    """Defrag plan: move ``victim`` to ``victim_to`` so ``requester``
    (currently blocked by fragmentation) fits at ``requester_to``."""
    victim: str
    victim_to: Allocation
    requester: str
    requester_to: Allocation


class PoolError(ValueError):
    pass


class DevicePool:
    def __init__(self, n_hosts: int, devices_per_host: int):
        if n_hosts < 1 or devices_per_host < 1:
            raise PoolError("pool needs at least one host and one device")
        self.n_hosts = n_hosts
        self.devices_per_host = devices_per_host
        self.allocs: Dict[str, Allocation] = {}

    # ------------------------------------------------------------ geometry
    @property
    def n_devices(self) -> int:
        return self.n_hosts * self.devices_per_host

    def host_of(self, dev: int) -> int:
        if not 0 <= dev < self.n_devices:
            raise PoolError(f"device {dev} outside pool of "
                            f"{self.n_devices}")
        return dev // self.devices_per_host

    def free_by_host(self,
                     exclude: Sequence[str] = ()) -> List[List[int]]:
        """Free device ids per host; ``exclude`` treats those jobs'
        devices as free (hypothetical planning: the excluded job is the
        one about to move)."""
        used = set()
        for jid, a in self.allocs.items():
            if jid in exclude:
                continue
            used.update(a.devices)
        return [[d for d in range(h * self.devices_per_host,
                                  (h + 1) * self.devices_per_host)
                 if d not in used]
                for h in range(self.n_hosts)]

    def total_free(self, exclude: Sequence[str] = ()) -> int:
        return sum(len(f) for f in self.free_by_host(exclude))

    # ------------------------------------------------------------ planning
    def _spans(self, size: int, strategy: str) -> List[int]:
        spans = [s for s in range(1, self.n_hosts + 1)
                 if size % s == 0 and size // s <= self.devices_per_host]
        if strategy == "round_robin":
            return sorted(spans, reverse=True)       # widest split first
        return spans                                 # packed/frag: narrow

    def _plan_frag_aware(self, size: int, require_span: Optional[int],
                         free: List[List[int]]
                         ) -> Optional[Tuple[Tuple[int, ...],
                                             Tuple[int, int]]]:
        """Exact argmin of post-placement stranded fragmentation.

        Per-host fragmentation is independent, so for a fixed span the
        optimal host set picks the ``span`` hosts with the smallest
        fragmentation *delta* ``F(free - per) - F(free)``; spans then
        compare by total delta (untouched hosts contribute zero).
        Deterministic tie-breaks: per host ``(delta, leftover free,
        host id)``; across spans lowest total delta wins, ties to the
        NARROWEST span (fewest hosts perturbed — the consolidation-
        leaning choice, matching defrag's packed bias).
        """
        best = None          # (total_delta, span, hosts, per)
        for span in self._spans(size, "frag_aware"):
            if require_span is not None and span != require_span:
                continue
            per = size // span
            scored = []
            for h in range(self.n_hosts):
                if len(free[h]) < per:
                    continue
                left = len(free[h]) - per
                delta = (stranded_frag(left, DEFAULT_FRAG_DEMAND)
                         - stranded_frag(len(free[h]),
                                         DEFAULT_FRAG_DEMAND))
                scored.append((delta, left, h))
            if len(scored) < span:
                continue
            scored.sort()
            take = scored[:span]
            total = sum(s[0] for s in take)
            hosts = sorted(s[2] for s in take)
            if best is None or total < best[0]:
                best = (total, span, hosts, per)
        if best is None:
            return None
        _, span, hosts, per = best
        devices = tuple(sorted(d for h in hosts for d in free[h][:per]))
        return devices, (span, per)

    def plan(self, size: int, *, strategy: str = "round_robin",
             require_span: Optional[int] = None,
             free: Optional[List[List[int]]] = None
             ) -> Optional[Tuple[Tuple[int, ...], Tuple[int, int]]]:
        """Find ``(devices, shape)`` for a job of width ``size``, or
        None.  Deterministic: host choice is by free-count then index
        (emptiest-first for ``round_robin``, fullest-first for
        ``packed``), devices lowest-id-first within a host."""
        if strategy not in ("round_robin", "packed", "frag_aware"):
            raise PoolError(f"unknown placement strategy {strategy!r}")
        if size < 1:
            raise PoolError(f"job width must be >= 1, got {size}")
        if free is None:
            free = self.free_by_host()
        if strategy == "frag_aware":
            return self._plan_frag_aware(size, require_span, free)
        for span in self._spans(size, strategy):
            if require_span is not None and span != require_span:
                continue
            per = size // span
            hosts = [h for h in range(self.n_hosts)
                     if len(free[h]) >= per]
            if len(hosts) < span:
                continue
            if strategy == "round_robin":
                hosts.sort(key=lambda h: (-len(free[h]), h))
            else:
                hosts.sort(key=lambda h: (len(free[h]), h))
            chosen = sorted(hosts[:span])
            devices = tuple(sorted(
                d for h in chosen for d in free[h][:per]))
            return devices, (span, per)
        return None

    def fragmented_for(self, size: int, *,
                       strategy: str = "round_robin",
                       require_span: Optional[int] = None) -> bool:
        """True iff the job is blocked by *fragmentation*: enough total
        free devices exist, but no valid placement does."""
        if self.total_free() < size:
            return False
        return self.plan(size, strategy=strategy,
                         require_span=require_span) is None

    def defrag_plan(self, requester_id: str, size: int, *,
                    require_span: Optional[int],
                    victims: Sequence[str]) -> Optional[DefragMove]:
        """Admit a fragmentation-blocked job by moving ONE victim.

        For each candidate victim (policy-ordered by the caller, see
        :func:`repro.core.policy.defrag_victims`): hypothetically free
        its devices, re-place it *packed* (minimum span — defrag exists
        to consolidate), and check the requester then fits under its own
        constraints on what remains.  First victim that works wins;
        None if no single move suffices.
        """
        for vid in victims:
            alloc = self.allocs.get(vid)
            if alloc is None:
                continue
            free = self.free_by_host(exclude=(vid,))
            new_v = self.plan(alloc.size, strategy="packed", free=free)
            if new_v is None:
                continue
            v_devices, v_shape = new_v
            remaining = [[d for d in f if d not in v_devices]
                         for f in free]
            placed = self.plan(size, strategy="packed" if require_span
                               else "round_robin",
                               require_span=require_span, free=remaining)
            if placed is None:
                continue
            r_devices, r_shape = placed
            return DefragMove(
                victim=vid,
                victim_to=Allocation(vid, v_devices, v_shape),
                requester=requester_id,
                requester_to=Allocation(requester_id, r_devices,
                                        r_shape))
        return None

    # ------------------------------------------------------------- ledger
    def _validate(self, job_id: str, devices: Tuple[int, ...],
                  shape: Tuple[int, int], *,
                  ignore: Sequence[str] = ()) -> None:
        devices = tuple(sorted(devices))
        if len(set(devices)) != len(devices):
            raise PoolError(f"{job_id}: duplicate devices {devices}")
        for d in devices:
            self.host_of(d)                      # range check
        for jid, a in self.allocs.items():
            if jid in ignore or jid == job_id:
                continue
            clash = set(devices) & set(a.devices)
            if clash:
                raise PoolError(
                    f"{job_id}: devices {sorted(clash)} already held "
                    f"by {jid}")
        span, per = shape
        if span * per != len(devices):
            raise PoolError(f"{job_id}: shape {shape} does not "
                            f"factor {len(devices)} devices")
        by_host: Dict[int, int] = {}
        for d in devices:
            by_host[self.host_of(d)] = by_host.get(self.host_of(d),
                                                   0) + 1
        if len(by_host) != span or set(by_host.values()) != {per}:
            raise PoolError(
                f"{job_id}: devices {devices} do not form an equal "
                f"{per}-per-host split over {span} hosts (got "
                f"{by_host})")

    def allocate(self, job_id: str, devices: Sequence[int],
                 shape: Tuple[int, int]) -> Allocation:
        if job_id in self.allocs:
            raise PoolError(f"{job_id} already allocated")
        devices = tuple(sorted(devices))
        self._validate(job_id, devices, tuple(shape))
        a = Allocation(job_id, devices, tuple(shape))
        self.allocs[job_id] = a
        return a

    def release(self, job_id: str) -> Allocation:
        try:
            return self.allocs.pop(job_id)
        except KeyError:
            raise PoolError(f"{job_id} holds no allocation")

    def reassign(self, job_id: str, devices: Sequence[int],
                 shape: Tuple[int, int]) -> Allocation:
        """Atomically move a job to a new placement (repack)."""
        if job_id not in self.allocs:
            raise PoolError(f"{job_id} holds no allocation to move")
        devices = tuple(sorted(devices))
        self._validate(job_id, devices, tuple(shape),
                       ignore=(job_id,))
        a = Allocation(job_id, devices, tuple(shape))
        self.allocs[job_id] = a
        return a
