"""Cluster worker: one job *segment* in a subprocess.

The :class:`~repro.cluster.manager.JobManager` launches this module
(``python -m repro.cluster.worker --spec S --result R``) with
``XLA_FLAGS`` forcing exactly the job's device count, so each
co-scheduled job gets its own private fake-device world sized to its
pool allocation.  The worker arms any namespaced fault plan *first*
(:func:`repro.faults.plan.install_from_env` — before anything compiles,
so crash specs can fire anywhere in the segment), runs one
:class:`~repro.elastic_driver.ElasticDriver` segment, and writes the
result JSON atomically (tmp + rename) — a missing/partial result file
is how the parent distinguishes a crash from a finished segment.

Segment protocol (the cluster runtime's handoff-by-segments):

- first segment: fresh start on the assigned shape, train to ``run_to``,
  ``final_save`` commits step ``run_to``;
- later segments: ``resume=True`` restores the newest committed step
  onto the (possibly different) assigned shape — the reshard-restore is
  the receiving half of the repack — and continues to the new ``run_to``.

``total_steps`` is always the job's FULL step count: the AdamW schedule
is absolute-step-indexed and :class:`~repro.data.SyntheticCorpus`
batches are deterministic by absolute step, which is what makes the
stitched per-job loss curve bitwise-equal to an uninterrupted run.
"""
from __future__ import annotations

import argparse
import json
import os


def run_segment(spec: dict) -> dict:
    # arm the (namespaced) fault plan before jax wakes up so injected
    # crashes can hit compile/first-step/save paths too
    from repro.faults.plan import install_from_env
    install_from_env(spec.get("job_id"))

    from repro import optim
    from repro.data import DataConfig
    from repro.elastic_driver import ElasticDriver
    from repro.models.registry import (build_model, get_config,
                                       reduced_config)

    cfg = reduced_config(get_config(spec["arch"]))
    model = build_model(cfg, remat=False)
    ocfg = optim.AdamWConfig(peak_lr=spec.get("peak_lr", 1e-3),
                             warmup_steps=spec.get("warmup_steps", 2),
                             total_steps=spec["total_steps"])
    dcfg = DataConfig(vocab_size=cfg.vocab_size,
                      seq_len=spec.get("seq_len", 16),
                      global_batch=spec.get("global_batch", 8))
    drv = ElasticDriver(model, ocfg, dcfg, base_dir=spec["base_dir"],
                        bucket_bytes=spec.get("bucket_bytes", 64 << 10),
                        fallback_on_corrupt=True)
    shape = tuple(spec["shape"])
    res = drv.run(spec["run_to"], (), initial_shape=shape,
                  seed=spec.get("seed", 0),
                  resume=bool(spec.get("resume", False)),
                  final_save=bool(spec.get("final_save", True)))
    return {
        "job_id": spec["job_id"],
        "start_step": res.start_step,
        "end_step": spec["run_to"],
        "shape": list(shape),
        "n_ranks": shape[0] * shape[1],
        "losses": res.losses,
        "steady_step_s": res.steady_step_s,
        "first_step_s": res.first_step_s,
        "state_bytes": res.state_bytes,
        "final_save_s": res.final_save_s,
        "final_save_bytes": res.final_save_bytes,
        "resume_restore_s": res.resume_restore_s,
        "resume_restore_bytes": res.resume_restore_bytes,
        "resume_setup_s": res.resume_setup_s,
        "recovered_step": (res.recovery.restored_step
                           if res.recovery else None),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", required=True)
    ap.add_argument("--result", required=True)
    args = ap.parse_args(argv)
    with open(args.spec) as f:
        spec = json.load(f)
    out = run_segment(spec)
    tmp = args.result + ".tmp"
    with open(tmp, "w") as f:
        json.dump(out, f)
    os.replace(tmp, args.result)      # atomic: exists => complete


if __name__ == "__main__":
    main()
