"""Multi-tenant elastic cluster runtime.

:class:`ClusterRuntime` co-schedules several training jobs — each a
sequence of :mod:`repro.cluster.worker` subprocesses — over ONE shared
fake-device pool.  It closes the loop the simulator only models: the
real :class:`repro.core.scheduler.Scheduler` (FIFO/backfill, per-tenant
quotas, priority tiers) decides *who* runs, the
:class:`~repro.cluster.pool.DevicePool` ledger decides *where*, and the
:class:`~repro.elastic_driver.ElasticDriver` segments execute the
decisions — with every repack a real committed-save → reshard-restore →
recompile handoff whose wallclock is measured and fed back to
:meth:`repro.core.jct_model.ReconfigCostModel.from_measurements`.

Repacks are **geometry moves at constant width**: a job of width R only
ever moves between device subsets / (pod, data) factorizations of the
same R, because the deterministic-reduce bitwise invariant holds across
factorizations of one rank count, not across widths.  Two scheduler-
driven reasons exist, both applied at a victim's segment boundary (the
only place a committed checkpoint exists to hand off from):

- ``defrag``: a queued job is blocked by *fragmentation* (enough free
  devices, no valid placement); the policy picks a victim
  (:func:`repro.core.policy.defrag_victims`) to consolidate (packed),
  freeing a placement for the blocked job — the paper's
  reconfiguration-for-admission case;
- ``rebalance``: devices freed by a departure let a running job return
  to its preferred round-robin (widest-split) placement.

Crash recovery rides the PR-7 path: a child that dies without a result
file is relaunched with ``resume=True`` onto its current allocation,
restoring the newest committed step; namespaced fault plans
(:func:`repro.faults.plan.plans_to_env`) let a test crash exactly one
tenant's job while its neighbors run on undisturbed.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.policy import cluster_placement, defrag_victims
from repro.core.scheduler import Scheduler, WaitQueue
from repro.cluster.manager import ClusterJobSpec, JobManager, SegmentResult
from repro.cluster.pool import DevicePool
from repro.faults.plan import FaultPlan, plans_to_env


class ClusterError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class RepackEvent:
    """One executed geometry move (constant width)."""
    job_id: str
    reason: str                       # "defrag" | "rebalance"
    at_step: int                      # victim's boundary step
    from_devices: Tuple[int, ...]
    from_shape: Tuple[int, int]
    to_devices: Tuple[int, ...]
    to_shape: Tuple[int, int]
    requested_by: Optional[str] = None

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for k in ("from_devices", "from_shape", "to_devices",
                  "to_shape"):
            d[k] = list(d[k])
        return d


@dataclasses.dataclass
class ClusterJobOutcome:
    job_id: str
    losses: List[float]               # stitched over segments, by step
    shapes: List[Tuple[int, int]]     # per segment
    segments: List[SegmentResult]
    restarts: int


@dataclasses.dataclass
class ClusterRunResult:
    jobs: Dict[str, ClusterJobOutcome]
    repacks: List[RepackEvent]
    # stitched cross-process handoff measurements, one per segment
    # boundary (ReconfigCostModel.from_measurements-shaped dicts)
    measurements: List[Dict[str, Any]]
    wall_s: float

    @property
    def n_repacks(self) -> int:
        return len(self.repacks)


class ClusterRuntime:
    def __init__(self, specs: Sequence[ClusterJobSpec], *,
                 pool: DevicePool, base_dir: str,
                 scheduler: Optional[Scheduler] = None,
                 rebalance: bool = True,
                 defrag: bool = True,
                 frag_aware: bool = False,
                 manager_factory=JobManager,
                 max_restarts: int = 2,
                 fault_plans: Optional[Dict[str, FaultPlan]] = None,
                 poll_s: float = 0.1,
                 timeout_s: float = 3000.0):
        ids = [s.job_id for s in specs]
        if len(set(ids)) != len(ids):
            raise ClusterError(f"duplicate job ids in {ids}")
        for s in specs:
            if s.size > pool.n_devices:
                raise ClusterError(
                    f"{s.job_id}: width {s.size} exceeds the pool "
                    f"({pool.n_devices} devices)")
        self.specs: Dict[str, ClusterJobSpec] = {s.job_id: s
                                                 for s in specs}
        self.order = ids
        self.pool = pool
        self.base_dir = base_dir
        self.scheduler = scheduler or Scheduler("backfill", depth=8)
        self.rebalance = rebalance
        self.defrag = defrag
        # frag-aware placement scoring (policy.cluster_placement);
        # strictly opt-in: default False keeps every golden identical
        self.frag_aware = frag_aware
        self.manager_factory = manager_factory
        self.max_restarts = max_restarts
        self.fault_env = (plans_to_env(fault_plans)
                          if fault_plans else None)
        self.poll_s = poll_s
        self.timeout_s = timeout_s

        self.queue = WaitQueue()
        self.deferred: List[str] = []       # specs gated on `after`
        self.managers: Dict[str, Any] = {}
        self.started: Set[str] = set()
        self.finished: Set[str] = set()
        # victim job id -> blocked requester id; applied at the
        # victim's next segment boundary
        self.pending_defrag: Dict[str, str] = {}
        self.reserved: Set[str] = set()     # requesters awaiting defrag
        self.repacks: List[RepackEvent] = []
        self.measurements: List[Dict[str, Any]] = []

        for jid in self.order:
            after = self.specs[jid].after
            if after:
                if after not in self.specs:
                    raise ClusterError(f"{jid}: after={after!r} names "
                                       f"no submitted job")
                self.deferred.append(jid)
            else:
                self.queue.push(self.specs[jid].to_job())

    # ----------------------------------------------------------- helpers
    def _usage(self) -> Dict[str, int]:
        usage: Dict[str, int] = {}
        for jid, a in self.pool.allocs.items():
            t = self.specs[jid].tenant
            usage[t] = usage.get(t, 0) + a.size
        return usage

    def _running_jobs(self) -> List:
        return [self.specs[jid].to_job() for jid in self.order
                if jid in self.pool.allocs]

    def _placement_of(self, job) -> Tuple[str, Optional[int]]:
        return cluster_placement(job.priority_tier, job.size,
                                 self.pool.devices_per_host,
                                 frag_aware=self.frag_aware)

    def _start(self, job, devices, shape) -> None:
        jid = job.job_id
        self.pool.allocate(jid, devices, shape)
        self.queue.remove(job)
        m = self.manager_factory(self.specs[jid], self.base_dir)
        self.managers[jid] = m
        self.started.add(jid)
        m.launch(shape, fault_env=self.fault_env)

    # ---------------------------------------------------------- schedule
    def _admit_deferred(self) -> None:
        still = []
        for jid in self.deferred:
            if self.specs[jid].after in self.started:
                self.queue.push(self.specs[jid].to_job())
            else:
                still.append(jid)
        self.deferred = still

    def _schedule_pass(self) -> bool:
        self._admit_deferred()
        progress = False
        for job in list(self.scheduler.candidates(self.queue,
                                                  usage=self._usage())):
            jid = job.job_id
            if jid in self.reserved:
                continue
            strategy, span = self._placement_of(job)
            placed = self.pool.plan(job.size, strategy=strategy,
                                    require_span=span)
            if placed is not None:
                self._start(job, *placed)
                progress = True
                continue
            # blocked: is it pure fragmentation a repack can fix?
            if not self.defrag:
                continue
            if not self.pool.fragmented_for(job.size, strategy=strategy,
                                            require_span=span):
                continue
            victims = [v.job_id for v in
                       defrag_victims(self._running_jobs(), job)]
            move = self.pool.defrag_plan(jid, job.size,
                                         require_span=span,
                                         victims=victims)
            if move is not None:
                self.pending_defrag[move.victim] = jid
                self.reserved.add(jid)
        return progress

    # ---------------------------------------------------------- boundary
    def _record_boundary(self, jid: str, res: SegmentResult) -> None:
        m = self.managers[jid]
        if len(m.results) < 2:
            return
        prev = m.results[-2]
        self.measurements.append({
            "job_id": jid, "step": res.start_step,
            "from_shape": list(prev.shape),
            "to_shape": list(res.shape), "mode": "handoff",
            "save_s": prev.final_save_s,
            "save_bytes": prev.final_save_bytes,
            "restore_s": res.resume_restore_s,
            "restore_bytes": res.resume_restore_bytes,
            "setup_s": res.resume_setup_s,
            "first_step_s": res.first_step_s,
            "compile_s": max(0.0,
                             res.first_step_s - res.steady_step_s),
            "state_bytes": prev.state_bytes,
            "n_ranks": res.shape[0] * res.shape[1],
            "repack": prev.shape != res.shape,
        })

    def _apply_defrag(self, victim: str) -> bool:
        """At ``victim``'s boundary: re-validate and execute the pending
        consolidation, then admit the blocked requester."""
        rid = self.pending_defrag.pop(victim)
        self.reserved.discard(rid)
        rjob = self.specs[rid].to_job()
        if rid not in [j.job_id for j in self.queue.jobs]:
            return False                  # requester got in some other way
        _, span = self._placement_of(rjob)
        move = self.pool.defrag_plan(rid, rjob.size, require_span=span,
                                     victims=[victim])
        if move is None:
            return False                  # world changed; requeue normally
        old = self.pool.allocs[victim]
        self.pool.reassign(victim, move.victim_to.devices,
                           move.victim_to.shape)
        self.repacks.append(RepackEvent(
            job_id=victim, reason="defrag",
            at_step=self.managers[victim].done_step,
            from_devices=old.devices, from_shape=old.shape,
            to_devices=move.victim_to.devices,
            to_shape=move.victim_to.shape, requested_by=rid))
        self._start(rjob, move.requester_to.devices,
                    move.requester_to.shape)
        return True

    def _maybe_rebalance(self, jid: str) -> None:
        """At a boundary, return the job to its preferred placement if
        departures made a better *geometry* available (device moves with
        no shape change are not worth a handoff)."""
        job = self.specs[jid].to_job()
        strategy, span = self._placement_of(job)
        cur = self.pool.allocs[jid]
        placed = self.pool.plan(
            job.size, strategy=strategy, require_span=span,
            free=self.pool.free_by_host(exclude=(jid,)))
        if placed is None:
            return
        devices, shape = placed
        if shape == cur.shape:
            return
        self.pool.reassign(jid, devices, shape)
        self.repacks.append(RepackEvent(
            job_id=jid, reason="rebalance",
            at_step=self.managers[jid].done_step,
            from_devices=cur.devices, from_shape=cur.shape,
            to_devices=devices, to_shape=shape))

    def _on_segment_done(self, jid: str, res: SegmentResult) -> None:
        m = self.managers[jid]
        self._record_boundary(jid, res)
        if m.finished:
            self.pool.release(jid)
            self.finished.add(jid)
            # a pending defrag whose victim just left is moot — the
            # departure freed more than the move would have
            if jid in self.pending_defrag:
                self.reserved.discard(self.pending_defrag.pop(jid))
            return
        # segment boundary: the one place this job can change geometry
        if jid in self.pending_defrag:
            self._apply_defrag(jid)
        elif self.rebalance:
            self._maybe_rebalance(jid)
        m.launch(self.pool.allocs[jid].shape,
                 fault_env=self.fault_env)

    def _on_crash(self, jid: str, rc: int) -> None:
        m = self.managers[jid]
        if m.attempt >= self.max_restarts:
            raise ClusterError(
                f"{jid}: segment {m.segment} died (rc={rc}) "
                f"{m.attempt + 1} times; giving up.\n--- child log "
                f"---\n{m.tail_log()}")
        m.note_crash()
        # relaunch on the same allocation, resuming the newest committed
        # step (the manager never re-arms the fault plan on relaunch)
        m.launch(self.pool.allocs[jid].shape,
                 fault_env=self.fault_env)

    # --------------------------------------------------------------- run
    def _poll_once(self) -> bool:
        progress = False
        for jid, m in list(self.managers.items()):
            if jid in self.finished:
                continue
            ev = m.poll()
            if ev is None:
                continue
            progress = True
            kind, payload = ev
            if kind == "ok":
                self._on_segment_done(jid, payload)
            else:
                self._on_crash(jid, payload)
        return progress

    def run(self) -> ClusterRunResult:
        os.makedirs(self.base_dir, exist_ok=True)
        t_start = time.monotonic()
        while (self.queue or self.deferred
               or len(self.finished) < len(self.started)):
            progress = self._schedule_pass()
            progress |= self._poll_once()
            if progress:
                continue
            active = [jid for jid, m in self.managers.items()
                      if jid not in self.finished]
            if not active:
                blocked = ([j.job_id for j in self.queue.jobs]
                           + self.deferred)
                raise ClusterError(
                    f"scheduling deadlock: nothing is running and "
                    f"{blocked} cannot start (pool free="
                    f"{self.pool.free_by_host()})")
            if time.monotonic() - t_start > self.timeout_s:
                raise ClusterError(
                    f"cluster run exceeded {self.timeout_s}s "
                    f"(active={active})")
            time.sleep(self.poll_s)

        jobs: Dict[str, ClusterJobOutcome] = {}
        for jid in self.order:
            m = self.managers[jid]
            n = self.specs[jid].n_steps
            losses: List[Optional[float]] = [None] * n
            for res in m.results:
                for i, l in enumerate(res.losses):
                    losses[res.start_step + i] = l
            missing = [i for i, l in enumerate(losses) if l is None]
            if missing:
                raise ClusterError(f"{jid}: steps {missing[:5]}... "
                                   f"never executed")
            jobs[jid] = ClusterJobOutcome(
                job_id=jid, losses=losses,
                shapes=[r.shape for r in m.results],
                segments=list(m.results), restarts=m.restarts)
        return ClusterRunResult(jobs=jobs, repacks=self.repacks,
                                measurements=self.measurements,
                                wall_s=time.monotonic() - t_start)
