"""Training step factory + fault-tolerant training loop.

``make_train_step`` builds the jit-able (params, opt, batch) -> (params,
opt, metrics) function with:
- microbatch gradient accumulation (lax.scan) — required to fit the 100B
  archs' activations in 16 GB/chip;
- per-layer remat (inside the models' scanned stacks);
- cross-pod gradient modes: 'xla' (SPMD inserts the minimal sharded
  all-reduce over 'pod') or 'compressed' (explicit shard_map over 'pod'
  with int8 all-gather — 4x fewer DCN bytes, §Perf).

``Trainer`` adds checkpoint/restart, heartbeats, straggler detection and
failure injection around the step function.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt_lib
from repro import optim
from repro import parallel as PX
from repro.collectives.compression import compressed_psum_mean
from repro.data import DataConfig, Prefetcher, SyntheticCorpus
from repro.elastic import HeartbeatMonitor, StragglerDetector
from repro.sharding import MeshRules, use_rules


def _split_micro(batch: Dict[str, jax.Array], accum: int):
    def f(x):
        return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
    return {k: f(v) for k, v in batch.items()}


def make_loss_and_grad(model, *, accum: int):
    """Pod-local accumulated (loss, grads) over ``accum`` microbatches.

    Differentiates wrt an f32 view of the params (cast back to their
    storage dtype inside the loss, so the forward math is unchanged):
    grads then materialize and combine in f32 end-to-end.  Differentiating
    wrt the bf16 leaves directly rounds each microbatch's gradient — e.g.
    the tied embedding's lookup-scatter + logits-matmul contributions — to
    bf16 before accumulation, which breaks accum-invariance.

    Cost: the f32 view is a transient 2x-param-bytes buffer live during
    the accumulation scan (it dies before the optimizer update, which
    holds its own f32 masters).  Threading the optimizer's masters in
    here instead would drop that copy; left for a later PR since it
    changes this function's (params, batch) interface.
    """

    def fn(params, batch):
        micro = _split_micro(batch, accum)
        dtypes = jax.tree.map(lambda p: p.dtype, params)
        params32 = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)

        def cast_loss(p32, mb):
            p = jax.tree.map(lambda q, dt: q.astype(dt), p32, dtypes)
            return model.loss(p, mb)

        def step(carry, mb):
            loss_sum, grads = carry
            (loss, _metrics), g = jax.value_and_grad(
                cast_loss, has_aux=True)(params32, mb)
            grads = jax.tree.map(lambda a, b: a + b, grads, g)
            return (loss_sum + loss, grads), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), zero_g), micro)
        inv = 1.0 / accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)

    return fn


def make_train_step(model, ocfg: optim.AdamWConfig, *, accum: int = 1,
                    rules: Optional[MeshRules] = None,
                    cross_pod_mode: str = "xla"):
    """Returns step(params, opt_state, batch) -> (params, opt, metrics)."""
    lg = make_loss_and_grad(model, accum=accum)
    mesh = rules.mesh if rules is not None else None
    has_pod = mesh is not None and "pod" in mesh.axis_names

    def base_step(params, opt_state, batch):
        if cross_pod_mode == "compressed" and has_pod:
            n_pods = mesh.shape["pod"]
            from repro.sharding import use_rules, without_axes
            inner_rules = (without_axes(rules, frozenset({"pod"}))
                           if rules is not None else None)

            def per_pod(params, batch):
                batch = {k: v[0] for k, v in batch.items()}  # strip pod dim
                with use_rules(inner_rules):  # 'pod' is manual in here
                    loss, grads = lg(params, batch)
                grads = jax.tree.map(
                    lambda g: compressed_psum_mean(g, "pod", bits=8),
                    grads)
                return PX.psum(loss, "pod") / n_pods, grads

            # an explicit leading pod dim keeps the manual 'pod' axis off
            # dims that are auto-sharded over 'data'
            batch_p = {k: v.reshape((n_pods, v.shape[0] // n_pods)
                                    + v.shape[1:])
                       for k, v in batch.items()}
            loss, grads = PX.shard_map(
                per_pod, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), params),
                          jax.tree.map(lambda _: P("pod"), batch_p)),
                out_specs=(P(), jax.tree.map(lambda _: P(), params)),
                check_vma=False, axis_names={"pod"},
            )(params, batch_p)
        else:
            loss, grads = lg(params, batch)
        params, opt_state, om = optim.apply(ocfg, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return base_step


def make_jitted_train_step(model, ocfg, *, accum, rules,
                           param_shardings=None, opt_shardings=None,
                           batch_sharding=None, cross_pod_mode="xla"):
    step = make_train_step(model, ocfg, accum=accum, rules=rules,
                           cross_pod_mode=cross_pod_mode)

    def wrapped(params, opt_state, batch):
        with use_rules(rules):
            return step(params, opt_state, batch)

    kw = {}
    if param_shardings is not None:
        kw["in_shardings"] = (param_shardings, opt_shardings,
                              batch_sharding)
        kw["out_shardings"] = (param_shardings, opt_shardings, None)
    return jax.jit(wrapped, donate_argnums=(0, 1), **kw)


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    accum: int = 1
    async_ckpt: bool = True
    heartbeat_timeout_s: float = 60.0


class Trainer:
    def __init__(self, model, ocfg: optim.AdamWConfig,
                 tcfg: TrainerConfig, data_cfg: DataConfig, *,
                 rules: Optional[MeshRules] = None,
                 failure_hook: Optional[Callable[[int], bool]] = None):
        self.model = model
        self.ocfg = ocfg
        self.tcfg = tcfg
        self.data_cfg = data_cfg
        self.rules = rules
        self.failure_hook = failure_hook
        self.heartbeat = HeartbeatMonitor(
            timeout_s=tcfg.heartbeat_timeout_s)
        self.straggler = StragglerDetector()
        self.step_fn = make_jitted_train_step(
            model, ocfg, accum=tcfg.accum, rules=rules)
        self.history: list = []

    def _init_state(self, seed: int = 0):
        params = self.model.init(jax.random.key(seed))
        return params, optim.init(self.ocfg, params)

    def run(self, *, seed: int = 0, resume: bool = True
            ) -> Dict[str, Any]:
        tcfg = self.tcfg
        start = 0
        params, opt_state = self._init_state(seed)
        if resume:
            last = ckpt_lib.latest_step(tcfg.ckpt_dir)
            if last is not None:
                start, (params, opt_state) = ckpt_lib.restore(
                    ckpt_lib.step_dir(tcfg.ckpt_dir, last),
                    (params, opt_state))
        corpus = SyntheticCorpus(self.data_cfg)
        prefetch = Prefetcher(corpus, start_step=start)
        pending = None
        try:
            for step in range(start, tcfg.n_steps):
                if self.failure_hook and self.failure_hook(step):
                    raise RuntimeError(f"injected failure at step {step}")
                t0 = time.perf_counter()
                _, batch = prefetch.next()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                dt = time.perf_counter() - t0
                self.heartbeat.beat(worker=0, t=time.time())
                self.straggler.record(dt)
                if step % tcfg.log_every == 0:
                    self.history.append(
                        {"step": step,
                         "loss": float(metrics["loss"]),
                         "sec_per_step": dt})
                if (step + 1) % tcfg.ckpt_every == 0:
                    if pending is not None:
                        pending.join()
                    pending = ckpt_lib.save(
                        ckpt_lib.step_dir(tcfg.ckpt_dir, step + 1),
                        step + 1, (params, opt_state),
                        blocking=not tcfg.async_ckpt)
        finally:
            if pending is not None:
                pending.join()
            prefetch.close()
        return {"params": params, "opt_state": opt_state,
                "history": self.history,
                "stragglers": self.straggler.summary()}
