"""Training step factory + fault-tolerant training loop.

``make_train_step`` builds the jit-able (params, opt, batch) -> (params,
opt, metrics) function with:
- microbatch gradient accumulation (lax.scan) — required to fit the 100B
  archs' activations in 16 GB/chip;
- per-layer remat (inside the models' scanned stacks);
- cross-pod gradient sync modes (``cross_pod_mode``):

  * ``'xla'``         SPMD inserts the minimal sharded all-reduce.
  * ``'compressed'``  retired: its partial shard_map (manual 'pod',
                      auto 'data') fatally aborts XLA under the pinned
                      jax — multi-pod meshes get a NotImplementedError
                      pointing at ``hier_bucketed`` +
                      ``slow_compress_bits=8`` (same int8 slow hop).
  * ``'hier'``        fully-manual per-tensor hierarchical schedule
                      (reduce-scatter fast / psum slow / all-gather
                      fast) — 3 collectives *per leaf*; kept as the
                      latency-bound baseline the bucketed modes beat.
  * ``'hier_bucketed'``        the hierarchical schedule once per flat
                      f32 *bucket* (``collectives.bucketing``) — a
                      handful of large collectives per step.
  * ``'hier_bucketed_zero1'``  bucketed + shard-resident optimizer: the
                      schedule stops after the slow hop, AdamW updates
                      each rank's bucket shard (f32 masters sharded over
                      the fast axis) and updated *params* are
                      all-gathered instead of gradients.  Bitwise-
                      identical losses to ``hier_bucketed``.

``Trainer`` adds checkpoint/restart, heartbeats, straggler detection and
failure injection around the step function.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import checkpoint as legacy_ckpt
from repro import ckpt as ckpt_lib
from repro import optim
from repro import parallel as PX
from repro.collectives import bucketing
from repro.collectives import deterministic as det
from repro.collectives.hierarchical import hier_all_reduce_mean
from repro.data import DataConfig, Prefetcher, SyntheticCorpus
from repro.elastic import HeartbeatMonitor, StragglerDetector
from repro.sharding import (MeshRules, grad_sync_axes, use_rules,
                            without_axes)

MANUAL_SYNC_MODES = ("hier", "hier_bucketed", "hier_bucketed_zero1")
BUCKETED_SYNC_MODES = ("hier_bucketed", "hier_bucketed_zero1")
CROSS_POD_MODES = ("xla", "compressed") + MANUAL_SYNC_MODES


class EFState(NamedTuple):
    """Optimizer state + int8 error-feedback residuals.

    ``residuals`` holds, per bucket, the part of each rank's (fast-axis
    reduce-scattered) gradient shard the int8 slow hop could not
    represent, carried across steps so the quantization noise telescopes
    (``collectives.compression.compressed_psum_mean_ef``).  Globally each
    residual is a flat ``(S * bucket_size,)`` f32 array sharded over
    (slow, fast) — every (pod, data) rank owns its private slice, since
    quantization error is per-rank state.
    """

    opt: Any                       # OptState | BucketedOptState
    residuals: Tuple[jax.Array, ...]


def _split_micro(batch: Dict[str, jax.Array], accum: int):
    def f(x):
        return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
    return {k: f(v) for k, v in batch.items()}


def make_loss_and_grad(model, *, accum: int):
    """Pod-local accumulated (loss, grads) over ``accum`` microbatches.

    Differentiates wrt an f32 view of the params (cast back to their
    storage dtype inside the loss, so the forward math is unchanged):
    grads then materialize and combine in f32 end-to-end.  Differentiating
    wrt the bf16 leaves directly rounds each microbatch's gradient — e.g.
    the tied embedding's lookup-scatter + logits-matmul contributions — to
    bf16 before accumulation, which breaks accum-invariance.

    Cost: the f32 view is a transient 2x-param-bytes buffer live during
    the accumulation scan (it dies before the optimizer update, which
    holds its own f32 masters).  The bucketed sync modes use
    ``collectives.bucketing.make_bucket_loss_and_grad`` instead, which
    differentiates wrt flat f32 buckets (same transient footprint, but
    no per-leaf f32 tree, flat gradient accumulation, and — in the
    zero1 mode — 1/F-sharded instead of replicated f32 masters).
    """

    def fn(params, batch):
        micro = _split_micro(batch, accum)
        dtypes = jax.tree.map(lambda p: p.dtype, params)
        params32 = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)

        def cast_loss(p32, mb):
            p = jax.tree.map(lambda q, dt: q.astype(dt), p32, dtypes)
            return model.loss(p, mb)

        def step(carry, mb):
            loss_sum, grads = carry
            (loss, _metrics), g = jax.value_and_grad(
                cast_loss, has_aux=True)(params32, mb)
            grads = jax.tree.map(lambda a, b: a + b, grads, g)
            return (loss_sum + loss, grads), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), zero_g), micro)
        inv = 1.0 / accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)

    return fn


def make_bucket_layout(params_or_shapes, mesh=None, *,
                       bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                       deterministic: bool = False
                       ) -> bucketing.BucketLayout:
    """The bucket layout the bucketed train modes derive for this mesh.

    Alignment is the fast-axis size so reduce-scatter divides every
    bucket evenly; passing the same (tree, mesh, bucket_bytes) the step
    sees — concrete params, ``jax.eval_shape`` output, either works —
    yields the exact layout, which is what ``optim.init_bucketed`` needs.

    ``deterministic=True`` (the ``deterministic_reduce`` train modes)
    aligns instead to ``lcm(fast, DETERMINISTIC_ALIGN)``, making the
    padded bucket sizes — and therefore every checkpointed flat array
    shape — identical across mesh factorizations whose fast size divides
    the constant.  That shape invariance is what lets a sharded
    checkpoint reshard *exactly* onto a re-factorized mesh.
    """
    fast_axis, _ = grad_sync_axes(mesh)
    fast = mesh.shape[fast_axis] if (mesh is not None and fast_axis) else 1
    align = det.det_align(fast) if deterministic else fast
    return bucketing.plan_buckets(params_or_shapes,
                                  bucket_bytes=bucket_bytes, align=align)


def _residual_spec(fast_axis, slow_axis) -> P:
    """PartitionSpec of one global error-feedback residual array."""
    axes = tuple(a for a in (slow_axis, fast_axis) if a)
    return P(axes) if axes else P()


def init_slow_residuals(params_or_shapes, mesh=None, *,
                        bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                        deterministic: bool = False
                        ) -> Tuple[jax.Array, ...]:
    """Zero error-feedback residuals for ``slow_error_feedback=True``.

    One flat f32 array per bucket of the layout the train step derives.
    Global size is ``S * bucket_size`` (S = slow-axis size): sharded over
    (slow, fast), each rank holds a residual the shape of its fast-axis
    reduce-scattered bucket shard.

    With ``deterministic=True`` each rank quantizes its *own full-bucket
    contribution* instead of a hierarchical shard, so the global size is
    ``R * bucket_size`` (R = total sync ranks) — invariant under mesh
    re-factorization, which is what lets the residuals reshard exactly
    on an elastic restore (the hierarchical variant's shard assignment
    follows the pod structure and cannot).
    """
    layout = make_bucket_layout(params_or_shapes, mesh,
                                bucket_bytes=bucket_bytes,
                                deterministic=deterministic)
    fast_axis, slow_axis = grad_sync_axes(mesh)
    ns = mesh.shape[slow_axis] if (mesh is not None and slow_axis) else 1
    nf = mesh.shape[fast_axis] if (mesh is not None and fast_axis) else 1
    n = ns * nf if deterministic else ns
    return tuple(jnp.zeros((n * c,), jnp.float32)
                 for c in layout.bucket_sizes)


def init_sharded_zero1(ocfg: optim.AdamWConfig, params, layout, mesh):
    """Build the ZeRO-1 opt state *already sharded* over the fast axis.

    Returns ``(BucketedOptState, shardings)`` where ``shardings`` is the
    matching tree of ``NamedSharding``s (None off-mesh).  Each rank
    materializes only its 1/F slice — a device_put after an unsharded
    init would transiently hold 3x full-model f32 on one device, the
    exact peak ZeRO-1 exists to avoid.  The single construction the
    trainer, the checkpoint bench and the reshard tests all share, so
    the state/sharding shapes cannot drift apart.
    """
    fast_axis, _ = grad_sync_axes(mesh)
    if mesh is None or not fast_axis:
        return optim.init_bucketed(ocfg, params, layout), None
    bshard = NamedSharding(mesh, P(fast_axis))
    shardings = optim.BucketedOptState(
        step=NamedSharding(mesh, P()),
        mu=(bshard,) * layout.n_buckets,
        nu=(bshard,) * layout.n_buckets,
        master=(bshard,) * layout.n_buckets)
    init_fn = jax.jit(lambda p: optim.init_bucketed(ocfg, p, layout),
                      out_shardings=shardings)
    return init_fn(params), shardings


# logical axes that shard *parameters* (vs batch/sequence activations) —
# the manual sync modes keep params replicated, so rules mapping any of
# these onto a real mesh axis would be silently ignored; reject instead
_PARAM_LOGICAL_AXES = ("embed", "heads", "kv_heads", "ff", "vocab",
                       "expert", "state", "conv", "norm", "lora")


def _check_manual_sync_rules(rules: Optional[MeshRules]) -> None:
    if rules is None or rules.mesh is None:
        return
    bad = {k: v for k, v in rules.rules.items()
           if k in _PARAM_LOGICAL_AXES and v is not None
           and PX.axes_size(rules.mesh, v) > 1}
    if bad:
        raise ValueError(
            f"manual gradient-sync modes keep params replicated, but the "
            f"rules shard parameter axes {bad} (FSDP/TP) — build rules "
            f"with make_rules(mesh, fsdp=False) or use "
            f"cross_pod_mode='xla'")


def _make_manual_sync_step(model, ocfg: optim.AdamWConfig, *, accum: int,
                           rules: Optional[MeshRules], mode: str,
                           bucket_bytes: int, slow_compress_bits: int,
                           overlap: bool = False,
                           slow_error_feedback: bool = False,
                           deterministic_reduce: bool = False):
    """The fully-manual (shard_map over pod+data) gradient-sync steps.

    With no mesh (or a 1-device one) every collective degenerates to the
    identity and the same code runs locally — that is what makes the
    single-process CPU equivalence tests possible.

    ``overlap`` pipelines consecutive buckets' syncs (bucketed modes;
    bitwise-identical results — see ``hier_reduce_bucket_shards``).
    ``slow_error_feedback`` carries int8 quantization residuals across
    steps; the step's opt-state argument then is an :class:`EFState`.
    ``deterministic_reduce`` swaps the hierarchical reduce for the
    mesh-factorization-invariant gather + fixed-tree fold
    (:mod:`repro.collectives.deterministic`): losses, grad norms and
    updates are then bitwise-identical across every (pod, data)
    factorization of the same rank count — the property the sharded
    checkpoint's reshard-on-restore acceptance test verifies.
    """
    _check_manual_sync_rules(rules)
    mesh = rules.mesh if rules is not None else None
    fast_axis, slow_axis = grad_sync_axes(mesh)
    sync_axes = tuple(a for a in (mesh.axis_names if mesh is not None
                                  else ()) if a in ("pod", "data"))
    n_sync = PX.axes_size(mesh, sync_axes)
    if n_sync == 1:
        # degenerate (single-cell) mesh: no shard_map is emitted, so the
        # axis names must not reach any collective either
        sync_axes = ()
        fast_axis = slow_axis = None
    ef = slow_error_feedback
    dt = deterministic_reduce
    lg = make_loss_and_grad(model, accum=accum)

    # inside the shard_map body the sync axes are mapped manually, so
    # model-code sharding constraints must not mention them.  Newer JAX
    # exposes the manual set for shard() to drop at trace time, but on
    # versions without that introspection the full ambient rules leak
    # through — visible only when a per-rank dim happens to be divisible
    # by the mesh size (e.g. any 2-rank mesh with per-rank batch 4), at
    # which point the partitioner rejects the constraint.  Stripping the
    # manual axes from the ambient rules is the version-independent fix;
    # per-rank the surviving constraints are all-None, exactly what the
    # divisibility check produced on the previously-working shapes.
    body_rules = (without_axes(rules, frozenset(sync_axes))
                  if rules is not None and sync_axes else rules)

    def manual_body(fn):
        def wrapped(*args):
            with use_rules(body_rules):
                return fn(*args)
        return wrapped

    def mean_loss(loss):
        if not sync_axes:
            return loss
        if dt:
            return det.det_mean(loss, sync_axes)
        return PX.psum(loss, sync_axes) / n_sync

    def layout_for(params):
        return make_bucket_layout(params, mesh, bucket_bytes=bucket_bytes,
                                  deterministic=dt)

    def hier_rank(params, batch):
        loss, grads = lg(params, batch)
        if sync_axes:
            grads = jax.tree.map(
                lambda g: hier_all_reduce_mean(
                    g, fast_axis=fast_axis, slow_axis=slow_axis,
                    compress_bits=slow_compress_bits), grads)
        return mean_loss(loss), grads

    def reduce_buckets(gbuckets, residuals):
        """The (optionally pipelined, optionally EF) per-bucket reduce.

        Returns (shards, new_residuals); residuals are ``()`` when error
        feedback is off, so rank functions can pass them through shard_map
        uniformly (an empty pytree needs no specs).
        """
        if ef:
            return bucketing.hier_reduce_bucket_shards(
                gbuckets, fast_axis=fast_axis, slow_axis=slow_axis,
                compress_bits=slow_compress_bits, overlap=overlap,
                residuals=residuals)
        shards = bucketing.hier_reduce_bucket_shards(
            gbuckets, fast_axis=fast_axis, slow_axis=slow_axis,
            compress_bits=slow_compress_bits, overlap=overlap)
        return shards, ()

    def det_reduce(gbuckets, residuals):
        """Deterministic reduce -> (full buckets, gnorm, new_residuals).

        Every rank holds the full meaned buckets; the grad norm is pure
        local arithmetic on them (no collective), so both are bitwise
        mesh-factorization-invariant.
        """
        full, new_res = det.det_reduce_bucket_full(
            gbuckets, sync_axes=sync_axes,
            compress_bits=slow_compress_bits,
            residuals=residuals if ef else None)
        return full, det.det_global_norm(full), new_res

    def bucketed_rank(params, batch, residuals):
        layout = layout_for(params)
        blg = bucketing.make_bucket_loss_and_grad(model, layout,
                                                  accum=accum)
        loss, gbuckets = blg(bucketing.flatten_to_buckets(layout, params),
                             batch)
        if dt:
            full, gnorm, new_res = det_reduce(gbuckets, residuals)
        else:
            shards, new_res = reduce_buckets(gbuckets, residuals)
            gnorm = bucketing.shard_global_norm(shards, fast_axis)
            full = bucketing.all_gather_buckets(shards,
                                                fast_axis=fast_axis)
        grads = bucketing.unflatten_from_buckets(layout, full,
                                                 dtype=jnp.float32)
        return mean_loss(loss), grads, gnorm, new_res

    def zero1_rank(layout, params, state, batch):
        opt_state, residuals = ((state.opt, state.residuals) if ef
                                else (state, ()))
        blg = bucketing.make_bucket_loss_and_grad(model, layout,
                                                  accum=accum)
        # forward from the (replicated) storage params, not from an
        # all-gather of the masters: params are the previous step's
        # gathered masters cast to storage dtype, and the forward casts
        # the buckets to storage dtype anyway, so loss/grads are
        # bit-identical — and the fast tier carries one full-model
        # gather per step (updated params) instead of two
        loss, gbuckets = blg(bucketing.flatten_to_buckets(layout, params),
                             batch)
        if dt:
            full, gnorm, new_res = det_reduce(gbuckets, residuals)
            shards = det.det_fast_shards(full, fast_axis)
        else:
            shards, new_res = reduce_buckets(gbuckets, residuals)
            gnorm = bucketing.shard_global_norm(shards, fast_axis)
        new_state, om = optim.apply_flat(ocfg, shards, opt_state,
                                         gnorm=gnorm)
        new_pb = bucketing.all_gather_buckets(new_state.master,
                                              fast_axis=fast_axis)
        params = bucketing.unflatten_from_buckets(layout, new_pb)
        if ef:
            new_state = EFState(new_state, new_res)
        return params, new_state, {"loss": mean_loss(loss), **om}

    def batch_specs(batch):
        return jax.tree.map(lambda _: P(sync_axes), batch)

    def residual_specs(layout):
        if not ef:
            return ()
        return (_residual_spec(fast_axis, slow_axis),) * layout.n_buckets

    if mode == "hier_bucketed_zero1":
        def step(params, opt_state, batch):
            layout = layout_for(params)
            if not sync_axes:
                return zero1_rank(layout, params, opt_state, batch)
            bspec = P(fast_axis) if fast_axis else P()
            state_specs = optim.BucketedOptState(
                step=P(), mu=(bspec,) * layout.n_buckets,
                nu=(bspec,) * layout.n_buckets,
                master=(bspec,) * layout.n_buckets)
            if ef:
                state_specs = EFState(state_specs, residual_specs(layout))
            pspecs = jax.tree.map(lambda _: P(), params)
            return PX.shard_map(
                manual_body(functools.partial(zero1_rank, layout)),
                mesh=mesh,
                in_specs=(pspecs, state_specs, batch_specs(batch)),
                out_specs=(pspecs, state_specs,
                           {"loss": P(), "lr": P(), "grad_norm": P()}),
                check_vma=False, axis_names=set(sync_axes),
            )(params, opt_state, batch)
        return step

    def step(params, opt_state, batch):
        inner_opt = opt_state.opt if ef else opt_state
        ef_res = opt_state.residuals if ef else ()
        new_res = ()
        if not sync_axes:
            if mode == "hier_bucketed":
                loss, grads, gnorm, new_res = bucketed_rank(
                    params, batch, ef_res)
            else:
                loss, grads = hier_rank(params, batch)
                gnorm = None
        elif mode == "hier_bucketed":
            layout = layout_for(params)
            pspecs = jax.tree.map(lambda _: P(), params)
            rspecs = residual_specs(layout)
            loss, grads, gnorm, new_res = PX.shard_map(
                manual_body(bucketed_rank), mesh=mesh,
                in_specs=(pspecs, batch_specs(batch), rspecs),
                out_specs=(P(), pspecs, P(), rspecs),
                check_vma=False, axis_names=set(sync_axes),
            )(params, batch, ef_res)
        else:
            pspecs = jax.tree.map(lambda _: P(), params)
            loss, grads = PX.shard_map(
                manual_body(hier_rank), mesh=mesh,
                in_specs=(pspecs, batch_specs(batch)),
                out_specs=(P(), pspecs),
                check_vma=False, axis_names=set(sync_axes),
            )(params, batch)
            gnorm = None
        params, inner_opt, om = optim.apply(ocfg, params, grads,
                                            inner_opt, gnorm=gnorm)
        opt_state = EFState(inner_opt, new_res) if ef else inner_opt
        return params, opt_state, {"loss": loss, **om}

    return step


def make_train_step(model, ocfg: optim.AdamWConfig, *, accum: int = 1,
                    rules: Optional[MeshRules] = None,
                    cross_pod_mode: str = "xla",
                    bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                    slow_compress_bits: int = 0,
                    overlap: bool = False,
                    slow_error_feedback: bool = False,
                    deterministic_reduce: bool = False):
    """Returns step(params, opt_state, batch) -> (params, opt, metrics).

    ``overlap=True`` (bucketed modes only) software-pipelines the
    per-bucket hierarchical sync: bucket i+1's fast-axis reduce-scatter
    is issued under bucket i's slow hop.  Bitwise-identical losses; a
    no-op on single-bucket layouts and size-1 meshes.

    ``slow_error_feedback=True`` (bucketed modes, requires
    ``slow_compress_bits=8``) carries each rank's int8 quantization
    residual across steps.  The step then takes/returns an
    :class:`EFState` wrapping the optimizer state (build the residuals
    with :func:`init_slow_residuals`).

    ``deterministic_reduce=True`` (bucketed modes) replaces the
    hierarchical schedule with the mesh-factorization-invariant gather +
    fixed-tree fold: the whole step is then bitwise-identical across
    (pod, data) factorizations of the same rank count, so a sharded
    checkpoint reshard-restored onto a repacked mesh continues the exact
    loss curve.  Bandwidth-heavier than the hierarchical schedule (the
    gather moves every rank's contribution) — the
    verification/elasticity schedule, not the throughput one.  Mutually
    exclusive with ``overlap`` (there is no two-tier pipeline to
    overlap); composes with ``slow_compress_bits``/``slow_error_feedback``
    (residuals from ``init_slow_residuals(..., deterministic=True)``).
    """
    if cross_pod_mode not in CROSS_POD_MODES:
        raise ValueError(f"unknown cross_pod_mode {cross_pod_mode!r}; "
                         f"known: {CROSS_POD_MODES}")
    if ((overlap or slow_error_feedback or deterministic_reduce)
            and cross_pod_mode not in BUCKETED_SYNC_MODES):
        raise ValueError(
            f"overlap/slow_error_feedback/deterministic_reduce apply to "
            f"the bucketed sync modes {BUCKETED_SYNC_MODES}, not "
            f"{cross_pod_mode!r}")
    if slow_error_feedback and slow_compress_bits != 8:
        raise ValueError(
            "slow_error_feedback carries int8 quantization residuals; "
            f"it requires slow_compress_bits=8 (got {slow_compress_bits})")
    if deterministic_reduce and overlap:
        raise ValueError(
            "deterministic_reduce has no two-tier pipeline to overlap; "
            "pick one of overlap / deterministic_reduce")
    mesh = rules.mesh if rules is not None else None
    if (cross_pod_mode == "compressed" and mesh is not None
            and "pod" in mesh.axis_names and mesh.shape["pod"] > 1):
        # the partial shard_map (manual 'pod', auto 'data') this mode
        # used fatally aborts XLA on (pod, data) meshes under the pinned
        # jax 0.4.37; the bucketed modes subsume it (same int8 slow hop,
        # fewer collectives), so the mode is a clear error, not a crash
        raise NotImplementedError(
            "cross_pod_mode='compressed' is not supported on multi-pod "
            "meshes (XLA aborts on its partial shard_map under the "
            "pinned jax); use cross_pod_mode='hier_bucketed' with "
            "slow_compress_bits=8 for the int8 cross-pod hop")
    if cross_pod_mode in MANUAL_SYNC_MODES:
        return _make_manual_sync_step(
            model, ocfg, accum=accum, rules=rules, mode=cross_pod_mode,
            bucket_bytes=bucket_bytes,
            slow_compress_bits=slow_compress_bits, overlap=overlap,
            slow_error_feedback=slow_error_feedback,
            deterministic_reduce=deterministic_reduce)
    lg = make_loss_and_grad(model, accum=accum)

    def base_step(params, opt_state, batch):
        loss, grads = lg(params, batch)
        params, opt_state, om = optim.apply(ocfg, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return base_step


def make_jitted_train_step(model, ocfg, *, accum, rules,
                           param_shardings=None, opt_shardings=None,
                           batch_sharding=None, cross_pod_mode="xla",
                           bucket_bytes=bucketing.DEFAULT_BUCKET_BYTES,
                           slow_compress_bits=0, overlap=False,
                           slow_error_feedback=False,
                           deterministic_reduce=False):
    step = make_train_step(model, ocfg, accum=accum, rules=rules,
                           cross_pod_mode=cross_pod_mode,
                           bucket_bytes=bucket_bytes,
                           slow_compress_bits=slow_compress_bits,
                           overlap=overlap,
                           slow_error_feedback=slow_error_feedback,
                           deterministic_reduce=deterministic_reduce)

    def wrapped(params, opt_state, batch):
        with use_rules(rules):
            return step(params, opt_state, batch)

    kw = {}
    if param_shardings is not None:
        kw["in_shardings"] = (param_shardings, opt_shardings,
                              batch_sharding)
        kw["out_shardings"] = (param_shardings, opt_shardings, None)
    return jax.jit(wrapped, donate_argnums=(0, 1), **kw)


def wrap_ef_state(params, opt_state, opt_shardings, mesh, *,
                  bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                  deterministic: bool = False):
    """Wrap an optimizer state (and its shardings, when sharded) with
    zero error-feedback residuals for ``slow_error_feedback=True``."""
    res = init_slow_residuals(params, mesh, bucket_bytes=bucket_bytes,
                              deterministic=deterministic)
    fast_axis, slow_axis = grad_sync_axes(mesh)
    if mesh is not None and (fast_axis or slow_axis):
        rshard = NamedSharding(mesh, _residual_spec(fast_axis, slow_axis))
        res = tuple(jax.device_put(r, rshard) for r in res)
        if opt_shardings is not None:
            opt_shardings = EFState(opt_shardings, (rshard,) * len(res))
    return EFState(opt_state, res), opt_shardings


def init_train_state(model, ocfg: optim.AdamWConfig, *,
                     rules: Optional[MeshRules] = None, seed: int = 0,
                     cross_pod_mode: str = "xla",
                     bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                     slow_error_feedback: bool = False,
                     deterministic_reduce: bool = False):
    """Initial ``(params, opt_state, opt_shardings, layout)`` for a mode.

    The single state construction the Trainer, the HLO lint matrix
    (``train_step_hlo``) and the benches share, so the state/layout a
    step function expects cannot drift from what callers build:
    ``hier_bucketed_zero1`` needs the fast-axis-sharded
    :class:`~repro.optim.BucketedOptState` over the *same*
    ``(bucket_bytes, deterministic)`` layout the step derives, and
    ``slow_error_feedback`` wraps it in an :class:`EFState`.
    ``opt_shardings``/``layout`` are None outside the zero1 mode.
    """
    params = model.init(jax.random.key(seed))
    mesh = rules.mesh if rules is not None else None
    opt_shardings = None
    layout = None
    if cross_pod_mode == "hier_bucketed_zero1":
        layout = make_bucket_layout(params, mesh,
                                    bucket_bytes=bucket_bytes,
                                    deterministic=deterministic_reduce)
        opt_state, opt_shardings = init_sharded_zero1(
            ocfg, params, layout, mesh)
    else:
        opt_state = optim.init(ocfg, params)
    if slow_error_feedback:
        opt_state, opt_shardings = wrap_ef_state(
            params, opt_state, opt_shardings, mesh,
            bucket_bytes=bucket_bytes,
            deterministic=deterministic_reduce)
    return params, opt_state, opt_shardings, layout


# ---------------------------------------------------------------------------
# static-analysis hooks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainStepHlo:
    """Both textual HLO dialects of one lowered+compiled train step.

    No single print carries every statically checkable contract, so the
    lint rules get both: ``lowered_text`` (``lowered.as_text("hlo")``,
    pre-optimization) holds the ``buffer_donor`` donation offers and the
    ``opt-barrier`` ops the backend consumes before scheduling;
    ``compiled_text`` (``compiled.as_text()``, post-optimization) holds
    the realized ``input_output_alias`` pairs, the scheduled collective
    mix and ``known_trip_count`` loop annotations.
    """

    lowered_text: str
    compiled_text: str
    n_buckets: int                 # 0 for the non-bucketed modes
    donated_args: int              # leaves in the donated (params, opt)
    grad_bytes: int                # total f32 gradient bytes per step


def train_step_hlo(model, ocfg: optim.AdamWConfig, *, rules: MeshRules,
                   accum: int = 1, seed: int = 0, batch_size: int = 8,
                   seq_len: int = 16, cross_pod_mode: str = "xla",
                   bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                   slow_compress_bits: int = 0, overlap: bool = False,
                   slow_error_feedback: bool = False,
                   deterministic_reduce: bool = False) -> TrainStepHlo:
    """Lower + compile one train step and return its HLO (both dialects).

    The hook behind ``scripts/lint_hlo.py``: builds the real initial
    state via :func:`init_train_state` (so the lowered program is the
    one training runs, donation and all) on a synthetic tokens/targets
    batch, and captures the pre- and post-optimization prints.
    """
    params, opt_state, _, layout = init_train_state(
        model, ocfg, rules=rules, seed=seed,
        cross_pod_mode=cross_pod_mode, bucket_bytes=bucket_bytes,
        slow_error_feedback=slow_error_feedback,
        deterministic_reduce=deterministic_reduce)
    mesh = rules.mesh if rules is not None else None
    if layout is None and cross_pod_mode in BUCKETED_SYNC_MODES:
        layout = make_bucket_layout(params, mesh,
                                    bucket_bytes=bucket_bytes,
                                    deterministic=deterministic_reduce)
    batch = {"tokens": jnp.zeros((batch_size, seq_len), jnp.int32),
             "targets": jnp.zeros((batch_size, seq_len), jnp.int32)}
    step = make_jitted_train_step(
        model, ocfg, accum=accum, rules=rules,
        cross_pod_mode=cross_pod_mode, bucket_bytes=bucket_bytes,
        slow_compress_bits=slow_compress_bits, overlap=overlap,
        slow_error_feedback=slow_error_feedback,
        deterministic_reduce=deterministic_reduce)
    if mesh is not None:
        with mesh:
            lowered = step.lower(params, opt_state, batch)
    else:
        lowered = step.lower(params, opt_state, batch)
    compiled = lowered.compile()
    return TrainStepHlo(
        lowered_text=lowered.as_text("hlo"),
        compiled_text=compiled.as_text(),
        n_buckets=layout.n_buckets if layout is not None else 0,
        donated_args=len(jax.tree.leaves((params, opt_state))),
        grad_bytes=sum(4 * int(np.prod(p.shape))
                       for p in jax.tree.leaves(params)))


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    accum: int = 1
    async_ckpt: bool = True
    heartbeat_timeout_s: float = 60.0
    cross_pod_mode: str = "xla"
    bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES
    slow_compress_bits: int = 0
    overlap: bool = False
    slow_error_feedback: bool = False
    deterministic_reduce: bool = False
    # sharded (per-rank shard + manifest) checkpoint format; False falls
    # back to the legacy gathered per-leaf format (repro.checkpoint)
    save_sharded: bool = True
    # recovery knobs (repro.faults): bounded exponential-backoff retries
    # for transient I/O during checkpoint save/restore, and whether a
    # corrupt committed step at resume is quarantined on disk with
    # fallback to the previous committed step (RecoveryReport returned
    # in the run output) instead of raising
    max_restore_retries: int = 0
    fallback_on_corrupt: bool = False


class Trainer:
    def __init__(self, model, ocfg: optim.AdamWConfig,
                 tcfg: TrainerConfig, data_cfg: DataConfig, *,
                 rules: Optional[MeshRules] = None,
                 failure_hook: Optional[Callable[[int], bool]] = None):
        self.model = model
        self.ocfg = ocfg
        self.tcfg = tcfg
        self.data_cfg = data_cfg
        self.rules = rules
        self.failure_hook = failure_hook
        self.heartbeat = HeartbeatMonitor(
            timeout_s=tcfg.heartbeat_timeout_s)
        self.straggler = StragglerDetector()
        self.step_fn = make_jitted_train_step(
            model, ocfg, accum=tcfg.accum, rules=rules,
            cross_pod_mode=tcfg.cross_pod_mode,
            bucket_bytes=tcfg.bucket_bytes,
            slow_compress_bits=tcfg.slow_compress_bits,
            overlap=tcfg.overlap,
            slow_error_feedback=tcfg.slow_error_feedback,
            deterministic_reduce=tcfg.deterministic_reduce)
        self.history: list = []

    def _init_state(self, seed: int = 0):
        params, opt_state, self._opt_shardings, self._layout = \
            init_train_state(
                self.model, self.ocfg, rules=self.rules, seed=seed,
                cross_pod_mode=self.tcfg.cross_pod_mode,
                bucket_bytes=self.tcfg.bucket_bytes,
                slow_error_feedback=self.tcfg.slow_error_feedback,
                deterministic_reduce=self.tcfg.deterministic_reduce)
        return params, opt_state

    def run(self, *, seed: int = 0, resume: bool = True
            ) -> Dict[str, Any]:
        # sharding constraints inside the jitted step trace against the
        # ambient mesh context; without it any --data-parallel launch
        # fails at first trace (tests enter the mesh themselves, which
        # is why only the launcher path ever hit this)
        mesh = self.rules.mesh if self.rules is not None else None
        if mesh is not None:
            with mesh:
                return self._run(seed=seed, resume=resume)
        return self._run(seed=seed, resume=resume)

    def _restore_policy(self, params, opt_state):
        """Per-leaf shape-mismatch policy for reshard-on-restore.

        Flat ZeRO-1 buckets (masters/moments) tolerate padded-size
        drift between mesh factorizations (PAD_FLAT: the tail past the
        live prefix is zeros on both sides); hierarchical EF residuals
        whose global size follows the pod count are re-zeroed (ZERO —
        deterministic-mode residuals are rank-count-keyed, so their
        shapes match and restore exactly); everything else must match
        exactly.
        """
        exact = functools.partial(jax.tree.map, lambda _: ckpt_lib.EXACT)

        def opt_policy(o):
            if isinstance(o, optim.BucketedOptState):
                nb = len(o.master)
                return optim.BucketedOptState(
                    step=ckpt_lib.EXACT,
                    mu=(ckpt_lib.PAD_FLAT,) * nb,
                    nu=(ckpt_lib.PAD_FLAT,) * nb,
                    master=(ckpt_lib.PAD_FLAT,) * nb)
            return exact(o)

        if isinstance(opt_state, EFState):
            pol = EFState(opt_policy(opt_state.opt),
                          (ckpt_lib.ZERO,) * len(opt_state.residuals))
        else:
            pol = opt_policy(opt_state)
        return (exact(params), pol)

    def _run(self, *, seed: int, resume: bool) -> Dict[str, Any]:
        from repro.faults.recovery import restore_with_fallback
        from repro.faults.retry import RetryPolicy
        tcfg = self.tcfg
        start = 0
        recovery = None
        retry = RetryPolicy(max_retries=tcfg.max_restore_retries)
        params, opt_state = self._init_state(seed)
        mesh = self.rules.mesh if self.rules is not None else None
        if resume:
            last = ckpt_lib.latest_step(tcfg.ckpt_dir)
            if last is not None:
                # restore the zero1 state straight onto its fast-axis
                # shards — an unsharded restore would replicate the full
                # f32 masters on every device until the first step
                shardings = ((None, self._opt_shardings)
                             if self._opt_shardings is not None else None)
                policy = self._restore_policy(params, opt_state)
                if tcfg.fallback_on_corrupt:
                    start, (params, opt_state), recovery = \
                        restore_with_fallback(
                            tcfg.ckpt_dir, (params, opt_state),
                            shardings=shardings, policy=policy,
                            layout=self._layout, retry=retry)
                else:
                    start, (params, opt_state) = ckpt_lib.restore_auto(
                        ckpt_lib.step_dir(tcfg.ckpt_dir, last),
                        (params, opt_state), shardings=shardings,
                        policy=policy, layout=self._layout, retry=retry)
        corpus = SyntheticCorpus(self.data_cfg)
        prefetch = Prefetcher(corpus, start_step=start)
        pending = None
        try:
            for step in range(start, tcfg.n_steps):
                if self.failure_hook and self.failure_hook(step):
                    raise RuntimeError(f"injected failure at step {step}")
                t0 = time.perf_counter()
                _, batch = prefetch.next()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                dt = time.perf_counter() - t0
                self.heartbeat.beat(worker=0, t=time.time())
                self.straggler.record(dt)
                if step % tcfg.log_every == 0:
                    self.history.append(
                        {"step": step,
                         "loss": float(metrics["loss"]),
                         "sec_per_step": dt})
                if (step + 1) % tcfg.ckpt_every == 0:
                    if pending is not None:
                        pending.join()
                    sdir = ckpt_lib.step_dir(tcfg.ckpt_dir, step + 1)
                    if tcfg.save_sharded:
                        pending = ckpt_lib.save_sharded(
                            sdir, step + 1, (params, opt_state),
                            layout=self._layout, mesh=mesh,
                            blocking=not tcfg.async_ckpt)
                    else:
                        pending = legacy_ckpt.save(
                            sdir, step + 1, (params, opt_state),
                            blocking=not tcfg.async_ckpt)
        finally:
            if pending is not None:
                pending.join()
            prefetch.close()
        return {"params": params, "opt_state": opt_state,
                "history": self.history,
                "stragglers": self.straggler.summary(),
                "recovery": recovery}
