"""Mamba2 (SSD) blocks — chunked scan in pure jnp (the Pallas kernel in
``repro.kernels.mamba_scan`` implements the same chunked algorithm; this
module is the XLA fallback and the numerical reference).

Math follows "Transformers are SSMs" (Mamba-2), ssd_minimal_discrete:
    h_{t} = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t
    y_t   = C_t . h_t + D x_t
computed chunk-parallel: within-chunk quadratic form + cross-chunk carried
state.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import layers as L
from repro.sharding import shard


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------

def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) lower-tri segment sums.

    out[t, s] = sum_{r=s+1..t} a_r  (decay applied moving from s to t).
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # b_t - b_s
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel SSD.

    x:  (Bt, S, H, P)   inputs per head
    dt: (Bt, S, H)      positive step sizes
    A:  (H,)            negative decay rates
    B:  (Bt, S, G, N)   input projections (G groups, H % G == 0)
    C:  (Bt, S, G, N)   output projections
    Returns (y (Bt,S,H,P), final_state (Bt,H,P,N)).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xf = (x * dt[..., None]).astype(jnp.float32)     # discretized input
    la = dt.astype(jnp.float32) * A.astype(jnp.float32)  # (Bt,S,H) log decay
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    # reshape to chunks
    xc = xf.reshape(Bt, nc, chunk, H, P)
    lac = la.reshape(Bt, nc, chunk, H)
    Bc = Bf.reshape(Bt, nc, chunk, G, N)
    Cc = Cf.reshape(Bt, nc, chunk, G, N)

    # ---- within-chunk (diagonal blocks) ----
    Lmat = jnp.exp(_segsum(jnp.moveaxis(lac, 3, 2)))     # (Bt,nc,H,Q,Q)
    # scores[t,s] = C_t . B_s, grouped
    Bg = Bc.reshape(Bt, nc, chunk, G, 1, N)
    Cg = Cc.reshape(Bt, nc, chunk, G, 1, N)
    CB = jnp.einsum("bnqgjN,bnsgjN->bngjqs",
                    jnp.broadcast_to(Cg, (Bt, nc, chunk, G, rep, N)),
                    jnp.broadcast_to(Bg, (Bt, nc, chunk, G, rep, N))
                    ).reshape(Bt, nc, G * rep, chunk, chunk)
    # order heads as g*rep+j to match h = g*rep + j layout
    W = CB * Lmat                                        # (Bt,nc,H,Q,Q)
    y_diag = jnp.einsum("bnhts,bnshp->bnthp", W, xc)

    # ---- chunk states ----
    b_end = jnp.cumsum(lac, axis=2)                      # (Bt,nc,Q,H)
    total = b_end[:, :, -1, :]                           # (Bt,nc,H)
    decay_states = jnp.exp(total[:, :, None, :] - b_end)  # (Bt,nc,Q,H)
    # state_c = sum_s decay * B_s x_s^T  -> (Bt,nc,H,P,N)
    Bh = jnp.repeat(Bc, rep, axis=3)                     # (Bt,nc,Q,H,N)
    states = jnp.einsum("bcqh,bcqhN,bcqhp->bchpN",
                        decay_states, Bh, xc)

    # ---- cross-chunk recurrence ----
    if init_state is None:
        s0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    else:
        s0 = init_state.astype(jnp.float32)

    @jax.checkpoint
    def step(s_prev, inp):
        st, dec = inp                                    # dec: (Bt,H)
        s_new = s_prev * jnp.exp(dec)[:, :, None, None] + st
        return s_new, s_prev

    tot_t = jnp.moveaxis(total, 1, 0)                    # (nc,Bt,H)
    st_t = jnp.moveaxis(states, 1, 0)                    # (nc,Bt,H,P,N)
    final, prev_states = jax.lax.scan(step, s0, (st_t, tot_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (Bt,nc,H,P,N)

    # ---- inter-chunk output ----
    Ch = jnp.repeat(Cc, rep, axis=3)                     # (Bt,nc,Q,H,N)
    state_decay = jnp.exp(b_end)                         # (Bt,nc,Q,H)
    y_off = jnp.einsum("bcqhN,bchpN,bcqh->bcqhp",
                       Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bt, S, H, P)
    return y.astype(x.dtype), final


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token recurrence.  state: (Bt,H,P,N); x_t: (Bt,H,P);
    dt_t: (Bt,H); B_t/C_t: (Bt,G,N)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)  # (Bt,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    xd = (x_t * dt_t[..., None]).astype(jnp.float32)
    state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xd, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x_t.dtype), state


def ssd_sequential_ref(x, dt, A, B, C, *, init_state=None):
    """Token-by-token oracle (tests only)."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    state = (jnp.zeros((Bt, H, P, N), jnp.float32)
             if init_state is None else init_state.astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp
        y, state = ssd_step(state, x_t, dt_t, A, B_t, C_t)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ArchConfig, dtype=L.DEFAULT_DTYPE):
    s = cfg.ssm
    D = cfg.d_model
    Di = s.d_inner(D)
    H = s.n_heads(D)
    G, N, K = 1, s.d_state, s.d_conv
    ks = jax.random.split(key, 8)
    dt_init = jnp.log(jnp.exp(
        jnp.linspace(1e-3, 1e-1, H).astype(jnp.float32)) - 1.0)
    return {
        "wz": L.dense_init(ks[0], D, Di, dtype=dtype),
        "wx": L.dense_init(ks[1], D, Di, dtype=dtype),
        "wB": L.dense_init(ks[2], D, G * N, dtype=dtype),
        "wC": L.dense_init(ks[3], D, G * N, dtype=dtype),
        "wdt": L.dense_init(ks[4], D, H, dtype=dtype),
        "conv_x": (jax.random.normal(ks[5], (Di, K), jnp.float32)
                   / math.sqrt(K)).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (G * N, K), jnp.float32)
                   / math.sqrt(K)).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (G * N, K), jnp.float32)
                   / math.sqrt(K)).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),    # A = -exp(0) = -1
        "Dskip": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_init,
        "norm": {"w": jnp.ones((Di,), jnp.float32)},
        "out": L.dense_init(ks[4], Di, D, dtype=dtype),
    }


def mamba_logical_axes(cfg: ArchConfig):
    return {
        "wz": ("embed", "heads"), "wx": ("embed", "heads"),
        "wB": ("embed", None), "wC": ("embed", None),
        "wdt": ("embed", None),
        "conv_x": ("heads", None), "conv_B": (None, None),
        "conv_C": (None, None),
        "A_log": (None,), "Dskip": (None,), "dt_bias": (None,),
        "norm": {"w": ("heads",)},
        "out": ("heads", "embed"),
    }


def _mamba_proj(x, p, cfg: ArchConfig):
    s = cfg.ssm
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xi = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bp = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cp = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return z, xi, Bp, Cp, dt


def mamba_apply(x, p, cfg: ArchConfig, *, use_kernel: bool = False):
    """Full-sequence (train / prefill) Mamba2 block.  x: (B,S,D)."""
    s = cfg.ssm
    B_, S, D = x.shape
    Di = s.d_inner(D)
    H = s.n_heads(D)
    G, N = 1, s.d_state
    z, xi, Bp, Cp, dt = _mamba_proj(x, p, cfg)
    xi = jax.nn.silu(L.causal_conv1d(xi, p["conv_x"]))
    Bp = jax.nn.silu(L.causal_conv1d(Bp, p["conv_B"]))
    Cp = jax.nn.silu(L.causal_conv1d(Cp, p["conv_C"]))
    xh = xi.reshape(B_, S, H, s.head_dim)
    xh = shard(xh, "batch", None, "heads", None)
    A = -jnp.exp(p["A_log"])
    Bg = Bp.reshape(B_, S, G, N)
    Cg = Cp.reshape(B_, S, G, N)
    if use_kernel:
        from repro.kernels.mamba_scan import ops as mops
        y, _ = mops.ssd(xh, dt, A, Bg, Cg, chunk=s.chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bg, Cg,
                           chunk=min(s.chunk, S))
    y = y + xh * p["Dskip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, S, Di)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  p["norm"]["w"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out"])


def mamba_make_cache(cfg: ArchConfig, n_blocks: int, batch: int,
                     dtype=L.DEFAULT_DTYPE):
    s = cfg.ssm
    D = cfg.d_model
    Di, H = s.d_inner(D), s.n_heads(D)
    G, N, K = 1, s.d_state, s.d_conv
    return {
        "conv_x": jnp.zeros((n_blocks, batch, K - 1, Di), dtype),
        "conv_B": jnp.zeros((n_blocks, batch, K - 1, G * N), dtype),
        "conv_C": jnp.zeros((n_blocks, batch, K - 1, G * N), dtype),
        "state": jnp.zeros((n_blocks, batch, H, s.head_dim, N), jnp.float32),
    }


def mamba_cache_axes():
    return {"conv_x": (None, "kv_batch", None, "heads"),
            "conv_B": (None, "kv_batch", None, None),
            "conv_C": (None, "kv_batch", None, None),
            "state": (None, "kv_batch", "heads", None, None)}


def mamba_decode(x, p, cfg: ArchConfig, cache_blk):
    """Single-token step.  x: (B,1,D); cache_blk: one block's cache slice."""
    s = cfg.ssm
    B_, _, D = x.shape
    H = s.n_heads(D)
    G, N, K = 1, s.d_state, s.d_conv
    z, xi, Bp, Cp, dt = _mamba_proj(x, p, cfg)

    def conv_step(seg, w, state):
        full = jnp.concatenate([state.astype(seg.dtype), seg], axis=1)
        out = jnp.einsum("bkc,ck->bc", full, w.astype(seg.dtype))[:, None]
        return jax.nn.silu(out), full[:, 1:]

    xi, cx = conv_step(xi, p["conv_x"], cache_blk["conv_x"])
    Bp, cb = conv_step(Bp, p["conv_B"], cache_blk["conv_B"])
    Cp, cc = conv_step(Cp, p["conv_C"], cache_blk["conv_C"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_step(cache_blk["state"],
                        xi[:, 0].reshape(B_, H, s.head_dim),
                        dt[:, 0], A,
                        Bp[:, 0].reshape(B_, G, N),
                        Cp[:, 0].reshape(B_, G, N))
    y = y + xi[:, 0].reshape(B_, H, s.head_dim) * \
        p["Dskip"].astype(y.dtype)[None, :, None]
    y = y.reshape(B_, 1, -1)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  p["norm"]["w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    new_cache = {"conv_x": cx.astype(cache_blk["conv_x"].dtype),
                 "conv_B": cb.astype(cache_blk["conv_B"].dtype),
                 "conv_C": cc.astype(cache_blk["conv_C"].dtype),
                 "state": state}
    return out, new_cache
