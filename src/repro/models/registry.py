"""Model + config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

_CONFIG_MODULES = {
    "whisper-tiny": "repro.configs.whisper_tiny",
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "glm4-9b": "repro.configs.glm4_9b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "llama3.2-1b": "repro.configs.llama32_1b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
}

ARCH_IDS = tuple(_CONFIG_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    import importlib
    if arch_id not in _CONFIG_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_CONFIG_MODULES[arch_id]).CONFIG


def build_model(cfg: ArchConfig, *, remat: bool = True):
    from repro.models.transformer import TransformerLM
    from repro.models.xlstm import XLSTMLM
    from repro.models.zamba import ZambaLM
    if cfg.family == "hybrid":
        return ZambaLM(cfg, remat=remat)
    if cfg.family == "ssm":
        return XLSTMLM(cfg, remat=remat)
    return TransformerLM(cfg, remat=remat)


def get_model(arch_id: str, *, remat: bool = True):
    cfg = get_config(arch_id)
    return cfg, build_model(cfg, remat=remat)


# ---------------------------------------------------------------------------
# parameter counting via eval_shape (no duplication of init math)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _param_tree_sizes(arch_id: str) -> Dict[str, int]:
    import math
    cfg = get_config(arch_id)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    # math.prod, NOT jnp.prod: stacked leaves exceed int32
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
    return {"total": total}


def param_count(cfg: ArchConfig) -> int:
    return _param_tree_sizes(cfg.arch_id)["total"]


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: only top-k + shared experts count)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    # routed expert params not selected are inactive
    per_expert = 3 * cfg.d_model * m.d_ff
    n_moe_layers = cfg.n_layers - m.first_dense_layers
    inactive = n_moe_layers * (m.n_experts_padded - m.top_k) * per_expert
    return total - inactive


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw: Dict[str, Any] = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family not in
                     ("hybrid", "ssm") else 8),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=0,
    )
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(cfg.mla, kv_lora_rank=64,
                                        qk_nope_dim=32, qk_rope_dim=16,
                                        v_head_dim=32)
        kw["head_dim"] = 32
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_routed=8, n_padded=8, top_k=2, d_ff=64,
            n_shared=min(cfg.moe.n_shared, 2),
            dense_d_ff=128 if cfg.moe.first_dense_layers else 0)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32,
                                        chunk=32)
    if cfg.hybrid_attn_every:
        kw["hybrid_attn_every"] = 3
    if cfg.slstm_every:
        kw["slstm_every"] = 4
        kw["n_layers"] = 8
    if cfg.is_encdec:
        kw["n_enc_layers"] = 2
        kw["enc_seq_len"] = 16
    if cfg.cross_every:
        kw["cross_every"] = 2
        kw["n_layers"] = 4
        kw["n_media_tokens"] = 8
    return dataclasses.replace(cfg, **kw)
