"""Core neural-net primitives (pure functional, pytree params).

All matmul-bearing ops keep params in bf16 and compute norms/softmax/router
logits in f32.  Tensors are annotated with logical-axis sharding constraints
(`repro.sharding.shard`) which resolve to physical mesh axes under a rules
context and to no-ops on a single device.
"""
from __future__ import annotations

import math
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard

DEFAULT_DTYPE = jnp.bfloat16

def _p_tile_bf16() -> bool:
    """§Perf knob: bf16 probability tiles in blocked attention (read at
    trace time so launchers can set it per-invocation)."""
    return os.environ.get("REPRO_ATTN_P_BF16", "0") == "1"


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, dtype=DEFAULT_DTYPE,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out),
                                        jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, *, dtype=DEFAULT_DTYPE) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d),
                                        jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, weight: jax.Array, bias: Optional[jax.Array],
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_apply(x, p, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"], eps)
    return layernorm(x, p["w"], p.get("b"), eps)


def norm_init(d: int, kind: str, dtype=jnp.float32):
    p = {"w": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# rotary embeddings (partial-rotary supported)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, rope_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> cos/sin of shape (..., rope_dim//2)."""
    half = rope_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               rope_dim: int) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, rope_dim//2) or (S, rope_dim//2)."""
    if rope_dim == 0:
        return x
    rot, rest = x[..., :rope_dim], x[..., rope_dim:]
    half = rope_dim // 2
    x1, x2 = rot[..., :half], rot[..., half:]
    if cos.ndim == 2:            # (S, half) -> broadcast over batch & heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:                         # (B, S, half)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * c - x2f * s
    o2 = x2f * c + x1f * s
    out = jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, rest], axis=-1) if rest.shape[-1] else out


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """Grouped-query scores without materializing repeated KV.

    q: (B, Sq, Kv, G, D), k: (B, Sk, Kv, D) -> (B, Kv, G, Sq, Sk) f32
    """
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p: (B, Kv, G, Sq, Sk) f32; v: (B, Sk, Kv, D) -> (B, Sq, Kv, G, D).

    Probabilities stay f32 and the PV product accumulates in f32 (flash-
    kernel convention); rounding p to bf16 costs ~0.4% per weight, which
    is what pushed the expanded-vs-absorbed MLA logit diff over tolerance.
    """
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v,
                      preferred_element_type=jnp.float32)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset: int = 0,
                      block_q: int = 512, block_k: int = 1024,
                      softcap: float = 0.0) -> jax.Array:
    """Memory-bounded online-softmax attention (pure jnp; flash-style).

    q: (B, Sq, H, D); k/v: (B, Sk, Kv, D).  GQA handled by grouped einsum (no
    KV repetition).  The Pallas flash kernel is the TPU production path; this
    is the XLA fallback / oracle with identical math.
    """
    B, Sq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // Kv
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    while Sq % block_q:            # non-power-of-two seqs (whisper's 1500)
        block_q -= 1
    while Sk % block_k:
        block_k -= 1
    nq, nk = Sq // block_q, Sk // block_k

    qr = q.reshape(B, nq, block_q, Kv, G, D)
    kr = k.reshape(B, nk, block_k, Kv, D)
    vr = v.reshape(B, nk, block_k, Kv, Dv)

    q_pos_base = jnp.arange(block_q)
    k_pos_base = jnp.arange(block_k)

    def q_block(carry, inputs):
        qi, qb = inputs            # qb: (B, block_q, Kv, G, D)
        q_pos = q_offset + qi * block_q + q_pos_base

        def kv_block(acc, kin):
            ki, kb, vb = kin
            m_prev, l_prev, o_prev = acc
            s = _gqa_scores(qb, kb) * scale      # (B,Kv,G,bq,bk) f32
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            if causal:
                k_pos = ki * block_k + k_pos_base
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            if _p_tile_bf16():
                # p tile in bf16 for the PV matmul (flash-kernel
                # practice): halves probability-tile traffic; the
                # accumulator stays f32 (§Perf knob REPRO_ATTN_P_BF16)
                pv = jnp.einsum("bkgqs,bskd->bkgqd",
                                p.astype(vb.dtype), vb,
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bkgqs,bskd->bkgqd", p,
                                vb.astype(jnp.float32))
            o_new = o_prev * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        init = (jnp.full((B, Kv, G, block_q), -1e30, jnp.float32),
                jnp.zeros((B, Kv, G, block_q), jnp.float32),
                jnp.zeros((B, Kv, G, block_q, Dv), jnp.float32))
        # checkpoint the kv block: backward recomputes the (bq, bk) score
        # tile instead of saving it — the flash-attention memory pattern
        (m, l, o), _ = jax.lax.scan(
            jax.checkpoint(kv_block), init,
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        o = o / jnp.maximum(l[..., None], 1e-30)
        # (B,Kv,G,bq,D) -> (B,bq,Kv,G,D)
        return carry, jnp.moveaxis(o, 3, 1)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq),
                                           jnp.moveaxis(qr, 1, 0)))
    # outs: (nq, B, bq, Kv, G, Dv) -> (B, Sq, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Kv, G, Dv)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                   softcap: float = 0.0) -> jax.Array:
    """Unblocked reference attention (small shapes / oracles)."""
    B, Sq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, D)
    s = _gqa_scores(qg, k) / math.sqrt(D)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        mask = q_pos[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(p, v)
    return o.reshape(B, Sq, H, v.shape[3]).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     softcap: float = 0.0) -> jax.Array:
    """One-token attention against a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, S, Kv, D); cache_len: scalar int (valid
    prefix length, new token already written at cache_len-1).
    """
    B, _, H, D = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    qg = q.reshape(B, 1, Kv, G, D)
    s = _gqa_scores(qg, k_cache) / math.sqrt(D)   # (B,Kv,G,1,S)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(S)[None, :] < cache_len    # broadcast (1,S) or (B,S)
    if valid.ndim == 2 and valid.shape[0] == 1:
        mask = valid[0][None, None, None, None, :]
    else:
        mask = valid[:, None, None, None, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(p, v_cache)
    return o.reshape(B, 1, H, v_cache.shape[3]).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str, dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], d_model, d_ff, dtype=dtype),
         "w_down": dense_init(ks[2], d_ff, d_model, dtype=dtype)}
    if act == "silu":             # SwiGLU
        p["w_gate"] = dense_init(ks[0], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(x, p, act: str):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if act == "silu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "batch", None, "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def mlp_logical_axes(act: str):
    p = {"w_up": ("embed", "ff"), "w_down": ("ff", "embed")}
    if act == "silu":
        p["w_gate"] = ("embed", "ff")
    return p


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, targets: jax.Array,
                 z_loss: float = 1e-4) -> Tuple[jax.Array, jax.Array]:
    """logits (B,S,V) any dtype; targets (B,S) int.  Returns (loss, zl)."""
    lf = logits.astype(jnp.float32)
    # the shift must be detached on BOTH sides: subtracting sg(m) but
    # adding back a live m leaks an extra +1 into the argmax logit's
    # gradient (d lse/dl = softmax + one_hot(argmax)), which suppresses
    # whichever logit is currently winning and stalls training
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = z_loss * jnp.square(lse)
    return jnp.mean(nll), jnp.mean(zl)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def causal_conv1d(x: jax.Array, w: jax.Array,
                  state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x: (B, L, C); w: (C, K).

    If ``state`` (B, K-1, C) is given it is prepended (decode path).
    """
    K = w.shape[1]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, L+K-1, C)
    stack = jnp.stack([xp[:, i:i + x.shape[1]] for i in range(K)],
                      axis=-1)                          # (B, L, C, K)
    return jnp.einsum("blck,ck->blc", stack, w.astype(x.dtype))
