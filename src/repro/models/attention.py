"""Attention modules: GQA (dense archs) and MLA (DeepSeek-V2).

Each module provides init / logical_axes / train-prefill apply / decode apply
and its cache layout.  MLA decode uses the *absorbed* formulation so only the
compressed (c_kv, k_rope) cache is ever materialized — the memory win that
makes deepseek-v2-lite decode_32k cheap (§Roofline).

Decode against a long sequence-sharded KV cache uses a flash-decode style
shard_map: each model shard computes a chunked partial softmax over its
local KV slice; partials merge with (pmax, rescale, psum) — peak scores
memory drops from O(S) to O(chunk) per chip.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro import parallel as PX
from repro.sharding import current_rules, shard


def _kv_seq_axes():
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return (), None
    ax = rules.rules.get("kv_seq")
    if ax is None:
        return (), rules
    return PX.axis_tuple(ax), rules


def _local_partial_softmax(q, k, v, valid, *, chunk: int = 1024,
                           softcap: float = 0.0):
    """Online-softmax partials over the local KV slice.

    q: (B,1,Kv,G,D); k/v: (B,Sl,Kv,Dv); valid: (Sl,) bool.
    Returns (m, l, acc): (B,Kv,G,1[,Dv]) f32 partial stats.
    """
    B, Sl, Kv, D = k.shape
    Dv = v.shape[-1]
    G = q.shape[3]
    scale = 1.0 / math.sqrt(q.shape[-1])
    while Sl % chunk:
        chunk -= 1
    n = Sl // chunk
    kr = k.reshape(B, n, chunk, Kv, D)
    vr = v.reshape(B, n, chunk, Kv, Dv)
    vm = valid.reshape(n, chunk)

    def body(carry, inp):
        m0, l0, a0 = carry
        kb, vb, vb_mask = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(vb_mask[None, None, None, None, :], s, -1e30)
        m1 = jnp.maximum(m0, jnp.max(s, axis=-1))
        p = jnp.exp(s - m1[..., None])
        corr = jnp.exp(m0 - m1)
        l1 = l0 * corr + jnp.sum(p, axis=-1)
        a1 = a0 * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
        return (m1, l1, a1), None

    init = (jnp.full((B, Kv, G, 1), -1e30, jnp.float32),
            jnp.zeros((B, Kv, G, 1), jnp.float32),
            jnp.zeros((B, Kv, G, 1, Dv), jnp.float32))
    (m, l, a), _ = jax.lax.scan(
        body, init, (jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0), vm))
    return m, l, a


def sharded_decode_attention(q, k_cache, v_cache, pos, *,
                             softcap: float = 0.0):
    """Flash-decode over a kv_seq-sharded cache; falls back to the dense
    path when no kv_seq sharding rule is active."""
    seq_axes, rules = _kv_seq_axes()
    B, _, H, D = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // Kv
    qg = q.reshape(B, 1, Kv, G, D)

    mesh = rules.mesh if rules is not None else None
    n_shards = PX.axes_size(mesh, seq_axes) if seq_axes else 1
    if n_shards == 1 or S % n_shards:
        # single-shard chunked path (still O(chunk) memory)
        valid = jnp.arange(S) < pos + 1
        m, l, acc = _local_partial_softmax(qg, k_cache, v_cache, valid,
                                           softcap=softcap)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, 1, H, Dv).astype(q.dtype)

    S_loc = S // n_shards
    # every mesh axis is mapped manually (partially-auto shard_maps crash
    # XLA's SPMD partitioner on older JAX), so the batch sharding must be
    # spelled out explicitly; axes that don't divide B stay replicated,
    # mirroring sharding.shard()'s drop rule
    batch_ax = tuple(a for a in PX.axis_tuple(rules.rules.get("kv_batch"))
                     if a not in seq_axes)
    if not batch_ax or B % PX.axes_size(mesh, batch_ax):
        batch_ax = None
    # each shard's KV start offset rides in as a P(seq_axes)-sharded
    # operand instead of axis_index arithmetic: axis_index lowers to a
    # PartitionId op some XLA versions reject, a sharded iota never is
    starts = (jnp.arange(n_shards, dtype=jnp.int32) * S_loc)

    def mapped(qg, k, v, pos, start):
        valid = (start[0] + jnp.arange(S_loc)) < pos + 1
        m, l, acc = _local_partial_softmax(qg, k, v, valid,
                                           softcap=softcap)
        gm = PX.pmax(m, seq_axes)
        corr = jnp.exp(m - gm)
        l = PX.psum(l * corr, seq_axes)
        acc = PX.psum(acc * corr[..., None], seq_axes)
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = PX.shard_map(
        mapped, mesh=mesh,
        in_specs=(P(batch_ax), P(batch_ax, seq_axes, None, None),
                  P(batch_ax, seq_axes, None, None), P(), P(seq_axes)),
        out_specs=P(batch_ax),
        check_vma=False,
    )(qg, k_cache, v_cache, pos, starts)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ArchConfig, dtype=L.DEFAULT_DTYPE):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, H * hd, dtype=dtype),
        "wk": L.dense_init(ks[1], d, Kv * hd, dtype=dtype),
        "wv": L.dense_init(ks[2], d, Kv * hd, dtype=dtype),
        "wo": L.dense_init(ks[3], H * hd, d, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Kv * hd,), dtype)
        p["bv"] = jnp.zeros((Kv * hd,), dtype)
    return p


def gqa_logical_axes(cfg: ArchConfig):
    p = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
         "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        p.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    return p


def _qkv(x, p, cfg: ArchConfig):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def _rope_dims(cfg: ArchConfig) -> int:
    if not cfg.use_rope:
        return 0
    hd = cfg.resolved_head_dim
    rd = int(hd * cfg.rope_fraction)
    return rd - (rd % 2)


def gqa_apply(x, p, cfg: ArchConfig, *, positions: jax.Array,
              causal: bool = True,
              kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
              block_q: int = 512, block_k: int = 1024) -> jax.Array:
    """Training / prefill attention.  x: (B,S,D); positions: (S,)."""
    q, k, v = _qkv(x, p, cfg)
    rd = _rope_dims(cfg)
    if rd and kv_override is None:
        cos, sin = L.rope_angles(positions, rd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin, rd)
        k = L.apply_rope(k, cos, sin, rd)
    elif rd:
        cos, sin = L.rope_angles(positions, rd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin, rd)
    if kv_override is not None:   # cross-attention: encoder / media KV
        k, v = kv_override
        causal = False
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    if q.shape[1] * k.shape[1] <= 1024 * 1024:
        o = L.full_attention(q, k, v, causal=causal,
                             softcap=cfg.logit_softcap)
    else:
        o = L.blocked_attention(q, k, v, causal=causal, block_q=block_q,
                                block_k=block_k, softcap=cfg.logit_softcap)
    o = o.reshape(x.shape[0], x.shape[1], -1)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])


def gqa_make_cache(cfg: ArchConfig, batch: int, seq: int, n_layers: int,
                   dtype=L.DEFAULT_DTYPE):
    hd = cfg.resolved_head_dim
    shape = (n_layers, batch, seq, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_cache_axes():
    return {"k": (None, "kv_batch", "kv_seq", None, None),
            "v": (None, "kv_batch", "kv_seq", None, None)}


def gqa_decode(x, p, cfg: ArchConfig, k_cache, v_cache, pos):
    """x: (B,1,D); caches (B,S,Kv,hd); pos: scalar index of the new token.

    Returns (out, new_k_entry, new_v_entry) — the caller owns cache updates
    (they live in a layer-stacked array updated inside the scan).
    """
    q, k, v = _qkv(x, p, cfg)
    rd = _rope_dims(cfg)
    if rd:
        posv = jnp.asarray(pos)[None]
        cos, sin = L.rope_angles(posv, rd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin, rd)
        k = L.apply_rope(k, cos, sin, rd)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)
    k_cache = shard(k_cache, "kv_batch", "kv_seq", None, None)
    v_cache = shard(v_cache, "kv_batch", "kv_seq", None, None)
    o = sharded_decode_attention(q, k_cache, v_cache, pos,
                                 softcap=cfg.logit_softcap)
    o = o.reshape(x.shape[0], 1, -1)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ArchConfig, dtype=L.DEFAULT_DTYPE):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": L.dense_init(ks[0], d, H * qd, dtype=dtype),
        "w_dkv": L.dense_init(ks[1], d, m.kv_lora_rank, dtype=dtype),
        "w_krope": L.dense_init(ks[2], d, m.qk_rope_dim, dtype=dtype),
        "w_uk": L.dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_dim,
                             dtype=dtype),
        "w_uv": L.dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim,
                             dtype=dtype),
        "wo": L.dense_init(ks[5], H * m.v_head_dim, d, dtype=dtype),
        "kv_norm": L.norm_init(m.kv_lora_rank, "rmsnorm"),
    }


def mla_logical_axes(cfg: ArchConfig):
    return {
        "wq": ("embed", "heads"),
        "w_dkv": ("embed", "lora"),
        "w_krope": ("embed", None),
        "w_uk": ("lora", "heads"),
        "w_uv": ("lora", "heads"),
        "wo": ("heads", "embed"),
        "kv_norm": {"w": (None,)},
    }


def _mla_qc(x, p, cfg: ArchConfig, positions):
    """Shared q / compressed-kv computation.  Returns q_nope,q_rope,c_kv,k_rope."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, qd)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    c_kv = jnp.einsum("bsd,dc->bsc", x, p["w_dkv"])
    c_kv = L.rmsnorm(c_kv, p["kv_norm"]["w"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_krope"])[:, :, None, :]
    cos, sin = L.rope_angles(positions, m.qk_rope_dim, cfg.rope_theta)
    q_rope = L.apply_rope(q_rope, cos, sin, m.qk_rope_dim)
    k_rope = L.apply_rope(k_rope, cos, sin, m.qk_rope_dim)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def mla_apply(x, p, cfg: ArchConfig, *, positions, causal: bool = True,
              block_q: int = 512, block_k: int = 1024):
    """Expanded (train/prefill) MLA: materialize per-head K,V from c_kv."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qc(x, p, cfg, positions)
    k_nope = jnp.einsum("bsc,ch->bsh", c_kv, p["w_uk"]).reshape(
        B, S, H, m.qk_nope_dim)
    v = jnp.einsum("bsc,ch->bsh", c_kv, p["w_uv"]).reshape(
        B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_dim))], axis=-1)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    if S * S <= 1024 * 1024:
        o = L.full_attention(q, k, v, causal=causal)
    else:
        o = L.blocked_attention(q, k, v, causal=causal,
                                block_q=block_q, block_k=block_k)
    o = o.reshape(B, S, -1)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])


def mla_make_cache(cfg: ArchConfig, batch: int, seq: int, n_layers: int,
                   dtype=L.DEFAULT_DTYPE):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((n_layers, batch, seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((n_layers, batch, seq, m.qk_rope_dim), dtype),
    }


def mla_cache_axes():
    return {"c_kv": (None, "kv_batch", "kv_seq", "lora"),
            "k_rope": (None, "kv_batch", "kv_seq", None)}


def mla_decode(x, p, cfg: ArchConfig, ckv_cache, krope_cache, pos):
    """Absorbed-matmul MLA decode: attention runs in the 512-d latent space.

    scores = (q_nope @ W_uk^T) @ c_kv^T + q_rope @ k_rope^T
    out    = (probs @ c_kv) @ W_uv
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    posv = jnp.asarray(pos)[None]
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qc(x, p, cfg, posv)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv_new.astype(ckv_cache.dtype), pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope_new.astype(krope_cache.dtype), pos, axis=1)
    ckv_cache = shard(ckv_cache, "kv_batch", "kv_seq", "lora")
    krope_cache = shard(krope_cache, "kv_batch", "kv_seq", None)

    # latent-space matmuls in f32: decode batches are small and the
    # absorbed reordering through the 512-d latent loses too much in bf16
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_lat = jnp.einsum("bqhn,chn->bqhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))          # (B,1,H,C)
    s = (jnp.einsum("bqhc,bsc->bhqs", q_lat,
                    ckv_cache.astype(jnp.float32))
         + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                      krope_cache.astype(jnp.float32)))
    s = s / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    valid = jnp.arange(ckv_cache.shape[1])[None, None, None, :] < pos + 1
    s = jnp.where(valid, s, -1e30)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsc->bqhc", prob,
                       ckv_cache.astype(jnp.float32))     # (B,1,H,C)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhc,chv->bqhv", o_lat,
                   w_uv.astype(jnp.float32)).reshape(B, 1, -1)
    out = jnp.einsum("bsh,hd->bsd", o.astype(x.dtype), p["wo"])
    return out, ckv_cache, krope_cache
