"""xLSTM LM: mLSTM (matrix memory, chunk-parallel) + sLSTM (recurrent) blocks.

mLSTM chunked math (stabilized, see derivation in kernels/mlstm/ref.py):
  carry (C_hat, n_hat, m);  per chunk with log-forget cumsum b_t, a_s=i_s-b_s,
  rm_t = max(m0, cummax(a)_t):
    scores[t,s] = (q_t.k_s/sqrt(d)) * exp(a_s - rm_t)        (s<=t)
    inter[t]    = exp(m0 - rm_t) * (C_hat0^T q_t)
    den[t]      = exp(m0 - rm_t) * (n_hat0.q_t) + sum_s scores[t,s]
    h_t         = (sum_s scores[t,s] v_s + inter[t]) / max(|den_t|, exp(-m_t))
  with m_t = b_t + rm_t; carried C' = exp(m0-R)C + sum_s exp(a_s-R) k_s v_s^T,
  n' likewise, m' = b_end + R, R = rm_{end}.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models.transformer import _norm_axes, _stacked
from repro.sharding import shard


# ---------------------------------------------------------------------------
# mLSTM cell — chunked (jnp; mirrored by the Pallas kernel)
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, i_raw, f_raw, *, chunk: int, carry=None):
    """q,k,v: (B,S,H,D); i_raw,f_raw: (B,S,H).  Returns (h, carry).

    carry = (C (B,H,D,D) f32, n (B,H,D) f32, m (B,H) f32).
    """
    B, S, H, D = q.shape
    assert S % chunk == 0
    nc = S // chunk
    scale = 1.0 / math.sqrt(D)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))   # (B,S,H)
    ii = i_raw.astype(jnp.float32)

    qc = qf.reshape(B, nc, chunk, H, D)
    kc = kf.reshape(B, nc, chunk, H, D)
    vc = vf.reshape(B, nc, chunk, H, D)
    lc = lf.reshape(B, nc, chunk, H)
    ic = ii.reshape(B, nc, chunk, H)

    if carry is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = carry

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, inp):
        C, n, m0 = carry
        qb, kb, vb, lb, ib = inp                  # (B,Q,H,*)
        b = jnp.cumsum(lb, axis=1)                # (B,Q,H)
        a = ib - b
        rm = jnp.maximum(jax.lax.cummax(a, axis=1), m0[:, None, :])
        m_t = b + rm                               # absolute stabilizer

        qk = jnp.einsum("bqhd,bshd->bhqs", qb, kb)
        w = jnp.exp(a[:, None, :, :].transpose(0, 3, 1, 2) -
                    rm.transpose(0, 2, 1)[:, :, :, None])     # (B,H,t,s)
        w = jnp.where(tri[None, None], w, 0.0)
        scores = qk * w

        inter_scale = jnp.exp(m0[:, :, None] - rm.transpose(0, 2, 1))
        inter = jnp.einsum("bhdk,bqhd->bhqk", C, qb)           # C^T q
        inter = inter * inter_scale[..., None]
        num = jnp.einsum("bhqs,bshd->bhqd", scores, vb) + inter
        den = (jnp.sum(scores, axis=-1)
               + jnp.einsum("bhd,bqhd->bhq", n, qb) * inter_scale)
        h = num / jnp.maximum(jnp.abs(den),
                              jnp.exp(-m_t).transpose(0, 2, 1))[..., None]

        R = rm[:, -1, :]                           # (B,H)
        decay_in = jnp.exp(a - R[:, None, :])      # per-source weight
        C_new = (C * jnp.exp(m0 - R)[:, :, None, None]
                 + jnp.einsum("bsh,bshd,bshe->bhde", decay_in, kb, vb))
        n_new = (n * jnp.exp(m0 - R)[:, :, None]
                 + jnp.einsum("bsh,bshd->bhd", decay_in, kb))
        m_new = b[:, -1, :] + R
        return (C_new, n_new, m_new), h.transpose(0, 2, 1, 3)  # (B,Q,H,D)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, lc, ic))
    # checkpointed: backward recomputes the (Q,Q) gate/score tiles
    (C, n, m), hs = jax.lax.scan(jax.checkpoint(chunk_step),
                                 (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, D)
    return h.astype(q.dtype), (C, n, m)


def mlstm_step(q, k, v, i_raw, f_raw, carry):
    """Single-token mLSTM.  q,k,v: (B,H,D); gates: (B,H)."""
    C, n, m = carry
    D = q.shape[-1]
    qf = q.astype(jnp.float32) / math.sqrt(D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    ii = i_raw.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, ii)
    i_s = jnp.exp(ii - m_new)
    f_s = jnp.exp(lf + m - m_new)
    C = C * f_s[..., None, None] + i_s[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = n * f_s[..., None] + i_s[..., None] * kf
    num = jnp.einsum("bhde,bhd->bhe", C, qf)
    den = jnp.einsum("bhd,bhd->bh", n, qf)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (C, n, m_new)


def mlstm_sequential_ref(q, k, v, i_raw, f_raw, carry=None):
    """Token-by-token oracle (tests only)."""
    B, S, H, D = q.shape
    if carry is None:
        carry = (jnp.zeros((B, H, D, D), jnp.float32),
                 jnp.zeros((B, H, D), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))

    def step(c, inp):
        qt, kt, vt, it, ft = inp
        h, c = mlstm_step(qt, kt, vt, it, ft, c)
        return c, h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_raw, f_raw))
    carry, hs = jax.lax.scan(step, carry, xs)
    return jnp.moveaxis(hs, 0, 1), carry


# ---------------------------------------------------------------------------
# sLSTM cell (recurrent)
# ---------------------------------------------------------------------------

def slstm_scan(x_gates, r_w, carry):
    """x_gates: (B,S,H,4,Dh) pre-computed input contributions.
    r_w: (H,4,Dh,Dh) recurrent weights.  carry: (c,n,m,h) each (B,H,Dh)."""

    def step(carry, xg):
        c, n, m, h = carry
        rec = jnp.einsum("bhd,hgde->bhge", h, r_w.astype(jnp.float32))
        g = xg.astype(jnp.float32) + rec            # (B,H,4,Dh)
        i_raw, f_raw, z_raw, o_raw = (g[:, :, 0], g[:, :, 1],
                                      g[:, :, 2], g[:, :, 3])
        lf = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(lf + m, i_raw)
        i_s = jnp.exp(i_raw - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(z_raw)
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    xs = jnp.moveaxis(x_gates, 1, 0)
    carry, hs = jax.lax.scan(jax.checkpoint(step), carry, xs)
    return jnp.moveaxis(hs, 0, 1), carry            # (B,S,H,Dh)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def mlstm_block_init(key, cfg: ArchConfig, dtype=L.DEFAULT_DTYPE):
    D = cfg.d_model
    Di = 2 * D
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": L.norm_init(D, cfg.norm),
        "w_up": L.dense_init(ks[0], D, Di, dtype=dtype),
        "w_z": L.dense_init(ks[1], D, Di, dtype=dtype),
        "conv": (jax.random.normal(ks[2], (Di, 4), jnp.float32)
                 / 2.0).astype(dtype),
        "wq": L.dense_init(ks[3], Di, Di, dtype=dtype),
        "wk": L.dense_init(ks[4], Di, Di, dtype=dtype),
        "wv": L.dense_init(ks[5], Di, Di, dtype=dtype),
        "w_if": L.dense_init(ks[6], Di, 2 * H, dtype=jnp.float32,
                             scale=0.01),
        "if_bias": jnp.concatenate([jnp.zeros((H,)),
                                    jnp.linspace(3.0, 6.0, H)]
                                   ).astype(jnp.float32),
        "onorm": {"w": jnp.ones((Di,), jnp.float32)},
        "w_down": L.dense_init(ks[7], Di, D, dtype=dtype),
    }


def mlstm_block_axes(cfg: ArchConfig):
    return {
        "norm": _norm_axes(cfg),
        "w_up": ("embed", "heads"), "w_z": ("embed", "heads"),
        "conv": ("heads", None),
        "wq": ("heads", None), "wk": ("heads", None), "wv": ("heads", None),
        "w_if": ("heads", None), "if_bias": (None,),
        "onorm": {"w": ("heads",)},
        "w_down": ("heads", "embed"),
    }


def _mlstm_qkvg(x, p, cfg: ArchConfig, conv_state=None):
    B, S, D = x.shape
    H = cfg.n_heads
    Di = 2 * D
    Dh = Di // H
    xu = jnp.einsum("bsd,de->bse", x, p["w_up"])
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xc = jax.nn.silu(L.causal_conv1d(xu, p["conv"], state=conv_state))
    q = jnp.einsum("bse,ef->bsf", xc, p["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bse,ef->bsf", xc, p["wk"]).reshape(B, S, H, Dh)
    v = jnp.einsum("bse,ef->bsf", xu, p["wv"]).reshape(B, S, H, Dh)
    gates = (jnp.einsum("bse,eg->bsg", xu.astype(jnp.float32),
                        p["w_if"]) + p["if_bias"])
    i_raw, f_raw = gates[..., :H], gates[..., H:]
    return xu, z, q, k, v, i_raw, f_raw


def mlstm_block_apply(x, p, cfg: ArchConfig, *, chunk: int = 256,
                      use_kernel: bool = False):
    B, S, D = x.shape
    h = L.norm_apply(x, p["norm"], cfg.norm, cfg.norm_eps)
    xu, z, q, k, v, i_raw, f_raw = _mlstm_qkvg(h, p, cfg)
    if use_kernel:
        from repro.kernels.mlstm import ops as mops
        out, _ = mops.mlstm(q, k, v, i_raw, f_raw, chunk=min(chunk, S))
    else:
        out, _ = mlstm_chunked(q, k, v, i_raw, f_raw, chunk=min(chunk, S))
    out = out.reshape(B, S, -1)
    out = L.rmsnorm(out, p["onorm"]["w"], cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(out.dtype)
    return x + jnp.einsum("bse,ed->bsd", out, p["w_down"])


def slstm_block_init(key, cfg: ArchConfig, dtype=L.DEFAULT_DTYPE):
    D = cfg.d_model
    H = cfg.n_heads
    Dh = D // H
    ks = jax.random.split(key, 3)
    return {
        "norm": L.norm_init(D, cfg.norm),
        "w_in": L.dense_init(ks[0], D, 4 * D, dtype=dtype),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((D,)), jnp.broadcast_to(
                jnp.linspace(3.0, 6.0, H)[:, None], (H, Dh)).reshape(-1),
             jnp.zeros((2 * D,))]).astype(jnp.float32),
        "r_w": (jax.random.normal(ks[1], (H, 4, Dh, Dh), jnp.float32)
                * 0.01),
        "onorm": {"w": jnp.ones((D,), jnp.float32)},
        "w_out": L.dense_init(ks[2], D, D, dtype=dtype),
    }


def slstm_block_axes(cfg: ArchConfig):
    return {
        "norm": _norm_axes(cfg),
        "w_in": ("embed", "heads"), "gate_bias": (None,),
        "r_w": ("heads", None, None, None),
        "onorm": {"w": ("heads",)},
        "w_out": ("heads", "embed"),
    }


def _slstm_gates(x, p, cfg):
    B, S, D = x.shape
    H = cfg.n_heads
    Dh = D // H
    g = (jnp.einsum("bsd,dg->bsg", x, p["w_in"]).astype(jnp.float32)
         + p["gate_bias"])
    # layout: (i all heads, f all heads, z, o)
    return g.reshape(B, S, 4, H, Dh).transpose(0, 1, 3, 2, 4)  # (B,S,H,4,Dh)


def slstm_block_apply(x, p, cfg: ArchConfig, carry=None):
    B, S, D = x.shape
    H = cfg.n_heads
    Dh = D // H
    h = L.norm_apply(x, p["norm"], cfg.norm, cfg.norm_eps)
    xg = _slstm_gates(h, p, cfg)
    if carry is None:
        zero = jnp.zeros((B, H, Dh), jnp.float32)
        carry = (zero, zero, jnp.full((B, H, Dh), -1e30, jnp.float32), zero)
    hs, carry = slstm_scan(xg, p["r_w"], carry)
    hs = hs.reshape(B, S, D).astype(x.dtype)
    hs = L.rmsnorm(hs, p["onorm"]["w"], cfg.norm_eps)
    return x + jnp.einsum("bsd,de->bse", hs, p["w_out"]), carry


# ---------------------------------------------------------------------------
# the model: superblocks of (slstm_every-1 mLSTM + 1 sLSTM)
# ---------------------------------------------------------------------------

class XLSTMLM:
    def __init__(self, cfg: ArchConfig, *, remat: bool = True):
        self.cfg = cfg
        self.remat = remat
        se = cfg.slstm_every
        self.n_super = cfg.n_layers // se if se else 0
        self.n_m_per_super = (se - 1) if se else 0
        self.n_tail = cfg.n_layers - (self.n_super * se if se else 0)

    def init(self, rng):
        cfg = self.cfg
        ke, km, kt = jax.random.split(rng, 3)
        p: Dict[str, Any] = {
            "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm),
        }
        if self.n_super:
            def super_init(k):
                k1, k2 = jax.random.split(k)
                return {
                    "mlstm": jax.vmap(lambda kk: mlstm_block_init(kk, cfg))(
                        jax.random.split(k1, self.n_m_per_super)),
                    "slstm": slstm_block_init(k2, cfg),
                }
            p["blocks"] = jax.vmap(super_init)(
                jax.random.split(km, self.n_super))
        if self.n_tail:
            p["tail"] = jax.vmap(lambda kk: mlstm_block_init(kk, cfg))(
                jax.random.split(kt, self.n_tail))
        return p

    def param_logical_axes(self):
        cfg = self.cfg
        p = {"embed": ("vocab", "embed"), "final_norm": _norm_axes(cfg)}
        if self.n_super:
            p["blocks"] = {
                "mlstm": jax.tree.map(
                    lambda ax: (None, None) + ax, mlstm_block_axes(cfg),
                    is_leaf=lambda v: isinstance(v, tuple)),
                "slstm": _stacked(slstm_block_axes(cfg)),
            }
        if self.n_tail:
            p["tail"] = _stacked(mlstm_block_axes(cfg))
        return p

    def forward_logits(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        x = shard(x, "batch", None, None)

        def super_body(x, sp):
            def inner(x, bp):
                return mlstm_block_apply(x, bp, cfg), None
            x, _ = jax.lax.scan(inner, x, sp["mlstm"])
            x, _ = slstm_block_apply(x, sp["slstm"], cfg)
            return x, None

        if self.n_super:
            f = jax.checkpoint(super_body) if self.remat else super_body
            x, _ = jax.lax.scan(f, x, params["blocks"])
        if self.n_tail:
            def inner(x, bp):
                return mlstm_block_apply(x, bp, cfg), None
            g = jax.checkpoint(inner) if self.remat else inner
            x, _ = jax.lax.scan(g, x, params["tail"])
        x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return shard(logits, "batch", None, "vocab"), jnp.zeros(
            (), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward_logits(params, batch)
        nll, zl = L.softmax_xent(logits, batch["targets"])
        return nll + zl, {"nll": nll, "z_loss": zl, "aux": aux}

    # ------------------------------------------------------------- decode
    def init_cache(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        D = cfg.d_model
        H = cfg.n_heads
        Di = 2 * D
        Dh = Di // H
        Dh_s = D // H

        def m_cache(n):
            return {
                "conv": jnp.zeros((n, batch_size, 3, Di), L.DEFAULT_DTYPE),
                "C": jnp.zeros((n, batch_size, H, Dh, Dh), jnp.float32),
                "n": jnp.zeros((n, batch_size, H, Dh), jnp.float32),
                "m": jnp.full((n, batch_size, H), -1e30, jnp.float32),
            }

        cache: Dict[str, Any] = {}
        if self.n_super:
            cache["mlstm"] = jax.tree.map(
                lambda a: a.reshape((self.n_super, self.n_m_per_super)
                                    + a.shape[1:]),
                m_cache(self.n_super * self.n_m_per_super))
            zero = jnp.zeros((self.n_super, batch_size, H, Dh_s),
                             jnp.float32)
            cache["slstm"] = {
                "c": zero, "n": zero,
                "m": jnp.full_like(zero, -1e30), "h": zero,
            }
        if self.n_tail:
            cache["tail"] = m_cache(self.n_tail)
        return cache

    def cache_logical_axes(self):
        m_ax = {"conv": (None, "kv_batch", None, "heads"),
                "C": (None, "kv_batch", "heads", None, None),
                "n": (None, "kv_batch", "heads", None),
                "m": (None, "kv_batch", "heads")}
        axes: Dict[str, Any] = {}
        if self.n_super:
            axes["mlstm"] = jax.tree.map(
                lambda ax: (None,) + ax, m_ax,
                is_leaf=lambda v: isinstance(v, tuple))
            s_ax = (None, "kv_batch", "heads", None)
            axes["slstm"] = {"c": s_ax, "n": s_ax, "m": s_ax, "h": s_ax}
        if self.n_tail:
            axes["tail"] = m_ax
        return axes

    def _mlstm_decode(self, x, bp, c):
        cfg = self.cfg
        B = x.shape[0]
        h = L.norm_apply(x, bp["norm"], cfg.norm, cfg.norm_eps)
        xu, z, q, k, v, i_raw, f_raw = _mlstm_qkvg(
            h, bp, cfg, conv_state=c["conv"])
        new_conv = jnp.concatenate(
            [c["conv"][:, 1:], jnp.einsum(
                "bsd,de->bse", h, bp["w_up"]).astype(c["conv"].dtype)],
            axis=1)
        hq, (C, n, m) = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                   i_raw[:, 0], f_raw[:, 0],
                                   (c["C"], c["n"], c["m"]))
        out = hq.reshape(B, 1, -1)
        out = L.rmsnorm(out, bp["onorm"]["w"], cfg.norm_eps)
        out = out * jax.nn.silu(z.astype(jnp.float32)).astype(out.dtype)
        x = x + jnp.einsum("bse,ed->bsd", out, bp["w_down"])
        return x, {"conv": new_conv, "C": C, "n": n, "m": m}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = params["embed"][tokens]
        new_cache: Dict[str, Any] = {}

        if self.n_super:
            def super_body(x, inp):
                sp, mc, sc = inp

                def inner(x, bp_c):
                    bp, c = bp_c
                    return self._mlstm_decode(x, bp, c)

                x, mc = jax.lax.scan(inner, x, (sp["mlstm"], mc))
                # slstm single step
                h = L.norm_apply(x, sp["slstm"]["norm"], cfg.norm,
                                 cfg.norm_eps)
                xg = _slstm_gates(h, sp["slstm"], cfg)
                hs, (c_, n_, m_, h_) = slstm_scan(
                    xg, sp["slstm"]["r_w"],
                    (sc["c"], sc["n"], sc["m"], sc["h"]))
                hs = hs.reshape(x.shape).astype(x.dtype)
                hs = L.rmsnorm(hs, sp["slstm"]["onorm"]["w"], cfg.norm_eps)
                x = x + jnp.einsum("bsd,de->bse", hs, sp["slstm"]["w_out"])
                return x, (mc, {"c": c_, "n": n_, "m": m_, "h": h_})

            x, (mc, sc) = jax.lax.scan(
                super_body, x,
                (params["blocks"], cache["mlstm"], cache["slstm"]))
            new_cache["mlstm"], new_cache["slstm"] = mc, sc
        if self.n_tail:
            def inner(x, bp_c):
                bp, c = bp_c
                return self._mlstm_decode(x, bp, c)
            x, tc = jax.lax.scan(inner, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = tc
        x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return logits, new_cache

    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}
