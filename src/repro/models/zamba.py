"""Zamba2-style hybrid LM: Mamba2 backbone + one *shared* attention block
applied every N mamba blocks (weights shared across applications, per the
Zamba2 paper).  Sub-quadratic: eligible for long_500k.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import _norm_axes, _stacked, layer_init, \
    layer_logical_axes, layer_apply
from repro.sharding import shard


class ZambaLM:
    def __init__(self, cfg: ArchConfig, *, remat: bool = True):
        self.cfg = cfg
        self.remat = remat
        self.n_super = cfg.n_layers // cfg.hybrid_attn_every
        self.n_tail = cfg.n_layers - self.n_super * cfg.hybrid_attn_every

    # ---------------------------------------------------------------- init
    def init(self, rng):
        cfg = self.cfg
        km, kt, ka, ke = jax.random.split(rng, 4)

        def stack(key, n):
            return jax.vmap(lambda k: {
                "norm": L.norm_init(cfg.d_model, cfg.norm),
                "mamba": S.mamba_init(k, cfg),
            })(jax.random.split(key, n))

        p: Dict[str, Any] = {
            "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm),
            "blocks": jax.vmap(lambda k: stack(k, cfg.hybrid_attn_every))(
                jax.random.split(km, self.n_super)),
            "shared_attn": layer_init(ka, cfg, moe=False),
        }
        if self.n_tail:
            p["tail"] = stack(kt, self.n_tail)
        return p

    def param_logical_axes(self):
        cfg = self.cfg
        blk = {"norm": _norm_axes(cfg), "mamba": S.mamba_logical_axes(cfg)}
        p = {
            "embed": ("vocab", "embed"),
            "final_norm": _norm_axes(cfg),
            "blocks": jax.tree.map(lambda ax: (None, None) + ax, blk,
                                   is_leaf=lambda v: isinstance(v, tuple)),
            "shared_attn": layer_logical_axes(cfg, moe=False),
        }
        if self.n_tail:
            p["tail"] = _stacked(blk)
        return p

    # ------------------------------------------------------------ forward
    def _mamba_block(self, x, bp):
        cfg = self.cfg
        h = L.norm_apply(x, bp["norm"], cfg.norm, cfg.norm_eps)
        return x + S.mamba_apply(h, bp["mamba"], cfg)

    def forward_logits(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        x = shard(x, "batch", None, None)
        positions = jnp.arange(tokens.shape[1])

        def super_body(x, sp):
            def inner(x, bp):
                return self._mamba_block(x, bp), None
            x, _ = jax.lax.scan(inner, x, sp)
            x, _ = layer_apply(x, params["shared_attn"], cfg,
                               positions=positions, moe=False)
            return x, None

        f = jax.checkpoint(super_body) if self.remat else super_body
        x, _ = jax.lax.scan(f, x, params["blocks"])
        if self.n_tail:
            def inner(x, bp):
                return self._mamba_block(x, bp), None
            g = jax.checkpoint(inner) if self.remat else inner
            x, _ = jax.lax.scan(g, x, params["tail"])
        x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return shard(logits, "batch", None, "vocab"), jnp.zeros(
            (), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward_logits(params, batch)
        nll, zl = L.softmax_xent(logits, batch["targets"])
        return nll + zl, {"nll": nll, "z_loss": zl, "aux": aux}

    # ------------------------------------------------------------- decode
    def init_cache(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        cache = {
            "mamba": S.mamba_make_cache(cfg, self.n_super *
                                        cfg.hybrid_attn_every, batch_size),
            "attn_k": jnp.zeros((self.n_super, batch_size, seq_len,
                                 cfg.n_kv_heads, hd), L.DEFAULT_DTYPE),
            "attn_v": jnp.zeros((self.n_super, batch_size, seq_len,
                                 cfg.n_kv_heads, hd), L.DEFAULT_DTYPE),
        }
        cache["mamba"] = jax.tree.map(
            lambda a: a.reshape((self.n_super, cfg.hybrid_attn_every)
                                + a.shape[1:]), cache["mamba"])
        if self.n_tail:
            cache["tail"] = S.mamba_make_cache(cfg, self.n_tail, batch_size)
        return cache

    def cache_logical_axes(self):
        m = jax.tree.map(lambda ax: (None,) + ax, S.mamba_cache_axes(),
                         is_leaf=lambda v: isinstance(v, tuple))
        axes = {
            "mamba": m,
            "attn_k": (None, "kv_batch", "kv_seq", None, None),
            "attn_v": (None, "kv_batch", "kv_seq", None, None),
        }
        if self.n_tail:
            axes["tail"] = S.mamba_cache_axes()
        return axes

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = shard(x, "batch", None, None)

        def super_body(x, inp):
            sp, mcache, kc, vc = inp

            def inner(x, bp_c):
                bp, c = bp_c
                h = L.norm_apply(x, bp["norm"], cfg.norm, cfg.norm_eps)
                o, c = S.mamba_decode(h, bp["mamba"], cfg, c)
                return x + o, c

            x, mcache = jax.lax.scan(inner, x, (sp, mcache))
            # shared attention application
            ap = params["shared_attn"]
            h = L.norm_apply(x, ap["attn_norm"], cfg.norm, cfg.norm_eps)
            a, kc, vc = A.gqa_decode(h, ap["attn"], cfg, kc, vc, pos)
            x = x + a
            h2 = L.norm_apply(x, ap["ffn_norm"], cfg.norm, cfg.norm_eps)
            x = x + L.mlp_apply(h2, ap["ffn"], cfg.act)
            return x, (mcache, kc, vc)

        x, (mc, ks, vs) = jax.lax.scan(
            super_body, x,
            (params["blocks"], cache["mamba"],
             cache["attn_k"], cache["attn_v"]))
        new_cache = {"mamba": mc, "attn_k": ks, "attn_v": vs}
        if self.n_tail:
            def inner(x, bp_c):
                bp, c = bp_c
                h = L.norm_apply(x, bp["norm"], cfg.norm, cfg.norm_eps)
                o, c = S.mamba_decode(h, bp["mamba"], cfg, c)
                return x + o, c
            x, tc = jax.lax.scan(inner, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = tc
        x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return shard(logits, "batch", None, "vocab"), new_cache

    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}
