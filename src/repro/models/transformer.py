"""Unified transformer LM covering the dense / vlm / moe / encdec families.

One scanned layer stack (params stacked on a leading layer axis) keeps the
HLO size independent of depth — essential for fast multi-pod dry-run compiles
of the 100-layer archs.  Heterogeneous stacks (VLM cross-attn every Nth
layer) scan over *superblocks*.

Public surface (shared by all model classes in this package):
    init(rng) -> params
    param_logical_axes() -> pytree of logical-axis tuples (same treedef)
    loss(params, batch) -> (loss, metrics)
    forward_logits(params, batch) -> logits            (train fwd / prefill)
    init_cache(batch_size, seq_len) -> cache
    cache_logical_axes(...)
    prefill(params, batch, cache) -> (logits, cache)
    decode_step(params, cache, tokens, pos) -> (logits, cache)
    input_specs(shape) -> dict[str, ShapeDtypeStruct]
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import layers as L
from repro.sharding import shard

Params = Any


def _use_mla(cfg: ArchConfig) -> bool:
    return cfg.mla is not None


def _is_moe_layer(cfg: ArchConfig, layer_idx: int) -> bool:
    return (cfg.moe is not None
            and layer_idx >= cfg.moe.first_dense_layers)


# ---------------------------------------------------------------------------
# single layer init/apply
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ArchConfig, *, moe: bool, cross: bool = False,
               dense_ff: Optional[int] = None):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"attn_norm": L.norm_init(cfg.d_model, cfg.norm)}
    if cross:
        p["attn"] = A.gqa_init(ks[0], cfg)
    elif _use_mla(cfg):
        p["attn"] = A.mla_init(ks[0], cfg)
    else:
        p["attn"] = A.gqa_init(ks[0], cfg)
    if not cfg.parallel_block:
        p["ffn_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    if moe:
        p["ffn"] = F.moe_init(ks[1], cfg)
    else:
        p["ffn"] = L.mlp_init(ks[1], cfg.d_model, dense_ff or cfg.d_ff,
                              cfg.act)
    if cross:
        p["xgate"] = jnp.zeros((), jnp.float32)   # tanh-gated cross-attn
    return p


def layer_logical_axes(cfg: ArchConfig, *, moe: bool, cross: bool = False):
    p: Dict[str, Any] = {
        "attn_norm": _norm_axes(cfg),
    }
    if cross or not _use_mla(cfg):
        p["attn"] = A.gqa_logical_axes(cfg)
    else:
        p["attn"] = A.mla_logical_axes(cfg)
    if not cfg.parallel_block:
        p["ffn_norm"] = _norm_axes(cfg)
    p["ffn"] = F.moe_logical_axes(cfg) if moe else L.mlp_logical_axes(cfg.act)
    if cross:
        p["xgate"] = ()
    return p


def _norm_axes(cfg: ArchConfig):
    return ({"w": (None,), "b": (None,)} if cfg.norm == "layernorm"
            else {"w": (None,)})


def layer_apply(x, p, cfg: ArchConfig, *, positions, moe: bool,
                causal: bool = True,
                media_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                cross: bool = False):
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.norm_apply(x, p["attn_norm"], cfg.norm, cfg.norm_eps)
    h = shard(h, "batch", None, None)
    if cross:
        a = A.gqa_apply(h, p["attn"], cfg, positions=positions,
                        kv_override=media_kv)
        a = jnp.tanh(p["xgate"]).astype(a.dtype) * a
    elif _use_mla(cfg):
        a = A.mla_apply(h, p["attn"], cfg, positions=positions,
                        causal=causal)
    else:
        a = A.gqa_apply(h, p["attn"], cfg, positions=positions,
                        causal=causal)
    if cfg.parallel_block:
        if moe:
            f, aux = F.moe_apply(h, p["ffn"], cfg)
        else:
            f = L.mlp_apply(h, p["ffn"], cfg.act)
        x = x + a + f
    else:
        x = x + a
        h2 = L.norm_apply(x, p["ffn_norm"], cfg.norm, cfg.norm_eps)
        h2 = shard(h2, "batch", None, None)
        if moe:
            f, aux = F.moe_apply(h2, p["ffn"], cfg)
        else:
            f = L.mlp_apply(h2, p["ffn"], cfg.act)
        x = x + f
    return shard(x, "batch", "seq", None), aux


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class TransformerLM:
    """dense / moe / vlm / encdec transformer LM."""

    def __init__(self, cfg: ArchConfig, *, remat: bool = True):
        self.cfg = cfg
        self.remat = remat

    # ---------------------------------------------------------------- init
    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_layers, k_head, k_enc, k_first = jax.random.split(rng, 5)
        p: Dict[str, Any] = {
            "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_size)

        n_scanned = cfg.n_layers - self._n_first_dense()
        if cfg.family == "vlm":
            p["blocks"] = self._init_vlm_blocks(k_layers)
        else:
            p["blocks"] = self._init_stack(
                k_layers, n_scanned, moe=cfg.moe is not None)
        if self._n_first_dense():
            p["first"] = self._init_stack(
                k_first, self._n_first_dense(), moe=False,
                dense_ff=cfg.moe.dense_d_ff)
        if cfg.is_encdec:
            p["encoder"] = self._init_stack(
                k_enc, cfg.n_enc_layers, moe=False, causal_stack=False)
            p["enc_final_norm"] = L.norm_init(cfg.d_model, cfg.norm)
            ks = jax.random.split(k_enc, 3)
            p["cross_blocks"] = jax.vmap(
                lambda k: A.gqa_init(k, self.cfg))(
                    jax.random.split(ks[1], cfg.n_layers))
            p["cross_norms"] = jax.vmap(
                lambda k: L.norm_init(cfg.d_model, cfg.norm))(
                    jax.random.split(ks[2], cfg.n_layers))
        return p

    def _n_first_dense(self) -> int:
        return self.cfg.moe.first_dense_layers if self.cfg.moe else 0

    def _init_stack(self, key, n, *, moe, dense_ff=None, causal_stack=True):
        keys = jax.random.split(key, max(n, 1))
        return jax.vmap(lambda k: layer_init(
            k, self.cfg, moe=moe, dense_ff=dense_ff))(keys[:n])

    def _init_vlm_blocks(self, key):
        cfg = self.cfg
        n_super = cfg.n_layers // cfg.cross_every
        n_self = cfg.cross_every - 1
        k_self, k_cross = jax.random.split(key)

        def super_init(k):
            ks, kc = jax.random.split(k)
            return {
                "self": jax.vmap(lambda kk: layer_init(
                    kk, cfg, moe=False))(jax.random.split(ks, n_self)),
                "cross": layer_init(kc, cfg, moe=False, cross=True),
            }
        return jax.vmap(super_init)(jax.random.split(key, n_super))

    # ------------------------------------------------------------- axes
    def param_logical_axes(self):
        cfg = self.cfg
        p: Dict[str, Any] = {
            "embed": ("vocab", "embed"),
            "final_norm": _norm_axes(cfg),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = ("embed", "vocab")
        la = layer_logical_axes(cfg, moe=cfg.moe is not None)
        if cfg.family == "vlm":
            p["blocks"] = {
                "self": _stacked(layer_logical_axes(cfg, moe=False)),
                "cross": _stacked(
                    layer_logical_axes(cfg, moe=False, cross=True)),
            }
            # inner 'self' has two leading stack dims; _stacked adds one
            p["blocks"]["self"] = jax.tree.map(
                lambda ax: (None,) + ax if isinstance(ax, tuple) else ax,
                p["blocks"]["self"], is_leaf=lambda v: isinstance(v, tuple))
        else:
            p["blocks"] = _stacked(la)
        if self._n_first_dense():
            p["first"] = _stacked(layer_logical_axes(
                cfg, moe=False))
        if cfg.is_encdec:
            p["encoder"] = _stacked(layer_logical_axes(cfg, moe=False))
            p["enc_final_norm"] = _norm_axes(cfg)
            p["cross_blocks"] = _stacked(A.gqa_logical_axes(cfg))
            p["cross_norms"] = _stacked(_norm_axes(cfg))
        return p

    # ------------------------------------------------------------ forward
    def _stack_apply(self, x, stacked, *, positions, moe, causal=True):
        cfg = self.cfg

        def body(carry, lp):
            xc, aux = carry
            xo, a = layer_apply(xc, lp, cfg, positions=positions, moe=moe,
                                causal=causal)
            return (xo, aux + a), None

        f = jax.checkpoint(body) if self.remat else body
        (x, aux), _ = jax.lax.scan(f, (x, jnp.zeros((), jnp.float32)),
                                   stacked)
        return x, aux

    def _vlm_apply(self, x, blocks, *, positions, media):
        cfg = self.cfg
        # pin media's sharding: without this XLA's SPMD partitioner hits
        # "involuntary full rematerialization" on the fwd/bwd sharding
        # mismatch and all-gathers the media activations across pods once
        # per superblock (§Perf pair C, hypothesis C2)
        media = shard(media, "batch", None, None)

        def super_body(carry, sp):
            xc, aux = carry

            def self_body(c, lp):
                xs, a0 = c
                xo, a = layer_apply(xs, lp, cfg, positions=positions,
                                    moe=False)
                return (xo, a0 + a), None

            if self.remat:        # per-layer remat: one layer's gathered
                self_body = jax.checkpoint(self_body)  # weights live at once
            (xc, aux), _ = jax.lax.scan(self_body, (xc, aux), sp["self"])
            # cross layer: media K/V projected by this layer's wk/wv
            pm = sp["cross"]
            B, M, _ = media.shape
            hd = cfg.resolved_head_dim
            mk = jnp.einsum("bmd,dh->bmh", media, pm["attn"]["wk"]).reshape(
                B, M, cfg.n_kv_heads, hd)
            mv = jnp.einsum("bmd,dh->bmh", media, pm["attn"]["wv"]).reshape(
                B, M, cfg.n_kv_heads, hd)
            xc, a = layer_apply(xc, pm, cfg, positions=positions, moe=False,
                                cross=True, media_kv=(mk, mv))
            return (xc, aux + a), None

        f = jax.checkpoint(super_body) if self.remat else super_body
        (x, aux), _ = jax.lax.scan(f, (x, jnp.zeros((), jnp.float32)),
                                   blocks)
        return x, aux

    def _encode(self, params, frames):
        """Whisper encoder over stubbed frame embeddings (B, F, D)."""
        cfg = self.cfg
        x = frames + L.sinusoidal_positions(
            frames.shape[1], cfg.d_model).astype(frames.dtype)[None]
        positions = jnp.arange(frames.shape[1])
        x, _ = self._stack_apply(x, params["encoder"], positions=positions,
                                 moe=False, causal=False)
        return L.norm_apply(x, params["enc_final_norm"], cfg.norm,
                            cfg.norm_eps)

    def _decoder_encdec(self, params, x, positions, enc_out):
        """Whisper decoder: interleaved (self, cross, mlp) per layer."""
        cfg = self.cfg

        def body(carry, lp):
            xc, aux = carry
            block, xattn, xnorm = lp
            xo, a = layer_apply(xc, block, cfg, positions=positions,
                                moe=False)
            # cross-attention sublayer appended after the standard block
            h = L.norm_apply(xo, xnorm, cfg.norm, cfg.norm_eps)
            B, M, _ = enc_out.shape
            hd = cfg.resolved_head_dim
            mk = jnp.einsum("bmd,dh->bmh", enc_out, xattn["wk"]).reshape(
                B, M, cfg.n_kv_heads, hd)
            mv = jnp.einsum("bmd,dh->bmh", enc_out, xattn["wv"]).reshape(
                B, M, cfg.n_kv_heads, hd)
            c = A.gqa_apply(h, xattn, cfg, positions=positions,
                            kv_override=(mk, mv))
            return (xo + c, aux + a), None

        f = jax.checkpoint(body) if self.remat else body
        (x, aux), _ = jax.lax.scan(
            f, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], params["cross_blocks"],
             params["cross_norms"]))
        return x, aux

    def forward_logits(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (logits, aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        # gather through f32 so the backward scatter-add (the embed
        # gradient) accumulates in f32 — bf16 scatter accumulation is
        # reduction-order sensitive and breaks accum-invariance
        emb = params["embed"]
        x = emb.astype(jnp.float32)[tokens].astype(emb.dtype)  # (B, S, D)
        if not cfg.use_rope and not cfg.is_encdec:
            x = x + L.sinusoidal_positions(
                tokens.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = shard(x, "batch", None, None)
        positions = jnp.arange(tokens.shape[1])
        aux = jnp.zeros((), jnp.float32)

        if cfg.is_encdec:
            x = x + L.sinusoidal_positions(
                tokens.shape[1], cfg.d_model).astype(x.dtype)[None]
            enc_out = self._encode(params, batch["frames"])
            x, aux = self._decoder_encdec(params, x, positions, enc_out)
        elif cfg.family == "vlm":
            x, aux = self._vlm_apply(x, params["blocks"],
                                     positions=positions,
                                     media=batch["media"])
        else:
            if "first" in params:
                x, a0 = self._stack_apply(x, params["first"],
                                          positions=positions, moe=False)
                aux = aux + a0
            x, a1 = self._stack_apply(x, params["blocks"],
                                      positions=positions,
                                      moe=cfg.moe is not None)
            aux = aux + a1

        x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, aux

    def _logits(self, params, x):
        # f32 accumulation: the loss consumes logits in f32 anyway, and
        # the backward of this einsum is the embed/lm_head gradient,
        # which otherwise picks up partition-order-dependent bf16 noise
        if self.cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                                preferred_element_type=jnp.float32)
        return shard(logits, "batch", None, "vocab")

    def loss(self, params, batch):
        logits, aux = self.forward_logits(params, batch)
        nll, zl = L.softmax_xent(logits, batch["targets"])
        total = nll + zl + aux
        return total, {"nll": nll, "z_loss": zl, "aux": aux}

    # ------------------------------------------------------------- decode
    def init_cache(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        n = cfg.n_layers
        if _use_mla(cfg):
            n_moe = n - self._n_first_dense()
            cache = A.mla_make_cache(cfg, batch_size, seq_len, n_moe)
            if self._n_first_dense():
                cache["first"] = A.mla_make_cache(
                    cfg, batch_size, seq_len, self._n_first_dense())
        elif cfg.family == "vlm":
            n_super = cfg.n_layers // cfg.cross_every
            cache = {
                "self": jax.tree.map(
                    lambda a: a.reshape((n_super, cfg.cross_every - 1)
                                        + a.shape[1:]),
                    A.gqa_make_cache(cfg, batch_size, seq_len,
                                     n_super * (cfg.cross_every - 1))),
                "cross_k": jnp.zeros(
                    (n_super, batch_size, cfg.n_media_tokens,
                     cfg.n_kv_heads, cfg.resolved_head_dim), L.DEFAULT_DTYPE),
                "cross_v": jnp.zeros(
                    (n_super, batch_size, cfg.n_media_tokens,
                     cfg.n_kv_heads, cfg.resolved_head_dim), L.DEFAULT_DTYPE),
            }
        elif cfg.is_encdec:
            cache = A.gqa_make_cache(cfg, batch_size, seq_len, cfg.n_layers)
            M = cfg.enc_seq_len
            hd = cfg.resolved_head_dim
            cache["cross_k"] = jnp.zeros(
                (cfg.n_layers, batch_size, M, cfg.n_kv_heads, hd),
                L.DEFAULT_DTYPE)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        else:
            cache = A.gqa_make_cache(cfg, batch_size, seq_len, cfg.n_layers)
        return cache

    def cache_logical_axes(self):
        cfg = self.cfg
        if _use_mla(cfg):
            axes = A.mla_cache_axes()
            if self._n_first_dense():
                axes = dict(axes)
                axes["first"] = A.mla_cache_axes()
            return axes
        if cfg.family == "vlm":
            base = A.gqa_cache_axes()
            return {
                "self": jax.tree.map(
                    lambda ax: (None,) + ax, base,
                    is_leaf=lambda v: isinstance(v, tuple)),
                "cross_k": (None, "kv_batch", None, None, None),
                "cross_v": (None, "kv_batch", None, None, None),
            }
        axes = dict(A.gqa_cache_axes())
        if cfg.is_encdec:
            axes["cross_k"] = (None, "kv_batch", None, None, None)
            axes["cross_v"] = (None, "kv_batch", None, None, None)
        return axes

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1); pos: scalar int32.  Returns (logits, cache)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if not cfg.use_rope:
            pe = L.sinusoidal_positions(int(cache_seq_len(cache)),
                                        cfg.d_model)
            x = x + jax.lax.dynamic_slice_in_dim(
                pe, pos, 1, axis=0).astype(x.dtype)[None]
        x = shard(x, "batch", None, None)
        aux0 = jnp.zeros((), jnp.float32)

        if cfg.is_encdec:
            x, cache = self._decode_encdec(params, cache, x, pos)
        elif cfg.family == "vlm":
            x, cache = self._decode_vlm(params, cache, x, pos)
        elif _use_mla(cfg):
            x, cache = self._decode_mla(params, cache, x, pos)
        else:
            x, cache = self._decode_gqa(params, cache, x, pos)

        x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        return self._logits(params, x), cache

    def _decode_gqa(self, params, cache, x, pos):
        cfg = self.cfg

        def body(x, lp_kv):
            lp, (kc, vc) = lp_kv
            h = L.norm_apply(x, lp["attn_norm"], cfg.norm, cfg.norm_eps)
            a, kc, vc = A.gqa_decode(h, lp["attn"], cfg, kc, vc, pos)
            if cfg.parallel_block:
                f = self._decode_ffn(h, lp)
                x = x + a + f
            else:
                x = x + a
                h2 = L.norm_apply(x, lp["ffn_norm"], cfg.norm, cfg.norm_eps)
                x = x + self._decode_ffn(h2, lp)
            return x, (kc, vc)

        if "first" in params:      # unreached for GQA archs today
            raise NotImplementedError
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["blocks"],
                                    (cache["k"], cache["v"])))
        return x, {"k": ks, "v": vs}

    def _decode_ffn(self, h, lp):
        cfg = self.cfg
        if cfg.moe is not None and "router" in lp["ffn"]:
            f, _ = F.moe_apply(h, lp["ffn"], cfg)
            return f
        return L.mlp_apply(h, lp["ffn"], cfg.act)

    def _decode_mla(self, params, cache, x, pos):
        cfg = self.cfg

        def mk_body(moe):
            def body(x, lp_kv):
                lp, (cc, rc) = lp_kv
                h = L.norm_apply(x, lp["attn_norm"], cfg.norm, cfg.norm_eps)
                a, cc, rc = A.mla_decode(h, lp["attn"], cfg, cc, rc, pos)
                x = x + a
                h2 = L.norm_apply(x, lp["ffn_norm"], cfg.norm, cfg.norm_eps)
                if moe:
                    f, _ = F.moe_apply(h2, lp["ffn"], cfg)
                else:
                    f = L.mlp_apply(h2, lp["ffn"], cfg.act)
                return x + f, (cc, rc)
            return body

        if "first" in params:
            x, (c0, r0) = jax.lax.scan(
                mk_body(False), x,
                (params["first"],
                 (cache["first"]["c_kv"], cache["first"]["k_rope"])))
        x, (cs, rs) = jax.lax.scan(
            mk_body(True), x, (params["blocks"],
                               (cache["c_kv"], cache["k_rope"])))
        out = {"c_kv": cs, "k_rope": rs}
        if "first" in params:
            out["first"] = {"c_kv": c0, "k_rope": r0}
        return x, out

    def _decode_vlm(self, params, cache, x, pos):
        cfg = self.cfg

        def super_body(x, inp):
            sp, (kc, vc), xk, xv = inp

            def self_body(x, lp_kv):
                lp, (k1, v1) = lp_kv
                h = L.norm_apply(x, lp["attn_norm"], cfg.norm, cfg.norm_eps)
                a, k1, v1 = A.gqa_decode(h, lp["attn"], cfg, k1, v1, pos)
                x = x + a
                h2 = L.norm_apply(x, lp["ffn_norm"], cfg.norm, cfg.norm_eps)
                return x + L.mlp_apply(h2, lp["ffn"], cfg.act), (k1, v1)

            x, (ks, vs) = jax.lax.scan(self_body, x, (sp["self"], (kc, vc)))
            pm = sp["cross"]
            h = L.norm_apply(x, pm["attn_norm"], cfg.norm, cfg.norm_eps)
            a = A.gqa_apply(h, pm["attn"], cfg,
                            positions=jnp.asarray(pos)[None],
                            kv_override=(xk, xv))
            a = jnp.tanh(pm["xgate"]).astype(a.dtype) * a
            x = x + a
            h2 = L.norm_apply(x, pm["ffn_norm"], cfg.norm, cfg.norm_eps)
            x = x + L.mlp_apply(h2, pm["ffn"], cfg.act)
            return x, (ks, vs)

        x, (ks, vs) = jax.lax.scan(
            super_body, x,
            (params["blocks"], (cache["self"]["k"], cache["self"]["v"]),
             cache["cross_k"], cache["cross_v"]))
        return x, {"self": {"k": ks, "v": vs},
                   "cross_k": cache["cross_k"],
                   "cross_v": cache["cross_v"]}

    def _decode_encdec(self, params, cache, x, pos):
        cfg = self.cfg

        def body(x, inp):
            lp, xattn, xnorm, (kc, vc), xk, xv = inp
            h = L.norm_apply(x, lp["attn_norm"], cfg.norm, cfg.norm_eps)
            a, kc, vc = A.gqa_decode(h, lp["attn"], cfg, kc, vc, pos)
            x = x + a
            hx = L.norm_apply(x, xnorm, cfg.norm, cfg.norm_eps)
            c = A.gqa_apply(hx, xattn, cfg,
                            positions=jnp.asarray(pos)[None],
                            kv_override=(xk, xv))
            x = x + c
            h2 = L.norm_apply(x, lp["ffn_norm"], cfg.norm, cfg.norm_eps)
            return x + L.mlp_apply(h2, lp["ffn"], cfg.act), (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            body, x,
            (params["blocks"], params["cross_blocks"], params["cross_norms"],
             (cache["k"], cache["v"]), cache["cross_k"], cache["cross_v"]))
        return x, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                   "cross_v": cache["cross_v"]}

    # --------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                     "targets": jax.ShapeDtypeStruct((B, S), i32)}
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        else:                      # decode
            specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                     "pos": jax.ShapeDtypeStruct((), i32)}
        if cfg.frontend == "patch" and shape.kind != "decode":
            specs["media"] = jax.ShapeDtypeStruct(
                (B, cfg.n_media_tokens, cfg.d_model), L.DEFAULT_DTYPE)
        if cfg.frontend == "audio" and shape.kind != "decode":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq_len, cfg.d_model), L.DEFAULT_DTYPE)
        return specs


def _stacked(axes_tree):
    """Prepend a None (layer-stack) dim to every axes tuple in the tree."""
    return jax.tree.map(lambda ax: (None,) + ax,
                        axes_tree, is_leaf=lambda v: isinstance(v, tuple))


def cache_seq_len(cache) -> int:
    leaves = jax.tree.leaves(cache)
    return max(l.shape[2] for l in leaves if l.ndim >= 3)
