"""FFN variants: dense MLP and expert-parallel token-choice MoE.

The MoE layer is the framework's EP showcase: experts are sharded over the
'model' mesh axis via shard_map; tokens stay on their data shard (replicated
over 'model'), each model shard runs its local experts on a capacity-bounded
buffer built by scatter (no (T,E,C) one-hot dispatch tensor — that would be
~100x the token bytes at 32k prefill), and expert outputs are combined with a
single psum over 'model'.  Differentiable end-to-end (scatter-add / gather /
psum all have transposes), so it trains under grad-accumulation + remat.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import layers as L
from repro import parallel as PX
from repro.sharding import batch_axes, current_rules, shard


def moe_init(key, cfg: ArchConfig, dtype=L.DEFAULT_DTYPE):
    m = cfg.moe
    d = cfg.d_model
    E = m.n_experts_padded
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], d, E, dtype=jnp.float32,
                               scale=0.02),
        "w_gate": _expert_init(ks[1], E, d, m.d_ff, dtype),
        "w_up": _expert_init(ks[2], E, d, m.d_ff, dtype),
        "w_down": _expert_init(ks[3], E, m.d_ff, d, dtype),
    }
    if m.n_routed < E:
        # padded experts: router column bias -inf'ish via 0-init rows is not
        # enough; we mask their logits in apply using n_routed.
        pass
    if m.n_shared > 0:
        p["shared"] = L.mlp_init(ks[4], d, m.n_shared * m.d_ff, "silu", dtype)
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (e, d_in, d_out),
                                        jnp.float32) * scale).astype(dtype)


def moe_logical_axes(cfg: ArchConfig):
    m = cfg.moe
    p = {
        "router": (None, None),
        "w_gate": ("expert", "embed", None),
        "w_up": ("expert", "embed", None),
        "w_down": ("expert", None, "embed"),
    }
    if m.n_shared > 0:
        p["shared"] = L.mlp_logical_axes("silu")
    return p


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(math.ceil(n_tokens * m.top_k / m.n_experts_padded
                      * m.capacity_factor))
    return max(8, -(-c // 8) * 8)   # round up to 8


def _moe_local(x, router_w, w_gate, w_up, w_down, *, m: MoEConfig,
               shard_idx, model_axis: Optional[str]):
    """Per-shard MoE.  x: (B_local, S, D); expert weights: local slice."""
    B, S, D = x.shape
    T = B * S
    E = m.n_experts_padded
    E_loc = w_gate.shape[0]
    k = m.top_k
    C = _capacity(T, m)

    x2 = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    if m.n_routed < E:            # mask padded experts out of routing
        pad_mask = jnp.arange(E) < m.n_routed
        logits = jnp.where(pad_mask[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)               # (T,k)

    # --- capacity assignment (global over E, shared across model shards) ---
    flat_e = top_e.reshape(-1)                            # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot
    slot = jnp.sum(pos_in_e, axis=1) - 1                  # (T*k,)
    slot = slot.reshape(T, k)
    keep = slot < C

    e0 = shard_idx * E_loc
    local = (top_e >= e0) & (top_e < e0 + E_loc) & keep   # (T,k)
    b_idx = jnp.where(local, top_e - e0, 0)
    s_idx = jnp.where(local, slot, C)                     # C row = dropped

    buf = jnp.zeros((E_loc, C + 1, D), x.dtype)
    for j in range(k):            # k small (<=6): k scatters, no token repeat
        buf = buf.at[b_idx[:, j], s_idx[:, j]].add(
            x2 * local[:, j, None].astype(x.dtype))

    h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down)

    out = jnp.zeros((T, D), jnp.float32)
    for j in range(k):
        contrib = y[b_idx[:, j], s_idx[:, j]].astype(jnp.float32)
        gate = (top_p[:, j] * local[:, j]).astype(jnp.float32)
        out = out + contrib * gate[:, None]
    if model_axis is not None:
        out = PX.psum(out, model_axis)

    # --- aux losses (identical on every model shard; local-token means) ----
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1)) * E
    mean_prob = jnp.mean(probs, axis=0) * E
    aux = jnp.mean(dispatch_frac * mean_prob) * m.aux_coef
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = aux + m.router_z_coef * zl
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_apply(x, p, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  Shared experts added densely."""
    m = cfg.moe
    rules = current_rules()
    model_ax = None
    if rules is not None:
        ma = rules.rules.get("expert")
        if ma is not None:
            model_ax = ma if isinstance(ma, str) else ma[0]

    if model_ax is None:          # single-shard path (tests, CPU)
        out, aux = _moe_local(x, p["router"], p["w_gate"], p["w_up"],
                              p["w_down"], m=m, shard_idx=0, model_axis=None)
    else:
        mesh = rules.mesh
        from jax.sharding import PartitionSpec as P
        bspec = rules.rules.get("batch")
        n_model = mesh.shape[model_ax]
        assert m.n_experts_padded % n_model == 0, (
            f"experts {m.n_experts_padded} must divide model axis {n_model}")

        def mapped(xl, rw, wg, wu, wd):
            idx = PX.axis_index(model_ax)
            out, aux = _moe_local(xl, rw, wg, wu, wd, m=m, shard_idx=idx,
                                  model_axis=model_ax)
            # aux identical across model shards; average over batch shards
            for ax in batch_axes(rules):
                aux = PX.pmean(aux, ax)
            return out, aux

        out, aux = PX.shard_map(
            mapped, mesh=mesh,
            in_specs=(P(bspec, None, None), P(None, None),
                      P(model_ax, None, None), P(model_ax, None, None),
                      P(model_ax, None, None)),
            out_specs=(P(bspec, None, None), P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared > 0:
        out = out + L.mlp_apply(x, p["shared"], "silu")
    return out, aux
