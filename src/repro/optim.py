"""AdamW with warmup-cosine schedule, global-norm clipping, and ZeRO-style
sharding (optimizer state inherits parameter sharding; with FSDP rules the
states are fully sharded over data x model — ZeRO-3 equivalent).

Implemented from scratch (no optax in this environment).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Any                    # f32 master weights (None if disabled)


class BucketedOptState(NamedTuple):
    """ZeRO-1-style optimizer state over flat f32 buckets.

    ``mu``/``nu``/``master`` are tuples of 1-D f32 arrays, one per bucket
    of a :class:`repro.collectives.bucketing.BucketLayout`.  On a mesh
    they are sharded over the fast (data) axis — each rank holds only its
    contiguous 1/F shard of every bucket — and the train step's
    ``hier_bucketed_zero1`` path updates them shard-resident.
    """

    step: jax.Array
    mu: Any                        # Tuple[jax.Array, ...]
    nu: Any
    master: Any                    # f32 masters (always present)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    use_master: bool = True


def lr_schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # explicit copy: astype on an f32 param would alias the param buffer
    # and break donation in the jitted step
    master = (jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        if cfg.use_master else None)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros), master=master)


def init_bucketed(cfg: AdamWConfig, params, layout) -> BucketedOptState:
    """Bucketed (flat f32) state for the shard-resident optimizer mode.

    Returns *full* (unsharded) buckets; callers on a mesh device_put them
    with a fast-axis sharding (``PartitionSpec(fast_axis)``) so each rank
    materializes only its shard.  Masters are mandatory in this mode —
    they are the source of truth the params are re-gathered from.
    """
    from repro.collectives.bucketing import flatten_to_buckets
    assert cfg.use_master, "bucketed ZeRO-1 state requires f32 masters"
    # explicit copy: for an f32 leaf that exactly fills a bucket,
    # flatten_to_buckets' reshape+astype is a no-op alias of the param
    # buffer — donating params and masters to the jitted step would then
    # donate the same buffer twice (same guard as optim.init)
    master = tuple(jnp.array(b, dtype=jnp.float32, copy=True)
                   for b in flatten_to_buckets(layout, params))
    return BucketedOptState(
        step=jnp.zeros((), jnp.int32),
        mu=tuple(jnp.zeros_like(b) for b in master),
        nu=tuple(jnp.zeros_like(b) for b in master),
        master=master)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_scale(cfg: AdamWConfig, gnorm):
    return jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))


def _adamw_update(cfg: AdamWConfig, g, m, v, base, *, lr, b1c, b2c,
                  scale):
    """One elementwise AdamW update -> (m, v, new_w).

    The single source of the update math: ``apply`` (param tree) and
    ``apply_flat`` (flat bucket shards) both call this, which is what
    makes their bitwise parity — the ``hier_bucketed`` vs
    ``hier_bucketed_zero1`` guarantee — structural rather than a
    copy-paste invariant.
    """
    g = g.astype(jnp.float32) * scale
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
    mh = m / b1c
    vh = v / b2c
    new_w = base - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                         + cfg.weight_decay * base)
    return m, v, new_w


def apply(cfg: AdamWConfig, params, grads, state: OptState, *,
          gnorm=None):
    """One AdamW step.  Returns (new_params, new_state, metrics).

    ``gnorm`` lets callers that already hold a reduced view of the
    gradients (e.g. the bucketed hierarchical paths, which compute the
    norm from reduce-scattered shards) supply the clipping norm instead
    of re-deriving it from the full tree.
    """
    if gnorm is None:
        gnorm = global_norm(grads)
    scale = _clip_scale(cfg, gnorm)
    step = state.step + 1
    # the schedule is 0-based (lr_schedule(0) == 0: warmup ramps from
    # zero), so it is evaluated at the count of *completed* steps; the
    # first update then only seeds the Adam moments instead of taking a
    # half-peak sign-descent step off one batch's gradient
    lr = lr_schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w):
        base = w if w is not None else p.astype(jnp.float32)
        m, v, new_w = _adamw_update(cfg, g, m, v, base, lr=lr, b1c=b1c,
                                    b2c=b2c, scale=scale)
        return new_w.astype(p.dtype), m, v, new_w

    if state.master is not None:
        out = jax.tree.map(upd, params, grads, state.mu, state.nu,
                           state.master)
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_master = (jax.tree.map(lambda t: t[3], out,
                               is_leaf=lambda t: isinstance(t, tuple))
                  if state.master is not None else None)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step, new_mu, new_nu, new_master), metrics


def apply_flat(cfg: AdamWConfig, grads, state: BucketedOptState, *,
               gnorm) -> Tuple[BucketedOptState, Dict[str, jax.Array]]:
    """Shard-resident AdamW over flat f32 bucket (shards).

    ``grads`` is a tuple of flat f32 buffers aligned element-for-element
    with ``state``'s buckets — on a mesh, each rank's reduce-scattered
    shard of the globally meaned gradient.  ``gnorm`` must be the *global*
    norm (see ``bucketing.shard_global_norm``); clipping and the schedule
    are then identical to :func:`apply`, and because every remaining op is
    elementwise the update is bitwise-identical to the replicated path.

    Returns (new_state, metrics); params are the caller's to re-gather
    from ``new_state.master`` (cast to storage dtype on unflatten) — that
    is the whole point: gradients never travel the fast tier twice.
    """
    scale = _clip_scale(cfg, gnorm)
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)     # 0-based, as in apply()
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_mu, new_nu, new_master = [], [], []
    for g, m, v, w in zip(grads, state.mu, state.nu, state.master):
        m, v, new_w = _adamw_update(cfg, g, m, v, w, lr=lr, b1c=b1c,
                                    b2c=b2c, scale=scale)
        new_mu.append(m)
        new_nu.append(v)
        new_master.append(new_w)

    new_state = BucketedOptState(step, tuple(new_mu), tuple(new_nu),
                                 tuple(new_master))
    return new_state, {"lr": lr, "grad_norm": gnorm}
