"""AdamW with warmup-cosine schedule, global-norm clipping, and ZeRO-style
sharding (optimizer state inherits parameter sharding; with FSDP rules the
states are fully sharded over data x model — ZeRO-3 equivalent).

Implemented from scratch (no optax in this environment).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Any                    # f32 master weights (None if disabled)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    use_master: bool = True


def lr_schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # explicit copy: astype on an f32 param would alias the param buffer
    # and break donation in the jitted step
    master = (jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        if cfg.use_master else None)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    # the schedule is 0-based (lr_schedule(0) == 0: warmup ramps from
    # zero), so it is evaluated at the count of *completed* steps; the
    # first update then only seeds the Adam moments instead of taking a
    # half-peak sign-descent step off one batch's gradient
    lr = lr_schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        base = w if w is not None else p.astype(jnp.float32)
        new_w = base - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                             + cfg.weight_decay * base)
        return new_w.astype(p.dtype), m, v, new_w

    if state.master is not None:
        out = jax.tree.map(upd, params, grads, state.mu, state.nu,
                           state.master)
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_master = (jax.tree.map(lambda t: t[3], out,
                               is_leaf=lambda t: isinstance(t, tuple))
                  if state.master is not None else None)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step, new_mu, new_nu, new_master), metrics
