"""Subprocess kill harness: run real checkpoint/driver code, murder it.

In-process fault injection (:mod:`repro.faults.plan`) can raise and
corrupt, but a ``crash`` spec is the only honest way to test the commit
protocol — SIGKILL skips ``finally`` blocks, atexit handlers, and
buffered flushes, exactly like a preempted MIG slice.  Since SIGKILL
takes the test process with it, crash specs must run in a *child*: the
harness serializes a :class:`~repro.faults.plan.FaultPlan` into the
child's environment (the child arms it via
:func:`repro.faults.plan.install_from_env`), runs the child with a
forced fake-device backend, and asserts how it died.

The crash-matrix tests then relaunch the same scenario *without* a plan
and assert the recovery invariants: ``latest_step`` never names a torn
dir, and a resumed run continues bitwise-equal to an uninterrupted
reference.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import sys
import textwrap
from typing import Dict, Optional

from repro.faults.plan import ENV_VAR, FaultPlan

# child preamble: arm the env-serialized plan before anything else runs
CHILD_PROLOGUE = textwrap.dedent("""\
    from repro.faults.plan import install_from_env
    install_from_env()
""")


@dataclasses.dataclass
class ChildResult:
    returncode: int
    stdout: str
    stderr: str

    @property
    def sigkilled(self) -> bool:
        return self.returncode == -signal.SIGKILL


def run_child(code: str, *, plan: Optional[FaultPlan] = None,
              n_devices: int = 0, env: Optional[Dict[str, str]] = None,
              timeout: int = 560, src_dir: Optional[str] = None
              ) -> ChildResult:
    """Run ``code`` (dedented, prefixed with the plan-arming prologue) in
    a child interpreter.

    ``plan`` is serialized into ``$REPRO_FAULT_PLAN``; ``n_devices > 0``
    forces that many fake host devices (XLA device count is locked at
    first init, so this must happen via env, not in-process).
    ``src_dir`` overrides the ``PYTHONPATH`` entry (defaults to the
    ``src`` directory this package was imported from).
    """
    child_env = dict(os.environ)
    if env:
        child_env.update(env)
    if src_dir is None:
        # repro/faults/harness.py -> repro/faults -> repro -> src
        src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
    child_env["PYTHONPATH"] = (src_dir + os.pathsep
                               + child_env.get("PYTHONPATH", ""))
    if n_devices > 0:
        child_env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_devices}")
    if plan is not None:
        child_env[ENV_VAR] = plan.to_env()
    else:
        child_env.pop(ENV_VAR, None)
    res = subprocess.run(
        [sys.executable, "-c", CHILD_PROLOGUE + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=child_env)
    return ChildResult(res.returncode, res.stdout, res.stderr)


def expect_sigkill(result: ChildResult) -> None:
    """Assert the child died by the plan's crash spec, not by accident."""
    if not result.sigkilled:
        raise AssertionError(
            f"expected the child to be SIGKILLed by its fault plan, got "
            f"returncode {result.returncode}\n--- stdout ---\n"
            f"{result.stdout}\n--- stderr ---\n{result.stderr[-4000:]}")


def expect_clean(result: ChildResult) -> str:
    """Assert the child exited 0; return its stdout."""
    if result.returncode != 0:
        raise AssertionError(
            f"child failed with returncode {result.returncode}\n"
            f"--- stdout ---\n{result.stdout}\n--- stderr ---\n"
            f"{result.stderr[-4000:]}")
    return result.stdout
