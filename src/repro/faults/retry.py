"""Bounded retry-with-exponential-backoff for transient checkpoint I/O.

Multi-tenant MIG hosts see transient I/O failures (ENOSPC races while a
neighbor's checkpoint is being garbage-collected, EIO blips on network
filesystems) that should not kill a training job mid-handoff.  The
sharded writer/reader wrap their filesystem work in
:meth:`RetryPolicy.call`, which retries *only* OSErrors whose errno is in
a transient allow-list, with exponential backoff and a hard retry bound.

Corruption is never retried: a failing CRC means the bytes on disk are
wrong and will be wrong on every read — that is the quarantine/fallback
path's job (:mod:`repro.faults.recovery`), not a backoff loop's.
"""
from __future__ import annotations

import dataclasses
import errno
import time
from typing import Callable, FrozenSet, TypeVar

T = TypeVar("T")

# errnos that plausibly clear on their own; anything else is structural
TRANSIENT_ERRNOS: FrozenSet[int] = frozenset(
    {errno.EIO, errno.ENOSPC, errno.EAGAIN, errno.EINTR})


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """``max_retries`` *additional* attempts after the first; delay
    doubles from ``base_delay_s`` capped at ``max_delay_s``.  The default
    (0 retries) makes ``call`` a plain invoke — callers opt in."""

    max_retries: int = 0
    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    errnos: FrozenSet[int] = TRANSIENT_ERRNOS

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("delays must be >= 0")

    def retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, OSError) and exc.errno in self.errnos

    def call(self, fn: Callable[[], T]) -> T:
        """Run ``fn``; on a transient OSError retry up to ``max_retries``
        times with exponential backoff, then re-raise.  Non-transient
        exceptions propagate immediately."""
        delay = self.base_delay_s
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except OSError as exc:
                if attempt >= self.max_retries or not self.retryable(exc):
                    raise
                if delay > 0:
                    time.sleep(min(delay, self.max_delay_s))
                delay *= 2
        raise AssertionError("unreachable")


NO_RETRY = RetryPolicy()
