"""Fault-injection plane + crash-safe recovery for the checkpoint path.

Layering (import-cycle contract): this package init re-exports only the
*injection* and *retry* halves (:mod:`repro.faults.plan`,
:mod:`repro.faults.retry` — stdlib/numpy only), because
``repro.checkpoint`` and ``repro.ckpt.sharded`` import them to host
their injection points.  The *recovery* half
(:mod:`repro.faults.recovery`) imports the checkpoint modules in turn,
and the kill harness (:mod:`repro.faults.harness`) sits above both —
consumers import those submodules explicitly.
"""
from repro.faults.plan import (ENV_VAR, JOB_ENV_VAR, KINDS, FaultPlan,
                               FaultSpec, FiredFault, active_plan,
                               install, install_from_env, maybe_fire,
                               plans_to_env)
from repro.faults.retry import (NO_RETRY, TRANSIENT_ERRNOS, RetryPolicy)

__all__ = [
    "ENV_VAR", "JOB_ENV_VAR", "KINDS", "FaultPlan", "FaultSpec",
    "FiredFault", "active_plan", "install", "install_from_env",
    "maybe_fire", "plans_to_env",
    "NO_RETRY", "TRANSIENT_ERRNOS", "RetryPolicy",
]
