"""Deterministic fault-injection plane for the checkpoint/handoff path.

The recovery machinery this repo claims (atomic commits, CRC quarantine,
restart-resume) is only as real as the failures it has survived.  This
module provides the *injection* half: a seeded :class:`FaultPlan` holding
:class:`FaultSpec` entries keyed by **named injection points** that the
checkpoint writers/readers and the elastic driver call out to
(:func:`maybe_fire`) at every step of their protocols.  A spec decides
deterministically — by arrival count at its point, never by wallclock —
when to

- raise ``ENOSPC`` / ``EIO`` (transient-I/O faults the retry policy must
  absorb, or hard failures the async writer must surface at join);
- truncate or bit-flip the file just written (*post*-CRC-computation, so
  the corruption is invisible until a reader checksums it — the case the
  shard-level quarantine exists for);
- SIGKILL the process on the spot (the crash-matrix tests relaunch and
  assert the commit protocol's invariant).

Plans are installed ambiently (:func:`install` context manager) so
production code pays one module-global ``None`` check per point when no
plan is armed, and serialized through the environment
(:meth:`FaultPlan.to_env` / :func:`install_from_env`) so the subprocess
kill harness can arm a child process it is about to murder.

Injection-point names threaded through the repo (see README
"Fault tolerance" for the full protocol map):

==========================  ================================================
point                       fired
==========================  ================================================
``sharded.write``           before each sharded payload ``np.save``
``sharded.written``         after each payload write (path of the file)
``sharded.manifest``        after the manifest write (commit marker,
                            still in the temp dir; path of the file)
``sharded.pre_rename_aside``  before a same-step re-save moves the old
                            commit aside
``sharded.between_renames``  after the rename-aside, before the commit
                            rename — the ``.old-*`` crash window
``sharded.committed``       after the atomic commit rename
``sharded.read``            before each shard-file ``np.load`` on restore
``legacy.write``            before each legacy per-leaf write
``legacy.manifest``         before the legacy manifest ``os.replace``
``legacy.read``             before each legacy leaf ``np.load``
``driver.pre_save``         ElasticDriver: entering a handoff save
``driver.post_restore``     ElasticDriver: reshard-restore returned
``driver.first_step``       ElasticDriver: before the first (jit-
                            compiling) step of each mesh segment
==========================  ================================================
"""
from __future__ import annotations

import contextlib
import dataclasses
import errno
import json
import os
import signal
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

ENV_VAR = "REPRO_FAULT_PLAN"

# which job a cluster child *is* — set by the cluster runtime's
# JobManager so a namespaced fault plan (see plans_to_env) only arms in
# the subprocess it targets
JOB_ENV_VAR = "REPRO_JOB_ID"

KINDS = ("enospc", "eio", "truncate", "bitflip", "crash")

# .npy files put their header in the first ~128 bytes; corrupting past it
# keeps np.load parseable so the damage is only visible to the CRC check
_NPY_HEADER_BYTES = 128


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: at arrival ``hit`` (1-based) of injection
    point ``point``, apply ``kind``; keep firing for ``times``
    consecutive arrivals (>1 models a transient fault window a bounded
    retry must outlast)."""

    point: str
    kind: str
    hit: int = 1
    times: int = 1
    nbytes: int = 1               # bitflip: bytes to flip; truncate: keep-frac denominator

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {KINDS}")
        if self.hit < 1 or self.times < 1:
            raise ValueError(f"hit/times must be >= 1 ({self})")

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d) -> "FaultSpec":
        return FaultSpec(point=d["point"], kind=d["kind"],
                         hit=int(d.get("hit", 1)),
                         times=int(d.get("times", 1)),
                         nbytes=int(d.get("nbytes", 1)))


@dataclasses.dataclass
class FiredFault:
    """Record of one applied fault (for assertions in tests/benches)."""
    point: str
    kind: str
    count: int
    path: Optional[str]


class FaultPlan:
    """A seeded set of :class:`FaultSpec`\\ s plus per-point arrival
    counters.  ``fire`` is called by the production code's injection
    points; the plan applies every matching spec."""

    def __init__(self, specs: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.counts: Dict[str, int] = {}
        self.fired: List[FiredFault] = []

    # ------------------------------------------------------------- firing
    def fire(self, point: str, *, path: Optional[str] = None) -> None:
        count = self.counts.get(point, 0) + 1
        self.counts[point] = count
        for spec in self.specs:
            if spec.point != point:
                continue
            if not (spec.hit <= count < spec.hit + spec.times):
                continue
            self.fired.append(FiredFault(point, spec.kind, count, path))
            self._apply(spec, path)

    def _apply(self, spec: FaultSpec, path: Optional[str]) -> None:
        if spec.kind == "enospc":
            raise OSError(errno.ENOSPC, os.strerror(errno.ENOSPC),
                          path or spec.point)
        if spec.kind == "eio":
            raise OSError(errno.EIO, os.strerror(errno.EIO),
                          path or spec.point)
        if spec.kind == "crash":
            # a real SIGKILL: no atexit handlers, no finally blocks — the
            # only state that survives is what the commit protocol
            # already made durable
            os.kill(os.getpid(), signal.SIGKILL)
        # file-corruption kinds need the just-written file
        if path is None or not os.path.exists(path):
            raise RuntimeError(
                f"fault {spec.kind!r} at {spec.point!r} needs a file "
                f"path, got {path!r}")
        size = os.path.getsize(path)
        if spec.kind == "truncate":
            os.truncate(path, max(size // 2, 0))
        elif spec.kind == "bitflip":
            lo = min(_NPY_HEADER_BYTES, max(size - 1, 0))
            with open(path, "r+b") as f:
                for _ in range(max(spec.nbytes, 1)):
                    off = int(self.rng.integers(lo, max(size, lo + 1)))
                    f.seek(off)
                    b = f.read(1)
                    f.seek(off)
                    f.write(bytes([b[0] ^ 0xFF]) if b else b"\xff")

    # -------------------------------------------------------- env plumbing
    def to_env(self) -> str:
        """Serialize for a child process (``env[ENV_VAR] = plan.to_env()``)."""
        return json.dumps({"seed": self.seed,
                           "specs": [s.to_dict() for s in self.specs]})

    @staticmethod
    def from_env(value: str) -> "FaultPlan":
        d = json.loads(value)
        return FaultPlan([FaultSpec.from_dict(s) for s in d["specs"]],
                         seed=int(d.get("seed", 0)))


# ambient plan: production code calls maybe_fire at every injection
# point; a single global None check is the entire no-fault overhead
_ACTIVE: Optional[FaultPlan] = None


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def maybe_fire(point: str, *, path: Optional[str] = None) -> None:
    """The hook production code calls at a named injection point."""
    if _ACTIVE is not None:
        _ACTIVE.fire(point, path=path)


@contextlib.contextmanager
def install(plan: FaultPlan):
    """Arm ``plan`` ambiently for the duration of the context."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


def plans_to_env(plans: Dict[str, FaultPlan]) -> str:
    """Serialize a *namespaced* plan set — one plan per job id — for a
    multi-job (cluster) environment.  Every cluster child inherits the
    same ``$REPRO_FAULT_PLAN``; :func:`install_from_env` arms only the
    entry matching the child's own job id, so a plan aimed at one job
    can never fire inside its co-scheduled neighbors."""
    return json.dumps({"jobs": {jid: json.loads(p.to_env())
                                for jid, p in plans.items()}})


def install_from_env(job_id: Optional[str] = None) -> Optional[FaultPlan]:
    """Arm the plan serialized in ``$REPRO_FAULT_PLAN`` (kill-harness and
    cluster children call this first thing; no-op without the variable).
    The plan stays armed for the life of the process — crash specs make
    the process not outlive them anyway.

    Two wire formats:

    - legacy single plan (top-level ``specs``): armed unconditionally,
      exactly as before — the single-job kill harness's path;
    - namespaced (top-level ``jobs``: job id -> plan, from
      :func:`plans_to_env`): only the entry for this process's job id is
      armed.  ``job_id`` defaults to ``$REPRO_JOB_ID``; a process with
      no job id, or one no entry targets, arms nothing.
    """
    global _ACTIVE
    value = os.environ.get(ENV_VAR)
    if not value:
        return None
    d = json.loads(value)
    if "jobs" in d:
        if job_id is None:
            job_id = os.environ.get(JOB_ENV_VAR)
        entry = d["jobs"].get(job_id) if job_id is not None else None
        if entry is None:
            return None
        _ACTIVE = FaultPlan(
            [FaultSpec.from_dict(s) for s in entry["specs"]],
            seed=int(entry.get("seed", 0)))
        return _ACTIVE
    _ACTIVE = FaultPlan.from_env(value)
    return _ACTIVE
