"""Crash-safe restore: committed-step fallback walk + quarantine.

The sharded format's CRC catches silent corruption (bit rot, torn
writes that survived the atomic-rename protocol's crash windows), but a
raise at restore time kills the relaunched job exactly when it is trying
to recover.  This module turns that raise into a *fallback walk*: try
the newest committed step; if restoring it fails for a reason that means
"these bytes are bad" (checksum mismatch, unparseable manifest, missing
shard file), quarantine that step directory on disk — rename it so step
discovery stops offering it — and fall back to the previous committed
step, repeating until a restore succeeds or history runs out.  The
caller gets a structured :class:`RecoveryReport` of everything that was
skipped and why; an empty history still fails loudly (a job with no
recoverable state must not silently start from scratch).

Quarantining renames ``step_<n>`` to ``step_<n>.quarantined-<pid>``:
the name no longer matches the committed-step regex, so
``latest_step``/``committed_steps`` — and with them the elastic driver's
stale-checkpoint guards — all agree the step is gone, while the bytes
stay on disk for forensics.  Quarantined dirs are *not* garbage
collected by later saves (unlike ``.old-*``/``.tmp-*`` debris): they are
evidence.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, List, Optional, Tuple

from repro import checkpoint as ckpt_legacy
from repro.checkpoint import CorruptCheckpointError
from repro.ckpt.manifest import ManifestError
from repro.faults.retry import NO_RETRY, RetryPolicy

# exception types that mean "this step's bytes are unusable" (fall back)
# rather than "the caller's request is malformed" (propagate).  OSError
# covers missing/unreadable shard files; ValueError/EOFError cover
# np.load on truncated npy payloads; json decode errors are ValueError.
RESTORABLE_ERRORS = (CorruptCheckpointError, ManifestError, OSError,
                     ValueError, EOFError, KeyError)


@dataclasses.dataclass(frozen=True)
class QuarantineRecord:
    """One committed step that was offered, failed, and was skipped."""
    step: int
    path: str
    error: str                     # repr of the triggering exception
    quarantined_to: Optional[str]  # on-disk rename target, if performed


@dataclasses.dataclass
class RecoveryReport:
    """What the fallback walk did to produce a restored state."""
    base_dir: str
    attempted: List[int] = dataclasses.field(default_factory=list)
    quarantined: List[QuarantineRecord] = dataclasses.field(
        default_factory=list)
    restored_step: Optional[int] = None
    retries_used: int = 0

    @property
    def fell_back(self) -> bool:
        return bool(self.quarantined)

    def to_dict(self):
        return {
            "base_dir": self.base_dir,
            "attempted": list(self.attempted),
            "quarantined": [dataclasses.asdict(q)
                            for q in self.quarantined],
            "restored_step": self.restored_step,
            "retries_used": self.retries_used,
        }


def quarantine_dir(path: str) -> str:
    """Rename a bad step dir out of the committed-step namespace."""
    target = f"{path}.quarantined-{os.getpid()}"
    n = 0
    while os.path.exists(target):
        n += 1
        target = f"{path}.quarantined-{os.getpid()}.{n}"
    os.rename(path, target)
    return target


def walk_committed(base_dir: str,
                   attempt: Callable[[int, str], Any], *,
                   quarantine_on_disk: bool = True,
                   max_fallbacks: Optional[int] = None,
                   report: Optional[RecoveryReport] = None
                   ) -> Tuple[Any, RecoveryReport]:
    """Run ``attempt(step, step_path)`` over committed steps newest-first
    until one succeeds; quarantine the ones that fail restorably.

    ``max_fallbacks`` bounds how many *bad* steps may be skipped (None =
    walk the whole history).  Raises :class:`CorruptCheckpointError` when
    no committed step exists or every candidate failed — recovery that
    cannot recover must be loud.
    """
    rep = report if report is not None else RecoveryReport(base_dir)
    steps = ckpt_legacy.committed_steps(base_dir)
    if not steps:
        raise CorruptCheckpointError(
            f"no committed checkpoint under {base_dir!r} — nothing to "
            f"restore from")
    for step in reversed(steps):
        if (max_fallbacks is not None
                and len(rep.quarantined) > max_fallbacks):
            break
        path = ckpt_legacy.step_dir(base_dir, step)
        rep.attempted.append(step)
        try:
            result = attempt(step, path)
        except RESTORABLE_ERRORS as exc:
            moved = None
            if quarantine_on_disk and os.path.isdir(path):
                moved = quarantine_dir(path)
            rep.quarantined.append(QuarantineRecord(
                step=step, path=path, error=repr(exc),
                quarantined_to=moved))
            continue
        rep.restored_step = step
        return result, rep
    raise CorruptCheckpointError(
        f"every committed checkpoint under {base_dir!r} failed to "
        f"restore; quarantined "
        f"{[q.step for q in rep.quarantined]} "
        f"({[q.error for q in rep.quarantined]})")


def restore_with_fallback(base_dir: str, template, *, shardings=None,
                          policy=None, layout=None, verify: bool = True,
                          retry: RetryPolicy = NO_RETRY,
                          quarantine_on_disk: bool = True,
                          max_fallbacks: Optional[int] = None
                          ) -> Tuple[int, Any, RecoveryReport]:
    """``restore_auto`` with transient-I/O retry and corrupt-step
    fallback.  Returns ``(step, tree, report)``.

    Transient OSErrors inside one step's restore are retried per
    ``retry`` *before* the step is declared bad; only after retries are
    exhausted (or on non-transient corruption) does the walk quarantine
    and fall back.
    """
    from repro.ckpt import restore_auto     # deferred: package init cycle
    rep = RecoveryReport(base_dir)

    def attempt(step: int, path: str):
        tries = 0

        def once():
            nonlocal tries
            tries += 1
            return restore_auto(path, template, shardings=shardings,
                                policy=policy, layout=layout,
                                verify=verify)
        try:
            return retry.call(once)
        finally:
            rep.retries_used += tries - 1

    (step, tree), rep = walk_committed(
        base_dir, attempt, quarantine_on_disk=quarantine_on_disk,
        max_fallbacks=max_fallbacks, report=rep)
    return step, tree, rep
