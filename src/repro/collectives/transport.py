"""Transport selection + analytic bandwidth model (paper Fig. 10/11).

Models AllReduce/AllGather/ReduceScatter bus bandwidth for:
- GPU testbed transports: SHM (host shared memory across MIG leaves) vs
  NET (RDMA) — the paper's Fig. 11 microbenchmark;
- TPU fabrics: intra-pod ICI vs cross-pod DCN — the adapted two-tier cliff
  used for roofline collective terms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

# Canonical tier constants live in the runtime layer so the analytic model
# and the executable collectives (repro.collectives.hierarchical) price
# and name the same transports; re-exported here for back-compat.
from repro.parallel.transport import (DCN_GBPS_PER_HOST, ICI_GBPS_PER_LINK,
                                      ICI_LINKS, NET_GBPS, NET_LATENCY_S,
                                      PCIE_GBPS, SHM_LATENCY_S,
                                      SHM_STREAM_GBPS, TIERS)


@dataclasses.dataclass(frozen=True)
class CollectivePerf:
    transport: str
    n_ranks: int
    bytes_per_rank: float
    bus_bandwidth_gbps: float
    time_s: float


def _ring_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all_reduce":
        return 2.0 * (n - 1) / n
    if op in ("all_gather", "reduce_scatter"):
        return (n - 1) / n
    if op == "all_to_all":
        return (n - 1) / n
    raise ValueError(op)


def gpu_collective(op: str, nbytes: float, *, transport: str,
                   leaves_per_gpu: Tuple[int, ...],
                   concurrent_net_jobs: int = 1) -> CollectivePerf:
    """Paper testbed model: SHM streams share each GPU's PCIe interface;
    NET shares the host NIC across concurrent jobs."""
    n = sum(leaves_per_gpu)
    traffic = _ring_factor(op, n) * nbytes
    if transport == "SHM":
        worst = max(leaves_per_gpu) if leaves_per_gpu else 1
        bw = min(TIERS["SHM"].gbps, PCIE_GBPS / max(1, worst))
        lat = TIERS["SHM"].latency_s
    else:
        bw = TIERS["NET"].gbps / max(1, concurrent_net_jobs)
        lat = TIERS["NET"].latency_s
    t = traffic / (bw * 1e9) + lat * max(1, n - 1)
    bus = (nbytes * _ring_factor(op, n)) / t / 1e9 if t > 0 else 0.0
    return CollectivePerf(transport, n, nbytes, bus, t)


def tpu_collective_time(op: str, nbytes_per_chip: float, *, n_chips: int,
                        axis: str) -> float:
    """Roofline collective-term helper: time to move ``nbytes_per_chip``
    through the named fabric tier."""
    if n_chips <= 1:
        return 0.0
    traffic = _ring_factor(op, n_chips) * nbytes_per_chip
    tier = TIERS["ICI" if axis == "ici" else "DCN"]
    return traffic / (tier.gbps * 1e9)        # per-link serial model


def hierarchical_vs_flat_bytes(nbytes: float, *, fast: int,
                               slow: int) -> Dict[str, float]:
    """Slow-boundary bytes: flat all-reduce vs hierarchical schedule.

    Flat ring spanning both tiers sends O(nbytes) across the slow cut;
    hierarchical sends nbytes/fast (the reduce-scattered shard).
    """
    flat_slow = 2.0 * (slow - 1) / slow * nbytes
    hier_slow = 2.0 * (slow - 1) / slow * (nbytes / fast)
    return {"flat_slow_bytes": flat_slow, "hier_slow_bytes": hier_slow,
            "reduction": flat_slow / max(hier_slow, 1e-12)}
