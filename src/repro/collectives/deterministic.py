"""Deterministic (mesh-factorization-invariant) bucket reduction.

The hierarchical schedule's floating-point sum *grouping* follows the mesh
factorization: on a (2, 2) pod x data mesh the global mean is
``(g0+g1)+(g2+g3)`` while a (4, 1) or (1, 4) mesh sums linearly — the
results differ at the ulp level, so a training run restored onto a
re-factorized mesh (the elastic repack path) drifts bitwise even though
every rank's local gradient is identical.

This module fixes the associativity instead of the mesh: every rank

1. all-gathers all R = S*F per-rank contributions over (slow, fast) into
   *global pod-major rank order* — the linearization is a property of the
   job, not of the (S, F) factorization;
2. sums them with a fixed pairwise balanced-tree fold
   (:func:`tree_fold_sum`) and divides by R.

The result is bitwise-identical for every (S, F) factorization of the
same R ranks, which is what makes the sharded-checkpoint reshard test
(save on (2,2), restore on (4,1)/(1,4), continue) *bitwise* verifiable —
the property the elastic/repack machinery relies on to prove a
reconfiguration lost nothing.

Cost: the gather moves R/F x the bytes of a reduce-scatter and every rank
transiently holds the (R, bucket) stack, so this is the *verification /
elasticity* schedule, not the bandwidth-optimal one — the hierarchical
bucketed schedule remains the production path.  With
``compress_bits=8`` each rank int8-quantizes its own full contribution
before the gather (4x fewer bytes on every hop) and, with error
feedback, carries the residual of its *own* contribution — per-global-rank
state that reshards exactly under any re-factorization (unlike the
hierarchical EF residuals, whose shard assignment follows the pod
structure).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import parallel as PX
from repro.collectives.compression import dequantize_int8, quantize_int8

# Buckets in deterministic mode are padded to a multiple of this, so the
# padded bucket sizes — and with them every jnp.sum / fold shape — are
# identical across mesh factorizations whose fast-axis size divides it.
DETERMINISTIC_ALIGN = 64


def det_align(fast_size: int) -> int:
    """Mesh-invariant bucket alignment: lcm(fast, DETERMINISTIC_ALIGN).

    For the power-of-two fast sizes real meshes use this is just
    DETERMINISTIC_ALIGN, making the padded bucket sizes a pure function
    of the leaf shapes — the reshard-on-restore exactness guarantee.
    """
    import math
    f = max(1, int(fast_size))
    return f * DETERMINISTIC_ALIGN // math.gcd(f, DETERMINISTIC_ALIGN)


def gather_rank_stack(x, sync_axes: Sequence[str]):
    """All-gather ``x`` over ``sync_axes`` into global pod-major order.

    ``sync_axes`` is (outer, ..., inner) — ("pod", "data") in the train
    step.  Returns an ``(R,) + x.shape`` stack whose index is the global
    linear rank id, independent of how R factors over the axes.
    """
    out = x[None]
    for ax in reversed(tuple(sync_axes)):
        n = PX.axis_size(ax)
        if n > 1:
            out = PX.all_gather(out, ax, gather_axis=0, tiled=False)
            out = out.reshape((-1,) + x.shape)
    return out


def tree_fold_sum(stack):
    """Balanced pairwise fold over axis 0 — a fixed summation tree.

    ``((g0+g1)+(g2+g3))+...``: depends only on the number of
    contributions, never on how the mesh factors them.  Odd tails pass
    through to the next level unchanged.
    """
    while stack.shape[0] > 1:
        m = stack.shape[0]
        half = m // 2
        folded = stack[: 2 * half : 2] + stack[1 : 2 * half : 2]
        stack = (jnp.concatenate([folded, stack[2 * half:]], axis=0)
                 if m % 2 else folded)
    return stack[0]


def det_mean(x, sync_axes: Sequence[str]):
    """Mesh-invariant mean of a per-rank value (loss scalars, metrics)."""
    axes = tuple(a for a in sync_axes if a and PX.axis_size(a) > 1)
    if not axes:
        return x
    stack = gather_rank_stack(x, sync_axes)
    return tree_fold_sum(stack) / stack.shape[0]


def det_reduce_bucket_full(buckets: Sequence[jax.Array], *,
                           sync_axes: Sequence[str],
                           compress_bits: int = 0,
                           residuals: Optional[Sequence[jax.Array]] = None
                           ) -> Tuple[Tuple[jax.Array, ...], tuple]:
    """Deterministic global mean of flat f32 buckets.

    Every rank ends up holding the *full* meaned bucket (identical bits on
    every rank and for every mesh factorization).  ``compress_bits``
    compresses each rank's own contribution before the gather (16 = bf16,
    8 = int8 + per-bucket scale); ``residuals`` (int8 only; one per
    bucket, each the size of the rank's full bucket) switches on error
    feedback over the rank's own contribution.  Returns
    ``(full_buckets, new_residuals)`` — residuals are ``()`` when error
    feedback is off.
    """
    if residuals is not None and compress_bits != 8:
        raise ValueError(
            "deterministic error feedback requires the int8 contribution "
            f"(compress_bits=8, got {compress_bits})")
    res_in = tuple(residuals) if residuals is not None else (None,) * len(
        tuple(buckets))
    full, res_out = [], []
    for b, res in zip(buckets, res_in):
        contrib = b.astype(jnp.float32)
        new_res = None
        if res is not None:
            contrib = contrib + res.astype(jnp.float32)
        if compress_bits == 8:
            q, scale = quantize_int8(contrib)
            recon = dequantize_int8(q, scale)
            if res is not None:
                new_res = contrib - recon
            qs = gather_rank_stack(q, sync_axes)          # (R, C) int8
            ss = gather_rank_stack(scale, sync_axes)      # (R,)
            stack = qs.astype(jnp.float32) * ss.reshape((-1, 1))
        elif compress_bits == 16:
            stack = gather_rank_stack(
                contrib.astype(jnp.bfloat16), sync_axes).astype(jnp.float32)
        else:
            assert compress_bits == 0, compress_bits
            stack = gather_rank_stack(contrib, sync_axes)
        full.append(tree_fold_sum(stack) / stack.shape[0])
        res_out.append(new_res)
    # seal the reduction: without the barrier XLA's algebraic simplifier
    # may fuse the /R division into downstream elementwise consumers
    # (e.g. the optimizer's clip-scale multiply) with context-dependent
    # rounding — observed as a 1-ulp drift on (4,1) meshes, where the
    # ZeRO-1 shard IS the full bucket and the fusion window is widest.
    # The barrier pins `full` to one self-contained subgraph, so its bits
    # depend only on the gathered stack, never on the consuming program.
    full = list(jax.lax.optimization_barrier(tuple(full)))
    if residuals is not None:
        return tuple(full), tuple(res_out)
    return tuple(full), ()


def det_fast_shards(full_buckets: Sequence[jax.Array],
                    fast_axis: Optional[str]) -> Tuple[jax.Array, ...]:
    """Each rank's contiguous fast-axis slice of the full meaned buckets.

    The deterministic analogue of the reduce-scattered shard the ZeRO-1
    optimizer consumes; identity when the fast axis is absent/trivial.
    """
    if fast_axis is None or PX.axis_size(fast_axis) <= 1:
        return tuple(full_buckets)
    nf = PX.axis_size(fast_axis)
    idx = PX.axis_index(fast_axis)
    out = []
    for b in full_buckets:
        size = b.shape[0] // nf
        out.append(jax.lax.dynamic_slice(b, (idx * size,), (size,)))
    return tuple(out)


def det_global_norm(full_buckets: Sequence[jax.Array]) -> jax.Array:
    """Global gradient norm from the full meaned buckets.

    Pure local arithmetic on data that is bitwise-identical on every rank
    and across factorizations (same padded shapes via :func:`det_align`),
    so no collective is needed and the result is mesh-invariant — the
    clip scale, and with it the whole optimizer update, stays bitwise
    reproducible under resharding.
    """
    ss = jnp.zeros((), jnp.float32)
    for b in full_buckets:
        ss = ss + jnp.sum(jnp.square(b.astype(jnp.float32)))
    return jnp.sqrt(ss)
