"""Gradient compression for the slow (cross-pod / NET) hop.

int8 block quantization with per-tensor scale: the cross-pod all-reduce is
implemented as all_gather(int8) + local dequantize-mean, cutting slow-axis
bytes 4x vs f32 (2x vs bf16).  Error feedback (residual carrying) keeps the
quantization noise unbiased across steps.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import parallel as PX


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _int8_gather_mean(q, scale, axis: str, *, like):
    """int8 transport: all_gather quantized shards + per-shard scales,
    dequantize-mean locally.  The single implementation both the plain
    and error-feedback slow hops ride (their parity depends on it)."""
    n = PX.axis_size(axis)
    qs = PX.all_gather(q, axis, gather_axis=0, tiled=False)      # (n, ...)
    ss = PX.all_gather(scale, axis, gather_axis=0, tiled=False)  # (n,)
    deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * like.ndim)
    return (jnp.sum(deq, axis=0) / n).astype(like.dtype)


def compressed_psum_mean(x, axis: str, *, bits: int = 8):
    """Mean-reduce ``x`` over mesh axis ``axis`` with compressed transport.

    Runs inside shard_map.  bits=16 casts to bf16 (psum native); bits=8
    all_gathers int8 + per-shard scales and averages locally.
    """
    if bits == 16:
        n = PX.axis_size(axis)
        y = PX.psum(x.astype(jnp.bfloat16), axis)
        return (y.astype(jnp.float32) / n).astype(x.dtype)
    assert bits == 8, bits
    q, scale = quantize_int8(x)
    return _int8_gather_mean(q, scale, axis, like=x)


def apply_error_feedback(grad, residual: Optional[jax.Array], *,
                         bits: int = 8):
    """Returns (compressed-representable grad, new residual)."""
    g = grad.astype(jnp.float32)
    if residual is not None:
        g = g + residual.astype(jnp.float32)
    q, scale = quantize_int8(g)
    gq = dequantize_int8(q, scale)
    return gq.astype(grad.dtype), (g - gq).astype(jnp.float32)


def compressed_psum_mean_ef(x, residual, axis: str, *, bits: int = 8):
    """:func:`compressed_psum_mean` with error feedback on the int8 hop.

    The residual from previous steps is folded into ``x`` *before*
    quantization and the part the int8 grid cannot represent is carried
    forward, so the quantization noise telescopes instead of
    accumulating (:func:`apply_error_feedback`, fused here so the value
    that crosses the slow tier is quantized exactly once).  Runs inside
    shard_map; the residual is per-rank state in the same units as ``x``.
    Returns ``(mean, new_residual)``.
    """
    assert bits == 8, "error feedback is defined for the int8 hop"
    g = x.astype(jnp.float32) + residual.astype(jnp.float32)
    q, scale = quantize_int8(g)
    new_res = g - dequantize_int8(q, scale)
    return _int8_gather_mean(q, scale, axis, like=x), new_res
