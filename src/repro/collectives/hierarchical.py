"""Two-level ("SHM-first") collectives — the paper's runtime insight on TPU.

Flex-MIG's SHM collectives exploit the intra-host fast path between leaves;
on TPU the same two-tier bandwidth cliff separates intra-pod ICI
(~50 GB/s/link) from cross-pod DCN.  These shard_map collectives implement
the hierarchical schedule explicitly:

    all_reduce  = reduce_scatter(fast axis)
                -> all_reduce(slow axis, optionally compressed)
                -> all_gather(fast axis)

which moves only 1/F of the tensor across the slow boundary (F = fast-axis
size) instead of the whole tensor — exactly the paper's "keep bulk traffic
on SHM, not NET" principle.  Fast/slow classification comes from
``repro.parallel.transport`` (the same tier map the analytic bandwidth
model prices), measured in lowered-HLO collective bytes by
benchmarks/fig11_allreduce_bw.py and used by the train step's
``cross_pod_grad_mode='hier*'`` paths.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import parallel as PX
from repro.collectives.compression import (compressed_psum_mean,
                                           compressed_psum_mean_ef)
from repro.parallel.transport import is_slow_axis


def fast_reduce_scatter(flat, fast_axis: Optional[str]):
    """Stage 1 of the hierarchical schedule: fast-axis reduce-scatter.

    Identity when the fast axis is absent or trivial.  ``flat`` must be
    1-D with length divisible by the fast-axis size.  Exposed separately
    from :func:`hier_reduce_mean_shard` so the bucketed paths can
    software-pipeline it against the previous bucket's slow hop.
    """
    nf = PX.axis_size(fast_axis) if fast_axis is not None else 1
    return PX.reduce_scatter_flat(flat, fast_axis) if nf > 1 else flat


def slow_mean_shard(shard, *, fast_axis: Optional[str],
                    slow_axis: Optional[str], compress_bits: int = 0,
                    residual=None):
    """Stage 2: slow-axis mean (optionally compressed) + /F normalization.

    ``shard`` is one rank's fast-axis reduce-scattered slice (stage 1's
    output).  When ``residual`` is given the compressed slow hop runs
    with error feedback (int8 only) and the new residual — in the same
    pre-normalization units as the input — is returned alongside:
    ``(meaned_shard, new_residual)``.  With ``residual=None`` only the
    shard is returned.
    """
    nf = PX.axis_size(fast_axis) if fast_axis is not None else 1
    if slow_axis is not None:
        if compress_bits and residual is not None:
            shard, residual = compressed_psum_mean_ef(
                shard, residual, slow_axis, bits=compress_bits)
        elif compress_bits:
            shard = compressed_psum_mean(shard, slow_axis,
                                         bits=compress_bits)
        else:
            ns = PX.axis_size(slow_axis)
            shard = PX.psum(shard, slow_axis) / ns
    shard = shard / nf
    return shard if residual is None else (shard, residual)


def hier_reduce_mean_shard(flat, *, fast_axis: Optional[str],
                           slow_axis: Optional[str],
                           compress_bits: int = 0):
    """Fast-axis reduce-scatter + slow-axis mean of a flat f32 buffer.

    The shard-level half of the hierarchical schedule: each rank is left
    holding the *globally meaned* 1/F contiguous slice of ``flat``
    (replicated across the slow axis), which is exactly what a
    shard-resident (ZeRO-1) optimizer consumes — the bucketed train paths
    stop here and only all-gather updated params.

    ``flat`` must be 1-D with length divisible by the fast-axis size.
    Either axis may be ``None`` (single-tier / single-device meshes), in
    which case that hop is skipped.  Composition of
    :func:`fast_reduce_scatter` and :func:`slow_mean_shard`, which the
    overlapped bucket schedule calls stage-by-stage — per-bucket
    arithmetic is therefore shared, making serial/overlapped bitwise
    parity structural.
    """
    return slow_mean_shard(fast_reduce_scatter(flat, fast_axis),
                           fast_axis=fast_axis, slow_axis=slow_axis,
                           compress_bits=compress_bits)


def hier_all_reduce_mean(x, *, fast_axis: Optional[str],
                         slow_axis: Optional[str], compress_bits: int = 0):
    """Hierarchical mean all-reduce inside a shard_map body.

    fast_axis: intra-pod axis (ICI / 'SHM'); slow_axis: cross-pod ('NET').
    compress_bits: 0 (full precision) | 16 (bf16) | 8 (int8+scale) for the
    slow hop only.  Pads the flattened tensor so the fast axis divides it.
    """
    nf = PX.axis_size(fast_axis) if fast_axis is not None else 1
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % nf
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = hier_reduce_mean_shard(flat, fast_axis=fast_axis,
                                   slow_axis=slow_axis,
                                   compress_bits=compress_bits)
    flat = (PX.all_gather_flat(shard, fast_axis)        # fast all-gather
            if nf > 1 else shard)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape)


def flat_all_reduce_mean(x, *, axes: Tuple[str, ...]):
    """Baseline: single-level psum over all axes (the 'NET-everything'
    schedule the paper's stock-NCCL workaround forces)."""
    n = 1
    for ax in axes:
        n *= PX.axis_size(ax)
    return PX.psum(x, axes) / n


def make_hier_all_reduce(mesh: Mesh, *, fast_axis: str = "data",
                         slow_axis: Optional[str] = "pod",
                         compress_bits: int = 0, flat: bool = False):
    """jit-able tensor-level hierarchical all-reduce over a mesh.

    Input is expected replicated over 'model' and sharded/replicated over
    (pod, fast) as P() — each (pod, data) cell holds its local copy.
    The default fast/slow split matches the transport tier map; passing a
    slow axis as ``fast_axis`` (or vice versa) is almost certainly a bug.
    """
    assert not is_slow_axis(fast_axis), (
        f"fast_axis {fast_axis!r} is a slow-transport axis")
    assert slow_axis is None or is_slow_axis(slow_axis), (
        f"slow_axis {slow_axis!r} is a fast-transport axis")
    axes = tuple(a for a in (fast_axis, slow_axis) if a in mesh.axis_names)
    slow = slow_axis if (slow_axis and slow_axis in mesh.axis_names) \
        else None

    def fn(x):
        if flat:
            return flat_all_reduce_mean(x, axes=axes)
        return hier_all_reduce_mean(x, fast_axis=fast_axis, slow_axis=slow,
                                    compress_bits=compress_bits)

    return jax.jit(PX.shard_map(
        fn, mesh=mesh,
        in_specs=P(axes),           # distinct value per (pod,data) cell
        out_specs=P(axes),          # mean broadcast back to every cell
        check_vma=False,
        axis_names=set(axes)))
