"""Bucketed flat-buffer gradient collectives (the fused hot path).

The hierarchical schedule in :mod:`repro.collectives.hierarchical` keeps
bulk traffic on the fast tier and moves only a 1/F shard across the slow
tier — but applied *per gradient tensor* it launches 3 collectives + a pad
for every leaf, hundreds of tiny latency-bound ops per step on a real
model.  This module fuses that: the f32 gradient pytree is flattened into
a small number of fixed-capacity contiguous f32 *buckets* with a
deterministic leaf->bucket layout (offsets + shape/dtype metadata, so
unflattening is exact), and the hierarchical schedule runs **once per
bucket**:

    reduce_scatter(fast)  ->  psum(slow, optionally int8/bf16)  ->
    all_gather(fast)

Bucket sizes are padded to a multiple of ``align`` (the fast-axis size),
so the reduce-scatter needs no per-tensor padding.  The layout is pure
metadata — planning works on concrete arrays, tracers, or
``jax.eval_shape`` outputs alike, so the train step and the optimizer
state initializer always derive the *same* layout from the same pytree.

Two consumers:

- ``cross_pod_mode="hier_bucketed"``: buckets carry gradients; the full
  mean gradient is re-gathered and a replicated optimizer applies it.
- ``cross_pod_mode="hier_bucketed_zero1"``: the schedule stops after the
  slow hop; each rank's optimizer updates only its bucket *shard*
  (f32 masters live sharded over the fast axis) and the updated *params*
  are all-gathered instead of gradients.

:func:`make_bucket_loss_and_grad` differentiates the microbatch-
accumulation scan with respect to the flat f32 buckets directly, so
gradients accumulate flat (no per-leaf zero tree) and no full-size f32
params *tree* is ever materialized inside the scan — the f32 buffer the
scan holds IS the bucket set being differentiated.  (That flat f32
differentiation buffer itself remains: it is what makes bf16 training
accumulation-invariant.  What ZeRO-1 mode additionally saves is the
replicated f32 optimizer state — masters and moments live 1/F-sharded.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import parallel as PX
from repro.collectives.hierarchical import (fast_reduce_scatter,
                                            slow_mean_shard)

DEFAULT_BUCKET_BYTES = 32 << 20          # 32 MiB of f32 per bucket


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside the bucket set."""

    bucket: int                  # bucket index
    offset: int                  # f32-element offset within the bucket
    size: int                    # number of elements
    shape: Tuple[int, ...]
    dtype: Any                   # storage dtype (restored on unflatten)


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Deterministic leaf->bucket placement for one pytree structure.

    ``slots`` follow ``jax.tree.flatten`` leaf order; greedy first-fit in
    that order means the layout is a pure function of (tree structure,
    leaf shapes/dtypes, bucket_bytes, align).
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    bucket_sizes: Tuple[int, ...]        # padded numels, each % align == 0
    align: int

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    def n_elements(self) -> int:
        """Live (un-padded) elements across all buckets."""
        return sum(s.size for s in self.slots)

    def n_padded_elements(self) -> int:
        return sum(self.bucket_sizes)


def _round_up(n: int, align: int) -> int:
    return ((n + align - 1) // align) * align


def plan_buckets(tree, *, bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 align: int = 1) -> BucketLayout:
    """Greedy first-fit bucketing of ``tree``'s leaves into f32 buckets.

    A bucket closes when the next leaf would push it past
    ``bucket_bytes`` worth of f32; a single leaf larger than the capacity
    gets a bucket of its own.  Every bucket is padded up to a multiple of
    ``align`` (pass the fast-axis size so reduce-scatter divides evenly).
    """
    assert bucket_bytes >= 4 and align >= 1
    leaves, treedef = jax.tree.flatten(tree)
    capacity = max(1, bucket_bytes // 4)   # f32 elements per bucket
    slots = []
    bucket_sizes = []
    fill = 0
    for leaf in leaves:
        size = 1
        for d in leaf.shape:
            size *= d
        if fill and fill + size > capacity:
            bucket_sizes.append(_round_up(fill, align))
            fill = 0
        slots.append(LeafSlot(bucket=len(bucket_sizes), offset=fill,
                              size=size, shape=tuple(leaf.shape),
                              dtype=leaf.dtype))
        fill += size
    if fill or not bucket_sizes:
        bucket_sizes.append(_round_up(max(fill, 1), align))
    return BucketLayout(treedef=treedef, slots=tuple(slots),
                        bucket_sizes=tuple(bucket_sizes), align=align)


def flatten_to_buckets(layout: BucketLayout, tree) -> Tuple[jax.Array, ...]:
    """Pack the leaves of ``tree`` into f32 buckets per ``layout``.

    Leaves are cast to f32; padding regions are zero.  Exact inverse of
    :func:`unflatten_from_buckets` on the live regions.
    """
    leaves = jax.tree.leaves(tree)
    assert len(leaves) == len(layout.slots), (
        f"{len(leaves)} leaves vs layout of {len(layout.slots)}")
    buckets = []
    for b, cap in enumerate(layout.bucket_sizes):
        parts = [leaf.reshape(-1).astype(jnp.float32)
                 for leaf, slot in zip(leaves, layout.slots)
                 if slot.bucket == b]
        fill = sum(p.shape[0] for p in parts)
        if fill < cap:
            parts.append(jnp.zeros((cap - fill,), jnp.float32))
        buckets.append(parts[0] if len(parts) == 1
                       else jnp.concatenate(parts))
    return tuple(buckets)


def unflatten_from_buckets(layout: BucketLayout,
                           buckets: Sequence[jax.Array], *,
                           dtype=None):
    """Rebuild the pytree from flat buckets.

    ``dtype=None`` restores each leaf's storage dtype from the layout;
    passing a dtype (e.g. ``jnp.float32`` for gradients) overrides it.
    """
    assert len(buckets) == layout.n_buckets
    leaves = []
    for slot in layout.slots:
        flat = jax.lax.slice(buckets[slot.bucket], (slot.offset,),
                             (slot.offset + slot.size,))
        leaves.append(flat.reshape(slot.shape).astype(
            slot.dtype if dtype is None else dtype))
    return jax.tree.unflatten(layout.treedef, leaves)


# ---------------------------------------------------------------------------
# bucket-resident loss/grad + collectives
# ---------------------------------------------------------------------------

def make_bucket_loss_and_grad(model, layout: BucketLayout, *, accum: int):
    """Accumulated (loss, grad-buckets) differentiating wrt flat buckets.

    The forward unflattens the f32 buckets to storage-dtype leaves (so the
    math matches :func:`repro.train.make_loss_and_grad` bit for bit), but
    the cotangent accumulates directly in bucket form: gradients never
    exist as a per-leaf zero tree and no f32 param *tree* is live during
    the scan — only the flat buckets the caller already holds.
    """

    def fn(param_buckets, batch):
        from repro.train import _split_micro
        micro = _split_micro(batch, accum)

        def bucket_loss(bks, mb):
            params = unflatten_from_buckets(layout, bks)
            return model.loss(params, mb)

        def step(carry, mb):
            loss_sum, gbks = carry
            (loss, _metrics), g = jax.value_and_grad(
                bucket_loss, has_aux=True)(param_buckets, mb)
            gbks = tuple(a + b for a, b in zip(gbks, g))
            return (loss_sum + loss, gbks), None

        zero = tuple(jnp.zeros_like(b) for b in param_buckets)
        (loss_sum, grads), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), zero), micro)
        inv = 1.0 / accum
        return loss_sum * inv, tuple(g * inv for g in grads)

    return fn


def hier_reduce_bucket_shards(buckets: Sequence[jax.Array], *,
                              fast_axis: Optional[str],
                              slow_axis: Optional[str],
                              compress_bits: int = 0,
                              overlap: bool = False,
                              residuals: Optional[Sequence[jax.Array]]
                              = None):
    """One hierarchical reduce per *bucket* (not per tensor).

    Returns each rank's globally-meaned contiguous shard of every bucket
    (full buckets when ``fast_axis`` is None / size 1).

    ``overlap=True`` restructures the k-bucket sync as a depth-1 software
    pipeline: bucket i+1's fast-axis reduce-scatter is issued *before*
    bucket i's slow hop, so on a backend with asynchronous collectives
    the slow (DCN/NET) hop of every bucket but the last hides under the
    next bucket's fast (ICI/SHM) phase.  An ``optimization_barrier``
    bundles the two in-flight fast shards at each stage boundary so the
    compiler cannot re-serialize the issue order; no slow collective ever
    feeds a barrier, so consecutive buckets' slow collectives stay
    data-independent in the lowered HLO
    (:func:`repro.analysis.hlo.slow_collective_chains` proves this).
    Per-bucket arithmetic is shared with the serial schedule
    (:func:`fast_reduce_scatter` / :func:`slow_mean_shard`), so the
    result is bitwise-identical; with a single bucket, a trivial fast
    axis, or no slow axis the pipeline silently degenerates to the
    serial path.

    ``residuals`` (one per bucket, per-rank shard-shaped, in the same
    units as the reduce-scattered shard) switches the compressed slow
    hop to error feedback; the return value is then
    ``(shards, new_residuals)`` instead of just the shards.
    """
    k = len(buckets)
    nf = PX.axis_size(fast_axis) if fast_axis is not None else 1
    ns = PX.axis_size(slow_axis) if slow_axis is not None else 1
    if residuals is not None and compress_bits != 8:
        raise ValueError(
            "error-feedback residuals require the int8 slow hop "
            f"(compress_bits=8, got {compress_bits}) — without it the "
            "residuals would silently never update")
    res_in = tuple(residuals) if residuals is not None else (None,) * k
    assert len(res_in) == k, (len(res_in), k)

    def slow(shard, res):
        out = slow_mean_shard(shard, fast_axis=fast_axis,
                              slow_axis=slow_axis,
                              compress_bits=compress_bits, residual=res)
        return out if res is not None else (out, None)

    pipelined = overlap and k >= 2 and nf > 1 and ns > 1
    shards, res_out = [], []
    if not pipelined:
        for b, res in zip(buckets, res_in):
            s, r = slow(fast_reduce_scatter(b, fast_axis), res)
            shards.append(s)
            res_out.append(r)
    else:
        cur = fast_reduce_scatter(buckets[0], fast_axis)
        for i in range(k):
            nxt = None
            if i + 1 < k:
                nxt = fast_reduce_scatter(buckets[i + 1], fast_axis)
                # pin the pipeline: bucket i+1's reduce-scatter is
                # bundled with bucket i's shard, so it cannot sink below
                # bucket i's slow hop
                cur, nxt = jax.lax.optimization_barrier((cur, nxt))
            s, r = slow(cur, res_in[i])
            shards.append(s)
            res_out.append(r)
            cur = nxt
    if residuals is not None:
        return tuple(shards), tuple(res_out)
    return tuple(shards)


def all_gather_buckets(shards: Sequence[jax.Array], *,
                       fast_axis: Optional[str]) -> Tuple[jax.Array, ...]:
    """Re-assemble full buckets from per-rank shards (identity when the
    fast axis is absent or trivial)."""
    if fast_axis is None or PX.axis_size(fast_axis) <= 1:
        return tuple(shards)
    return tuple(PX.all_gather_flat(s, fast_axis) for s in shards)


def shard_global_norm(shards: Sequence[jax.Array],
                      fast_axis: Optional[str]) -> jax.Array:
    """Global gradient norm from reduce-scattered bucket shards.

    The shards are already summed over the slow axis (replicated there),
    so one psum over the fast axis completes the global sum of squares.
    Both bucketed train paths use this — the replicated-optimizer mode
    passes it into ``optim.apply`` so the two stay bitwise identical.
    """
    ss = jnp.zeros((), jnp.float32)
    for s in shards:
        ss = ss + jnp.sum(jnp.square(s.astype(jnp.float32)))
    if fast_axis is not None and PX.axis_size(fast_axis) > 1:
        ss = PX.psum(ss, fast_axis)
    return jnp.sqrt(ss)
