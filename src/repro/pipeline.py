"""GPipe-style pipeline parallelism over shard_map + collective_permute.

Demonstration-scale PP: layer stacks are sharded over a 'stage' mesh axis;
microbatches stream through stages with ppermute handoffs (1F1B-ish fill/
drain).  The production dry-run uses DP+FSDP+TP(+EP), which fits every
assigned arch; PP is provided as the scale-out escape hatch for deeper
models and validated by tests/test_pipeline.py on fake devices.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import parallel as PX


def gpipe_forward(layer_fn: Callable, stage_params, x_micro, *,
                  mesh: Mesh, stage_axis: str = "stage"):
    """Run microbatches through pipeline stages.

    layer_fn(params_slice, x) -> x : one stage's computation.
    stage_params: pytree with leading dim = n_stages (sharded over stage).
    x_micro: (n_micro, mb, ...) microbatched input (replicated).
    Returns (n_micro, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    perm_fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def body(params_stage, x_micro):
        sid = PX.axis_index(stage_axis)
        mb_shape = x_micro.shape[1:]
        buf = jnp.zeros(mb_shape, x_micro.dtype)       # stage input reg
        outs = jnp.zeros_like(x_micro)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            take = jnp.clip(t, 0, n_micro - 1)
            fresh = jax.lax.dynamic_index_in_dim(
                x_micro, take, axis=0, keepdims=False)
            inp = jnp.where(sid == 0,
                            jnp.where(t < n_micro, fresh, buf * 0), buf)
            y = layer_fn(params_stage, inp)
            # last stage commits its output for microbatch t-(S-1)
            mb_idx = t - (n_stages - 1)
            commit = jnp.logical_and(sid == n_stages - 1, mb_idx >= 0)
            idx = jnp.clip(mb_idx, 0, n_micro - 1)
            outs = jnp.where(
                commit,
                jax.lax.dynamic_update_index_in_dim(
                    outs, y.astype(outs.dtype), idx, axis=0),
                outs)
            # hand off activations to the next stage
            buf_next = PX.ppermute(y, stage_axis, perm_fwd)
            return (buf_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them
        outs = PX.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    return PX.shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)


def make_gpipe_loss(layer_fn, loss_fn, *, mesh: Mesh,
                    stage_axis: str = "stage"):
    """Differentiable pipeline loss: grads flow back through ppermute."""

    def fn(stage_params, x_micro, targets_micro):
        y = gpipe_forward(layer_fn, stage_params, x_micro,
                          mesh=mesh, stage_axis=stage_axis)
        return loss_fn(y, targets_micro)

    return fn
