"""End-to-end elastic preemption/repack driver.

Closes the loop between the repo's two halves: the cluster simulator
charges reconfiguration events a cost, and this driver *executes* those
events for real on the SPMD training runtime.  A reconfiguration
schedule (typically derived from a simulated trace's reconfig events via
:func:`schedule_from_sim`) names, per event, the training step at which
the job is repacked and the new (pod, data) mesh factorization.  For
each event the driver runs the full cycle the paper's
software-coordinated handoff describes:

1. committed sharded save on the old (pod, data) mesh
   (:func:`repro.ckpt.save_sharded` — per-rank shards + manifest,
   atomic temp-dir-rename commit);
2. :func:`repro.elastic.plan_elastic_remesh` with the checkpoint base
   dir — the handoff refuses to proceed without a committed checkpoint
   and names the step dir the re-meshed job restores from;
3. reshard-restore onto the new factorization
   (:func:`repro.ckpt.restore_sharded` — pure offset arithmetic, no
   rank gathers a full bucket) + jit re-compile of the train step;
4. continue training.

With ``deterministic_reduce`` (always on here: the driver trains
``hier_bucketed_zero1`` with the mesh-factorization-invariant reduce)
the continued run is *bitwise identical* to an uninterrupted run — the
PR-4 invariant, asserted at every handoff (``verify=True`` additionally
checks the restored state equals the saved state bit-for-bit).

Every phase's wallclock is measured (:class:`HandoffMeasurement`), so
:meth:`repro.core.jct_model.ReconfigCostModel.from_measurements` can
calibrate the simulator's handoff cost from *measured*, not assumed,
reconfiguration time (``benchmarks/elastic_bench.py``).

``mode='drain'`` executes the incumbent cycle instead — a gathered
legacy checkpoint save and a full (non-resharding) restore — so the
bench can price both operational models from measurements.
"""
from __future__ import annotations

import dataclasses
import os
import statistics
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as legacy_ckpt
from repro import ckpt as ckpt_lib
from repro import optim
from repro.core.leaves import TpuLeaf
from repro.data import DataConfig, SyntheticCorpus
from repro.elastic import plan_elastic_remesh
from repro.faults.plan import maybe_fire
from repro.faults.recovery import RecoveryReport, walk_committed
from repro.faults.retry import NO_RETRY, RetryPolicy
from repro.sharding import make_rules
from repro.train import (EFState, init_sharded_zero1, init_slow_residuals,
                         make_bucket_layout, make_jitted_train_step)


def factorizations(n_devices: int) -> List[Tuple[int, int]]:
    """All (pod, data) factorizations of ``n_devices``, pod ascending."""
    if n_devices < 1:
        raise ValueError("need at least one device")
    return [(p, n_devices // p) for p in range(1, n_devices + 1)
            if n_devices % p == 0]


@dataclasses.dataclass(frozen=True)
class ReconfigEvent:
    """One repack: before executing training step ``step``, hand the job
    off to the ``mesh_shape`` (pod, data) factorization."""
    step: int
    mesh_shape: Tuple[int, int]
    sim_time: float = 0.0         # when the source sim event fired
    kind: str = "handoff"

    def __post_init__(self):
        if self.step < 1:
            raise ValueError(
                f"reconfig step must be >= 1 (there is nothing to hand "
                f"off before the first step), got {self.step}")
        if len(self.mesh_shape) != 2 or min(self.mesh_shape) < 1:
            raise ValueError(f"bad mesh shape {self.mesh_shape!r}")


def schedule_from_sim(result, *, n_devices: int, n_steps: int,
                      initial_shape: Optional[Tuple[int, int]] = None,
                      max_events: Optional[int] = None
                      ) -> List[ReconfigEvent]:
    """Map a :class:`~repro.core.simulator.SimResult`'s job-suspending
    reconfiguration events onto a training run's steps.

    Event times are scaled from the simulated span onto ``[1,
    n_steps - 1]`` (order-preserving, deduplicated); target
    factorizations cycle through ``factorizations(n_devices)``, always
    differing from the mesh they leave.  Deterministic: the same sim
    result yields the same schedule.
    """
    recs = sorted((r for r in result.reconfig_events if r.n_affected > 0),
                  key=lambda r: r.t)
    if max_events is not None:
        recs = recs[:max_events]
    if not recs or n_steps < 2:
        return []
    t_end = max(result.makespan, recs[-1].t, 1e-9)
    facs = factorizations(n_devices)
    prev = tuple(initial_shape) if initial_shape is not None else facs[0]
    out: List[ReconfigEvent] = []
    used = set()
    fi = 0
    for r in recs:
        step = 1 + int(round(r.t / t_end * (n_steps - 2)))
        step = min(max(step, 1), n_steps - 1)
        while step in used and step < n_steps - 1:
            step += 1
        if step in used:
            continue                      # schedule is full
        cand = prev
        for _ in range(len(facs)):
            cand = facs[fi % len(facs)]
            fi += 1
            if cand != prev:
                break
        if cand == prev:
            continue                      # single-factorization device count
        out.append(ReconfigEvent(step=step, mesh_shape=cand,
                                 sim_time=r.t, kind=r.kind))
        used.add(step)
        prev = cand
    return out


@dataclasses.dataclass
class HandoffMeasurement:
    """Measured wallclock of one executed reconfiguration cycle."""
    step: int
    from_shape: Tuple[int, int]
    to_shape: Tuple[int, int]
    mode: str                     # "handoff" | "drain"
    save_s: float
    restore_s: float
    first_step_s: float           # first step on the new mesh (incl. jit)
    setup_s: float = 0.0          # new-mesh state build (init + zero1 jit)
    compile_s: float = 0.0        # first_step_s minus steady step time
    # total bytes the measuring process wrote/read: on the single-host
    # fake-device mesh one process moves EVERY rank's shards, so
    # bytes/seconds is the storage throughput a real per-rank writer
    # would see (ReconfigCostModel.from_measurements divides per-rank
    # shares by that throughput to project the concurrent handoff)
    save_bytes: int = 0
    restore_bytes: int = 0
    state_bytes: int = 0          # logical size of the saved state
    verified: bool = False        # restored state == saved state bitwise

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["from_shape"] = list(self.from_shape)
        d["to_shape"] = list(self.to_shape)
        return d


@dataclasses.dataclass
class ElasticRunResult:
    losses: List[float]
    measurements: List[HandoffMeasurement]
    mesh_shapes: List[Tuple[int, int]]    # factorization per step
    params: Any
    opt_state: Any
    steady_step_s: float
    start_step: int = 0                   # > 0 on a restart-resume
    recovery: Optional[RecoveryReport] = None
    # boundary timings for runs that are one *segment* of a longer job
    # (the cluster runtime splits a job into segment subprocesses and
    # stitches segment k's final save + segment k+1's resume restore
    # into one cross-process handoff measurement)
    state_bytes: int = 0                  # logical training-state size
    first_step_s: float = 0.0             # first executed step (incl jit)
    final_save_s: float = 0.0             # final_save wallclock
    final_save_bytes: int = 0
    resume_restore_s: float = 0.0         # resume: restore wallclock
    resume_restore_bytes: int = 0
    resume_setup_s: float = 0.0           # resume: new-mesh state build


@dataclasses.dataclass
class _MeshCtx:
    shape: Tuple[int, int]
    mesh: Any
    layout: Any
    params: Any
    state: Any
    opt_shardings: Any
    step_fn: Any


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _dirs, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


def _tree_bytes(tree) -> int:
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)
                   if hasattr(l, "dtype")))


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):        # a truncating zip would pass trivially
        return False
    return all(np.array_equal(np.asarray(jax.device_get(x)),
                              np.asarray(jax.device_get(y)))
               for x, y in zip(la, lb))


class ElasticDriver:
    """Executes a reconfiguration schedule on a real training run.

    The training configuration is pinned to the elastic-capable mode:
    ``hier_bucketed_zero1`` + ``deterministic_reduce`` (sharded f32
    state, factorization-invariant losses), optionally with the int8
    error-feedback slow hop.
    """

    def __init__(self, model, ocfg: optim.AdamWConfig,
                 data_cfg: DataConfig, *, base_dir: str,
                 bucket_bytes: int = 64 << 10, accum: int = 1,
                 mode: str = "handoff", error_feedback: bool = False,
                 verify: bool = True, retry: RetryPolicy = NO_RETRY,
                 fallback_on_corrupt: bool = False):
        if mode not in ("handoff", "drain"):
            raise ValueError(f"unknown driver mode {mode!r}")
        self.model = model
        self.ocfg = ocfg
        self.data_cfg = data_cfg
        self.base_dir = base_dir
        self.bucket_bytes = bucket_bytes
        self.accum = accum
        self.mode = mode
        self.ef = error_feedback
        self.verify = verify
        # recovery knobs: transient-I/O retry for every checkpoint
        # save/restore this driver performs, and whether a corrupt
        # committed step at resume quarantines + falls back to the
        # previous one instead of raising
        self.retry = retry
        self.fallback_on_corrupt = fallback_on_corrupt
        # set by _restore_into; on a resumed run the last successful
        # restore attempt's timings are the segment's receiving-half cost
        self._resume_timing: Optional[Dict[str, Any]] = None

    # ----------------------------------------------------------- setup
    def _setup(self, shape: Tuple[int, int], seed: int) -> _MeshCtx:
        mesh = jax.make_mesh(tuple(shape), ("pod", "data"))
        rules = make_rules(mesh, fsdp=False)
        params = self.model.init(jax.random.key(seed))
        layout = make_bucket_layout(params, mesh,
                                    bucket_bytes=self.bucket_bytes,
                                    deterministic=True)
        state, opt_sh = init_sharded_zero1(self.ocfg, params, layout,
                                           mesh)
        if self.ef:
            rshard = NamedSharding(mesh, P(("pod", "data")))
            res = tuple(jax.device_put(r, rshard)
                        for r in init_slow_residuals(
                            params, mesh, bucket_bytes=self.bucket_bytes,
                            deterministic=True))
            state = EFState(state, res)
            opt_sh = EFState(opt_sh, (rshard,) * layout.n_buckets)
        step_fn = make_jitted_train_step(
            self.model, self.ocfg, accum=self.accum, rules=rules,
            cross_pod_mode="hier_bucketed_zero1",
            bucket_bytes=self.bucket_bytes,
            slow_compress_bits=8 if self.ef else 0,
            slow_error_feedback=self.ef, deterministic_reduce=True)
        return _MeshCtx(tuple(shape), mesh, layout, params, state,
                        opt_sh, step_fn)

    @staticmethod
    def _leaves(shape: Tuple[int, int]) -> List[TpuLeaf]:
        return [TpuLeaf(pod=p, host=d, chip=0)
                for p in range(shape[0]) for d in range(shape[1])]

    # ------------------------------------------------------ save/restore
    def _save(self, ctx: _MeshCtx, step: int) -> None:
        """Commit ``ctx``'s state as checkpoint ``step`` (the state
        *before* executing training step ``step``)."""
        sdir = ckpt_lib.step_dir(self.base_dir, step)
        maybe_fire("driver.pre_save")
        if self.mode == "handoff":
            ckpt_lib.save_sharded(sdir, step, (ctx.params, ctx.state),
                                  layout=ctx.layout, mesh=ctx.mesh,
                                  blocking=True, retry=self.retry)
        else:
            legacy_ckpt.save(sdir, step, (ctx.params, ctx.state),
                             blocking=True)

    def _restore_into(self, path: str, step: int, shape: Tuple[int, int],
                      seed: int) -> _MeshCtx:
        """Build a fresh mesh context for ``shape`` and restore committed
        step ``step`` into it (format-dispatched, reshard-capable).

        Times both phases into ``_resume_timing`` — on a resumed run this
        restore is the *receiving* half of a cross-process handoff, and
        the cluster runtime calibrates from it."""
        t0 = time.perf_counter()
        ctx = self._setup(shape, seed)
        setup_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        rstep, (ctx.params, ctx.state) = ckpt_lib.restore_auto(
            path, (ctx.params, ctx.state),
            shardings=(None, ctx.opt_shardings),
            layout=ctx.layout if self.mode == "handoff" else None,
            retry=self.retry)
        self._resume_timing = {
            "setup_s": setup_s,
            "restore_s": time.perf_counter() - t0,
            "restore_bytes": _dir_bytes(path),
        }
        if rstep != step:
            raise ckpt_lib.CorruptCheckpointError(
                f"checkpoint at {path!r} records step {rstep}, directory "
                f"name says {step}")
        return ctx

    # --------------------------------------------------------- handoff
    def _handoff(self, ctx: _MeshCtx, event: ReconfigEvent, step: int,
                 seed: int) -> Tuple[_MeshCtx, HandoffMeasurement]:
        sdir = ckpt_lib.step_dir(self.base_dir, step)
        state_bytes = _tree_bytes((ctx.params, ctx.state))

        # the handoff below restores the *latest committed* step in
        # base_dir; a stale newer checkpoint (a previous run's leftovers)
        # would silently win over the save we are about to make
        stale = ckpt_lib.latest_step(self.base_dir)
        if stale is not None and stale > step:
            raise RuntimeError(
                f"checkpoint dir {self.base_dir!r} already holds a "
                f"committed step {stale} > current step {step}; the "
                f"handoff would restore that stale state — use a fresh "
                f"directory for this elastic run")

        t0 = time.perf_counter()
        self._save(ctx, step)
        save_s = time.perf_counter() - t0
        save_bytes = _dir_bytes(sdir)

        # the remesh plan validates the commit: it refuses a handoff
        # with no committed checkpoint, and names the step dir to
        # restore from
        plan = plan_elastic_remesh(self._leaves(ctx.shape), (),
                                   model_parallel=1,
                                   ckpt_base_dir=self.base_dir)
        if plan.handoff is None or plan.handoff.step != step:
            raise RuntimeError(
                f"remesh handoff names step "
                f"{getattr(plan.handoff, 'step', None)}, expected the "
                f"step {step} just committed")
        if plan.handoff.sharded != (self.mode == "handoff"):
            raise RuntimeError(
                f"checkpoint format mismatch: handoff.sharded="
                f"{plan.handoff.sharded} under driver mode {self.mode!r}")

        # building the new-mesh state (param init + jitted sharded-zero1
        # init) is real handoff work — time it so the calibrated
        # recompile cost does not undercount the cycle
        t0 = time.perf_counter()
        new = self._setup(event.mesh_shape, seed)
        setup_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        if self.mode == "handoff":
            rstep, (new.params, new.state) = ckpt_lib.restore_sharded(
                plan.handoff.step_dir, (new.params, new.state),
                shardings=(None, new.opt_shardings), layout=new.layout,
                retry=self.retry)
        else:
            rstep, (new.params, new.state) = legacy_ckpt.restore(
                plan.handoff.step_dir, (new.params, new.state),
                shardings=(None, new.opt_shardings))
        restore_s = time.perf_counter() - t0
        assert rstep == step, (rstep, step)
        maybe_fire("driver.post_restore")

        verified = False
        if self.verify:
            # the PR-4 bitwise handoff invariant, checked in place: the
            # resharded state is the saved state, bit for bit
            if not _trees_equal((ctx.params, ctx.state),
                                (new.params, new.state)):
                raise RuntimeError(
                    f"handoff not bitwise: {ctx.shape} -> "
                    f"{event.mesh_shape} at step {step}")
            verified = True

        return new, HandoffMeasurement(
            step=step, from_shape=ctx.shape, to_shape=new.shape,
            mode=self.mode, save_s=save_s, restore_s=restore_s,
            first_step_s=0.0, setup_s=setup_s, save_bytes=save_bytes,
            restore_bytes=save_bytes, state_bytes=state_bytes,
            verified=verified)

    # ----------------------------------------------------------- resume
    def _resume(self, shape_at, seed: int
                ) -> Tuple[Optional[_MeshCtx], int,
                           Optional[RecoveryReport]]:
        """Restore the newest usable committed step from ``base_dir``.

        Checkpoint step ``k`` holds the state *before* executing step
        ``k`` (both the handoff saves and the periodic saves follow this
        convention), so the resumed run continues at step ``k`` on
        ``shape_at(k)``.  With ``fallback_on_corrupt`` a corrupt newest
        step is quarantined on disk and the walk falls back through
        history; otherwise the first failure propagates.  No committed
        step at all means the crash predated the first commit — start
        from scratch (the caller's fresh-start path).
        """
        steps = ckpt_lib.committed_steps(self.base_dir)
        if not steps:
            return None, 0, None

        def attempt(step: int, path: str) -> _MeshCtx:
            return self._restore_into(path, step, shape_at(step), seed)

        if self.fallback_on_corrupt:
            ctx, report = walk_committed(self.base_dir, attempt,
                                         quarantine_on_disk=True)
            return ctx, report.restored_step, report
        step = steps[-1]
        ctx = attempt(step, ckpt_lib.step_dir(self.base_dir, step))
        report = RecoveryReport(self.base_dir, attempted=[step],
                                restored_step=step)
        return ctx, step, report

    # -------------------------------------------------------------- run
    def run(self, n_steps: int,
            schedule: Sequence[ReconfigEvent] = (), *,
            initial_shape: Tuple[int, int] = (2, 2),
            seed: int = 0, resume: bool = False, save_every: int = 0,
            final_save: bool = False) -> ElasticRunResult:
        """Train ``n_steps``, executing every scheduled reconfiguration.

        An empty ``schedule`` is the uninterrupted reference run — same
        code path, so bitwise comparisons between the two are symmetric.

        ``save_every=k`` commits a periodic checkpoint before every k-th
        step (skipped where a handoff already saves); ``final_save``
        commits the end-of-run state as step ``n_steps``.
        ``resume=True`` is the restart path: restore the newest usable
        committed step (see :meth:`_resume`), skip the schedule's
        already-executed events, and continue — with
        ``deterministic_reduce`` the continuation is bitwise identical
        to the uninterrupted run, which is what makes SIGKILL-anywhere
        recovery provable rather than hopeful.
        """
        events: Dict[int, ReconfigEvent] = {}
        for e in schedule:
            if e.step in events:
                raise ValueError(f"duplicate reconfig step {e.step}")
            if e.step >= n_steps:
                raise ValueError(
                    f"reconfig step {e.step} is past the run "
                    f"(n_steps={n_steps}); it would silently never fire")
            if (e.mesh_shape[0] * e.mesh_shape[1]
                    != initial_shape[0] * initial_shape[1]):
                # same rank count R is what makes the deterministic
                # reduce — and therefore the continuation — bitwise
                raise ValueError(
                    f"reconfig target {e.mesh_shape} is not a "
                    f"factorization of the run's "
                    f"{initial_shape[0] * initial_shape[1]} ranks")
            events[e.step] = e

        def shape_at(step: int) -> Tuple[int, int]:
            # factorization in force when executing `step`: the initial
            # shape folded over every event at or before it (an event at
            # step k repacks BEFORE executing k)
            shape = tuple(initial_shape)
            for s in sorted(events):
                if s <= step:
                    shape = tuple(events[s].mesh_shape)
            return shape

        start_step = 0
        recovery: Optional[RecoveryReport] = None
        ctx: Optional[_MeshCtx] = None
        if resume:
            ctx, start_step, recovery = self._resume(shape_at, seed)
            if start_step >= n_steps > 0 and ctx is not None:
                raise RuntimeError(
                    f"resume found committed step {start_step} at or "
                    f"past the end of the run (n_steps={n_steps}) — "
                    f"nothing left to execute")
            # events at or before the resumed step already ran (the
            # resumed checkpoint is their product)
            events = {s: e for s, e in events.items() if s > start_step}
        elif events:
            # fail before compiling anything: a previous run's committed
            # checkpoint past the first event would win the handoff's
            # latest_step lookup over the save this run makes
            stale = ckpt_lib.latest_step(self.base_dir)
            if stale is not None and stale > min(events):
                raise RuntimeError(
                    f"checkpoint dir {self.base_dir!r} already holds a "
                    f"committed step {stale} past the first reconfig "
                    f"event (step {min(events)}); the handoff would "
                    f"restore that stale state — use a fresh directory "
                    f"for this elastic run (or pass resume=True to "
                    f"continue it)")
        corpus = SyntheticCorpus(self.data_cfg)
        if ctx is None:
            ctx = self._setup(shape_at(start_step) if resume
                              else initial_shape, seed)
        losses: List[float] = []
        shapes: List[Tuple[int, int]] = []
        measurements: List[HandoffMeasurement] = []
        step_times: List[float] = []      # non-first steps per segment
        run_first_step_s = 0.0            # very first executed step
        first_step = True
        for step in range(start_step, n_steps):
            if step in events:
                ctx, m = self._handoff(ctx, events[step], step, seed)
                measurements.append(m)
                first_step = True
            elif (save_every and step > start_step
                    and step % save_every == 0):
                # periodic commit of the pre-step state; a handoff at
                # this step already saved it
                self._save(ctx, step)
            batch = {k: jnp.asarray(v)
                     for k, v in corpus.batch(step).items()}
            if first_step:
                maybe_fire("driver.first_step")
            t0 = time.perf_counter()
            with ctx.mesh:
                ctx.params, ctx.state, metrics = ctx.step_fn(
                    ctx.params, ctx.state, batch)
            dt = time.perf_counter() - t0
            if first_step:
                if measurements and measurements[-1].first_step_s == 0.0:
                    measurements[-1].first_step_s = dt
                if step == start_step:
                    run_first_step_s = dt
                first_step = False
            else:
                step_times.append(dt)
            losses.append(float(metrics["loss"]))
            shapes.append(ctx.shape)
        final_save_s = 0.0
        final_save_bytes = 0
        if final_save:
            t0 = time.perf_counter()
            self._save(ctx, n_steps)
            final_save_s = time.perf_counter() - t0
            final_save_bytes = _dir_bytes(
                ckpt_lib.step_dir(self.base_dir, n_steps))
        # recompile cost = first post-handoff step minus the steady step
        # time (the jit cache is cold on every new factorization)
        steady = statistics.median(step_times) if step_times else 0.0
        for m in measurements:
            m.compile_s = max(0.0, m.first_step_s - steady)
        rt = (self._resume_timing or {}) if resume else {}
        return ElasticRunResult(losses=losses, measurements=measurements,
                                mesh_shapes=shapes, params=ctx.params,
                                opt_state=ctx.state,
                                steady_step_s=steady,
                                start_step=start_step, recovery=recovery,
                                state_bytes=_tree_bytes(
                                    (ctx.params, ctx.state)),
                                first_step_s=run_first_step_s,
                                final_save_s=final_save_s,
                                final_save_bytes=final_save_bytes,
                                resume_restore_s=rt.get("restore_s", 0.0),
                                resume_restore_bytes=rt.get(
                                    "restore_bytes", 0),
                                resume_setup_s=rt.get("setup_s", 0.0))
