"""Unified SPMD runtime layer.

Every SPMD primitive the repro uses lives behind this package:

- :mod:`repro.parallel.compat` — version-portable ``shard_map`` (the only
  place allowed to touch the raw jax implementations);
- :mod:`repro.parallel.mesh` — mesh construction + axis bookkeeping;
- :mod:`repro.parallel.collectives` — named wrappers for the collectives
  (psum / ppermute / all_gather / ...);
- :mod:`repro.parallel.transport` — the canonical transport tiers (SHM /
  NET / ICI / DCN) shared by the analytic models and the runtime.

Model and runtime modules import from here; none of them may call the raw
jax shard_map entry points or re-declare bandwidth constants.
"""
from repro.parallel.collectives import (all_gather, all_gather_flat,
                                        axis_index, axis_size, pmax, pmean,
                                        ppermute, psum, psum_scatter,
                                        reduce_scatter_flat)
from repro.parallel.compat import (SHARD_MAP_IMPL, manual_axes, shard_map,
                                   static_axis_size)
from repro.parallel.mesh import (axes_size, axis_tuple, make_device_mesh,
                                 make_production_mesh)
from repro.parallel.transport import (AXIS_TIER, TIERS, TransportTier,
                                      fast_slow_axes, is_slow_axis,
                                      tier_for_axis)

__all__ = [
    "SHARD_MAP_IMPL", "shard_map", "manual_axes", "static_axis_size",
    "axes_size", "axis_tuple", "make_device_mesh", "make_production_mesh",
    "psum", "pmean", "pmax", "ppermute", "all_gather", "psum_scatter",
    "axis_index", "axis_size", "reduce_scatter_flat", "all_gather_flat",
    "TIERS", "AXIS_TIER", "TransportTier", "tier_for_axis", "is_slow_axis",
    "fast_slow_axes",
]
