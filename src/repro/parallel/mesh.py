"""Mesh construction and axis bookkeeping helpers.

Pure-jax layer under ``repro.sharding`` / ``repro.launch.mesh``: nothing
here imports model or scheduler code, so SPMD plumbing has no cyclic
dependencies and JAX-version quirks stay in one place.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh

Axes = Union[None, str, Tuple[str, ...]]


def axis_tuple(axes: Axes) -> Tuple[str, ...]:
    """Normalize a logical-rule value (None | str | tuple) to a tuple."""
    if axes is None:
        return ()
    return (axes,) if isinstance(axes, str) else tuple(axes)


def axes_size(mesh: Optional[Mesh], axes: Axes) -> int:
    """Product of mesh extents over ``axes`` (1 for None / no mesh)."""
    if mesh is None or axes is None:
        return 1
    n = 1
    for a in axis_tuple(axes):
        n *= mesh.shape[a]
    return n


def make_device_mesh(shape: Sequence[int],
                     axis_names: Sequence[str],
                     *, devices=None) -> Mesh:
    """``jax.make_mesh`` where available, manual reshape otherwise."""
    mk = getattr(jax, "make_mesh", None)
    if devices is None and mk is not None:
        return mk(tuple(shape), tuple(axis_names))
    devs = np.asarray(devices if devices is not None else jax.devices())
    return Mesh(devs.reshape(tuple(shape)), tuple(axis_names))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The repro's production topology: (16,16) or (2,16,16) with 'pod'
    outermost — the slow-transport axis per ``repro.parallel.transport``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_device_mesh(shape, axes)
