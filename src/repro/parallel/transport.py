"""Canonical transport tiers shared by the analytic models and the runtime.

Flex-MIG's runtime insight is that collectives should ride the fastest
transport that connects the participating leaves: host shared memory (SHM)
between MIG instances on one box, RDMA (NET) across boxes.  On TPU the
same two-tier cliff separates intra-pod ICI from cross-pod DCN.

This module is the single source of truth for those numbers and for the
axis -> tier naming convention, so the analytic bandwidth model
(``repro.collectives.transport``), the JCT model (``repro.core.jct_model``)
and the executable hierarchical collectives (``repro.collectives.
hierarchical``) all agree on what "fast" and "slow" mean.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

# --- GPU testbed (paper Fig. 10/11) -----------------------------------------
SHM_STREAM_GBPS = 12.0            # per-leaf-pair host-shm effective
PCIE_GBPS = 20.0                  # practical per-GPU PCIe gen4 x16 cap
NET_GBPS = 8.0                    # RDMA via host NIC: effective per-stream
SHM_LATENCY_S = 4e-6
NET_LATENCY_S = 12e-6

# --- TPU v5e-ish fabric (per chip) ------------------------------------------
ICI_GBPS_PER_LINK = 50.0
ICI_LINKS = 4
DCN_GBPS_PER_HOST = 6.25          # 50 Gb/s NIC per host


@dataclasses.dataclass(frozen=True)
class TransportTier:
    """One rung of the bandwidth hierarchy."""

    name: str                     # "SHM" | "NET" | "ICI" | "DCN"
    fabric: str                   # "gpu" | "tpu"
    gbps: float                   # effective per-stream bandwidth
    latency_s: float


TIERS: Dict[str, TransportTier] = {
    "SHM": TransportTier("SHM", "gpu", SHM_STREAM_GBPS, SHM_LATENCY_S),
    "NET": TransportTier("NET", "gpu", NET_GBPS, NET_LATENCY_S),
    "ICI": TransportTier("ICI", "tpu", ICI_GBPS_PER_LINK, SHM_LATENCY_S),
    "DCN": TransportTier("DCN", "tpu", DCN_GBPS_PER_HOST, NET_LATENCY_S),
}

# Mesh-axis naming convention used across the repro: collectives over
# 'pod' cross the slow boundary; everything else stays on the fast fabric.
AXIS_TIER: Dict[str, str] = {
    "pod": "DCN",
    "data": "ICI",
    "model": "ICI",
    "stage": "ICI",
}
_SLOW_TIERS = frozenset({"NET", "DCN"})


def tier_for_axis(axis: str) -> TransportTier:
    return TIERS[AXIS_TIER.get(axis, "ICI")]


def is_slow_axis(axis: str) -> bool:
    """True when collectives over ``axis`` cross the NET/DCN boundary."""
    return AXIS_TIER.get(axis, "ICI") in _SLOW_TIERS


def fast_slow_axes(axis_names: Tuple[str, ...]
                   ) -> Tuple[Tuple[str, ...], Optional[str]]:
    """Split mesh axes into (fast_axes, slow_axis) per the tier map.

    At most one slow axis is supported (the meshes here have a single
    'pod' dimension); returns slow_axis=None for single-tier meshes.
    """
    fast = tuple(a for a in axis_names if not is_slow_axis(a))
    slow = [a for a in axis_names if is_slow_axis(a)]
    if len(slow) > 1:
        raise ValueError(f"multiple slow axes {slow!r} unsupported")
    return fast, (slow[0] if slow else None)
