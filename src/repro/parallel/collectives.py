"""Thin, named wrappers over the collective primitives used in the repro.

Model/runtime code calls these instead of ``jax.lax.*`` directly so that

- every collective call site names the same vocabulary the analytic
  bandwidth model uses (``repro.parallel.transport`` classifies the axis),
- a JAX rename (as happened to ``shard_map`` / ``axis_size``) or a second
  backend means touching this module, not six call sites.

All of these are valid only inside a :func:`repro.parallel.shard_map`
body (they act on *manual* mesh axes).
"""
from __future__ import annotations

from typing import Sequence, Tuple, Union

import jax

from repro.parallel.compat import static_axis_size

AxisName = Union[str, Tuple[str, ...], Sequence[str]]

__all__ = ["psum", "pmean", "pmax", "ppermute", "all_gather",
           "psum_scatter", "axis_index", "axis_size",
           "reduce_scatter_flat", "all_gather_flat"]


def psum(x, axes: AxisName):
    """Sum-reduce over one or more manual mesh axes."""
    return jax.lax.psum(x, axes)


def pmean(x, axes: AxisName):
    """Mean-reduce over one or more manual mesh axes."""
    return jax.lax.pmean(x, axes)


def pmax(x, axes: AxisName):
    """Max-reduce over one or more manual mesh axes."""
    return jax.lax.pmax(x, axes)


def ppermute(x, axis: str, perm):
    """Point-to-point shift along ``axis``; ``perm`` is (src, dst) pairs.
    Missing destinations receive zeros (the GPipe bubble semantics)."""
    return jax.lax.ppermute(x, axis, perm)


def all_gather(x, axis: str, *, tiled: bool = False, gather_axis: int = 0):
    """Gather per-shard values along a new (or tiled) leading dimension."""
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


def psum_scatter(x, axis: str, *, scatter_dimension: int = 0,
                 tiled: bool = False):
    """Reduce-scatter: sum over ``axis``, each shard keeps its slice."""
    return jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_dimension,
                                tiled=tiled)


def reduce_scatter_flat(x, axis: str):
    """Reduce-scatter a flat buffer: sum over ``axis``, rank ``i`` keeps the
    ``i``-th contiguous 1/n slice.

    ``x`` must be 1-D with length divisible by the axis size (the bucket
    layouts guarantee this via their ``align``).  Inverse of
    :func:`all_gather_flat` up to the reduction.
    """
    n = static_axis_size(axis)
    shard = jax.lax.psum_scatter(x.reshape(n, -1), axis,
                                 scatter_dimension=0, tiled=False)
    return shard.reshape(-1)


def all_gather_flat(shard, axis: str):
    """Concatenate per-rank flat shards in rank order into one flat buffer
    (the inverse of :func:`reduce_scatter_flat`'s slicing)."""
    full = jax.lax.all_gather(shard, axis, axis=0, tiled=False)
    return full.reshape(-1)


def axis_index(axis: str):
    """This shard's coordinate along a manual mesh axis."""
    return jax.lax.axis_index(axis)


def axis_size(axis: str) -> int:
    """Static size of a manual mesh axis (version-portable)."""
    return static_axis_size(axis)
