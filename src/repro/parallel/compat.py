"""Version-portable SPMD primitives.

JAX has moved ``shard_map`` twice (``jax.experimental.shard_map.shard_map``
-> ``jax.shard_map``) and renamed two of its keywords along the way
(``check_rep``/``auto`` -> ``check_vma``/``axis_names``).  Everything in
this repro goes through :func:`shard_map` below, which presents the *new*
keyword surface on every JAX version:

    shard_map(f, mesh=mesh, in_specs=..., out_specs=...,
              check_vma=False, axis_names={"pod"})

Resolution order (recorded in :data:`SHARD_MAP_IMPL` for tests/debugging):

1. ``jax.shard_map``                       (JAX >= 0.6 style)
2. ``jax.experimental.shard_map.shard_map``(JAX 0.4.x / 0.5.x); keywords
   are translated: ``check_vma`` -> ``check_rep`` and ``axis_names`` ->
   ``auto`` (the complement over the mesh axes).
3. A documented fallback that raises ``NotImplementedError`` at *call*
   time with upgrade guidance, so importing this module never fails even
   on a JAX with no shard_map at all (analysis-only workflows still work).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Set

import jax

__all__ = ["shard_map", "SHARD_MAP_IMPL", "static_axis_size", "manual_axes"]


_new_impl = getattr(jax, "shard_map", None)
_old_impl = None
if _new_impl is None:
    try:
        from jax.experimental.shard_map import shard_map as _old_impl
    except ImportError:                     # pragma: no cover - ancient jax
        _old_impl = None

if _new_impl is not None:
    SHARD_MAP_IMPL = "jax.shard_map"
elif _old_impl is not None:
    SHARD_MAP_IMPL = "jax.experimental.shard_map.shard_map"
else:                                       # pragma: no cover - ancient jax
    SHARD_MAP_IMPL = "unavailable"


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True,
              axis_names: Optional[Set[str]] = None) -> Callable:
    """Map ``f`` over shards of a mesh; new-style keyword surface.

    ``axis_names``: mesh axes mapped *manually* inside ``f`` (the rest
    stay automatic / visible to the partitioner).  ``None`` means all
    mesh axes are manual, matching both upstream defaults.
    """
    if _new_impl is not None:
        kw: dict = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                        check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return _new_impl(f, **kw)
    if _old_impl is not None:
        auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
                if axis_names is not None else frozenset())
        return _old_impl(f, mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=check_vma, auto=auto)

    def _unavailable(*a: Any, **k: Any):    # pragma: no cover - ancient jax
        raise NotImplementedError(
            "No shard_map implementation in this JAX "
            f"({jax.__version__}); need jax>=0.4.26 for "
            "jax.experimental.shard_map. Analytic/simulator paths work "
            "without it; executable SPMD paths do not.")
    return _unavailable


def static_axis_size(axis) -> int:
    """Size of a named mesh axis inside a shard_map body, as a static int.

    ``jax.lax.axis_size`` only exists on newer JAX; on older versions
    ``lax.psum(1, axis)`` is the canonical idiom (constant-folded to a
    Python int, usable in reshapes).
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis)


def manual_axes() -> frozenset:
    """Mesh axes that are Manual at the current trace point (i.e. we are
    inside a shard_map mapping them) — sharding constraints must not
    mention them.  Returns an empty set on JAX versions without the
    abstract-mesh introspection API (harmless: those versions reject the
    constraint later only if a caller actually violates the rule, and all
    in-repo callers drop manual axes explicitly via
    ``sharding.without_axes``)."""
    try:
        get_am = getattr(jax.sharding, "get_abstract_mesh", None)
        if get_am is None:
            return frozenset()
        am = get_am()
        if am is None or am.empty:
            return frozenset()
        from jax.sharding import AxisType
        return frozenset(n for n in am.axis_names
                         if am._name_to_type[n] == AxisType.Manual)
    except Exception:                       # pragma: no cover - API drift
        return frozenset()
