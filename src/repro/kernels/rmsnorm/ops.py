"""jit'd public wrapper for fused RMSNorm."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = True):
    """x: (..., D); w: (D,)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = rmsnorm_pallas(x2, w, eps=eps, block_rows=block_rows,
                         interpret=interpret)
    return out.reshape(shape)
