"""Fused RMSNorm Pallas kernel (row-blocked, f32 accumulation in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (br, D)
    var = jnp.mean(jnp.square(x), axis=1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, w, *, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = True):
    """x: (R, D); w: (D,)."""
    R, D = x.shape
    br = min(block_rows, R)
    while R % br:
        br -= 1
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ) if not interpret else None,
    )(x, w)
