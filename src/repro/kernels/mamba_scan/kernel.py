"""Mamba2 / SSD chunked-scan Pallas kernel.

Grid: (B, H, num_chunks).  The chunk axis is sequential ("arbitrary") and
carries the (P, N) SSM state in VMEM scratch — the TPU-native layout of the
paper's chunked algorithm: intra-chunk work is a pair of MXU matmuls
((Q,N)x(N,Q) and (Q,Q)x(Q,P)), the inter-chunk recurrence is a rank-N state
update.  B/C tensors are grouped (G groups); the head->group mapping lives in
the BlockSpec index maps so grouped heads re-read the same HBM block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
                state_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    A = a_ref[0].astype(jnp.float32)                  # scalar
    Bm = b_ref[0, :, 0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)           # (Q, N)

    la = dt * A                                       # (Q,) log-decay
    b_end = jnp.cumsum(la)                            # inclusive cumsum
    xd = x * dt[:, None]

    # intra-chunk decay matrix L[t,s] = exp(b_t - b_s) for t >= s
    bt = b_end[:, None]
    bs = b_end[None, :]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(ti >= si, jnp.exp(bt - bs), 0.0)

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    y_diag = jax.lax.dot_general(CB * Lmat, xd, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: y_off[t] = exp(b_t) * C_t . state_prev^T
    state_prev = state_ref[...]                       # (P, N)
    y_off = jax.lax.dot_general(Cm, state_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(b_end)[:, None]

    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S' = exp(total) S + sum_s exp(total - b_s) x_s B_s^T
    total = b_end[-1]
    decay = jnp.exp(total - b_end)                    # (Q,)
    chunk_state = jax.lax.dot_general(
        xd * decay[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (P, N)
    state_ref[...] = state_prev * jnp.exp(total) + chunk_state

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_ref[0, 0] = state_ref[...]


def ssd_pallas(x, dt, A, B, C, *, chunk: int, interpret: bool = True):
    """x: (Bt,S,H,P); dt: (Bt,S,H); A: (H,); B/C: (Bt,S,G,N).

    Returns (y (Bt,S,H,P), final_state (Bt,H,P,N) f32).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    assert S % chunk == 0
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(x, dt, A, B, C)
    return y, st
