from repro.kernels.mamba_scan.ops import ssd  # noqa: F401
