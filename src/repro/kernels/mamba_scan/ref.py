"""Pure-jnp oracle: token-by-token SSD recurrence."""
from __future__ import annotations

from repro.models.ssm import ssd_sequential_ref


def ssd_ref(x, dt, A, B, C):
    """x: (Bt,S,H,P); dt: (Bt,S,H); A: (H,); B/C: (Bt,S,G,N)."""
    return ssd_sequential_ref(x, dt, A, B, C)
