"""jit'd public wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = True):
    """Chunked SSD scan.  See kernel.py for shapes."""
    return ssd_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)
