"""Chunked mLSTM (xLSTM matrix-memory cell) Pallas kernel.

Grid: (B, H, num_chunks), chunk axis sequential carrying (C, n, m) in VMEM
scratch.  Math identical to ``repro.models.xlstm.mlstm_chunked`` (see the
stabilized derivation there): per chunk one (Q,Q) score matmul + one (Q,Q)x
(Q,Dv) value matmul + rank-Q state update — same MXU shape regime as flash
attention, with exponential gate stabilization handled in f32 scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, h_ref,
                  co_ref, no_ref, mo_ref, c_ref, n_ref, m_ref, *,
                  chunk: int, head_dim: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)

    D = head_dim
    scale = 1.0 / math.sqrt(D)
    q = q_ref[0, :, 0].astype(jnp.float32) * scale    # (Q, D)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    ig = i_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    fg = f_ref[0, :, 0].astype(jnp.float32)

    lf = jax.nn.log_sigmoid(fg)
    b = jnp.cumsum(lf)                                 # (Q,)
    a = ig - b
    m0 = m_ref[0, 0]
    rm = jnp.maximum(jax.lax.cummax(a, axis=0), m0)    # (Q,)
    m_t = b + rm

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(ti >= si, jnp.exp(a[None, :] - rm[:, None]), 0.0)
    scores = qk * w

    C0 = c_ref[...]                                    # (Dk, Dv)
    n0 = n_ref[...]                                    # (1, Dk)
    inter_scale = jnp.exp(m0 - rm)                     # (Q,)
    inter = jax.lax.dot_general(q, C0, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    num = (jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + inter * inter_scale[:, None])
    den = (jnp.sum(scores, axis=1)
           + jnp.sum(q * n0, axis=1) * inter_scale)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[:, None]
    h_ref[0, :, 0] = h.astype(h_ref.dtype)

    R = rm[-1]
    decay_in = jnp.exp(a - R)                          # (Q,)
    c_ref[...] = (C0 * jnp.exp(m0 - R)
                  + jax.lax.dot_general(k * decay_in[:, None], v,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    n_ref[...] = (n0 * jnp.exp(m0 - R)
                  + jnp.sum(k * decay_in[:, None], axis=0, keepdims=True))
    m_ref[0, 0] = b[-1] + R

    @pl.when(ci == nc - 1)
    def _emit():
        co_ref[0, 0] = c_ref[...]
        no_ref[0, 0] = n_ref[0]
        mo_ref[0, 0] = m_ref[0, 0]


def mlstm_pallas(q, k, v, i_raw, f_raw, *, chunk: int,
                 interpret: bool = True):
    """q,k,v: (B,S,H,D); i_raw,f_raw: (B,S,H).

    Returns (h (B,S,H,D), (C (B,H,D,D), n (B,H,D), m (B,H)) f32).
    """
    B, S, H, D = q.shape
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_mlstm_kernel, chunk=chunk, head_dim=D)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, hh, c: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, D), lambda b, hh, c: (b, hh, 0)),
            pl.BlockSpec((1, 1), lambda b, hh, c: (b, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(q, k, v, i_raw, f_raw)
    return h, (C, n, m)
