"""jit'd public wrapper for the chunked mLSTM kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm.kernel import mlstm_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm(q, k, v, i_raw, f_raw, *, chunk: int = 256,
          interpret: bool = True):
    return mlstm_pallas(q, k, v, i_raw, f_raw, chunk=chunk,
                        interpret=interpret)
