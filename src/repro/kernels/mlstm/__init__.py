from repro.kernels.mlstm.ops import mlstm  # noqa: F401
