"""Pure-jnp oracle: token-by-token stabilized mLSTM recurrence."""
from __future__ import annotations

from repro.models.xlstm import mlstm_sequential_ref


def mlstm_ref(q, k, v, i_raw, f_raw):
    """q,k,v: (B,S,H,D); gates: (B,S,H) -> (h, (C, n, m))."""
    return mlstm_sequential_ref(q, k, v, i_raw, f_raw)
