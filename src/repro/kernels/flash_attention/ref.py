"""Pure-jnp oracle for flash attention (unblocked softmax attention)."""
from __future__ import annotations

from repro.models.layers import full_attention


def attention_ref(q, k, v, *, causal: bool = True, softcap: float = 0.0):
    """q: (B,S,H,D); k/v: (B,S,Kv,Dv) -> (B,S,H,Dv)."""
    return full_attention(q, k, v, causal=causal, softcap=softcap)
