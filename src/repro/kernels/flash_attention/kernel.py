"""Blocked causal GQA flash attention — Pallas TPU kernel.

Grid: (B*H, num_q_blocks, num_kv_blocks); the kv axis is the innermost,
sequential ("arbitrary") dimension carrying the online-softmax state in VMEM
scratch.  GQA is handled in the BlockSpec index maps (kv blocks are fetched
per kv-head; query heads of the same group re-read them from HBM — no
repeated-KV materialization).  Causal skipping: fully-masked kv blocks are
skipped with ``pl.when`` (no MXU work issued).

Block shapes are MXU-aligned by ``ops.flash_attention`` (multiples of 128 on
the sequence axes whenever the sequence allows it).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, scale: float, softcap: float,
                 block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: kv block strictly above the diagonal band
    q_end = (qi + 1) * block_q - 1
    k_start = ki * block_k
    live = (not causal) or (k_start <= q_end)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # (bq, D)
        k = k_ref[0].astype(jnp.float32)               # (bk, D)
        v = v_ref[0].astype(jnp.float32)               # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, group: int,
                         block_q: int, block_k: int, softcap: float = 0.0,
                         interpret: bool = True):
    """q: (BH, S, D); k/v: (BKv, S, D|Dv); group = H // Kv."""
    BH, S, D = q.shape
    Dv = v.shape[-1]
    nq = S // block_q
    nk = S // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel, causal=causal, scale=scale, softcap=softcap,
        block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, qi, ki: (b // group, ki, 0)),
            pl.BlockSpec((1, block_k, Dv),
                         lambda b, qi, ki: (b // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv),
                               lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(q, k, v)
