"""jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _pick_block(s: int, target: int) -> int:
    if s % target == 0:
        return target
    b = math.gcd(s, target)
    while s % b:
        b -= 1
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "softcap", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, softcap: float = 0.0,
                    interpret: bool = True):
    """q: (B, S, H, D); k/v: (B, S, Kv, Dv).  Returns (B, S, H, Dv)."""
    B, S, H, D = q.shape
    Kv = k.shape[2]
    Dv = v.shape[-1]
    G = H // Kv
    bq = _pick_block(S, block_q)
    bk = _pick_block(S, block_k)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, Dv)
    o = flash_attention_bhsd(qf, kf, vf, causal=causal, group=G,
                             block_q=bq, block_k=bk, softcap=softcap,
                             interpret=interpret)
    return o.reshape(B, H, S, Dv).transpose(0, 2, 1, 3)
