"""Sharded checkpoint save/restore with resharding and async writes.

Used three ways:
- fault tolerance for the training loop (periodic save, restart-resume);
- the drain-required suspend/resume cycle the simulator charges (C4);
- elastic re-meshing (restore onto a different mesh/shardings).

Format: one ``.npy`` per pytree leaf (path-encoded filename) + a JSON
manifest with the treedef, dtypes/shapes, step and CRC32 checksums.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
import zlib
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

from repro.faults.plan import maybe_fire


def _leaf_paths(tree) -> Dict[str, Any]:
    # the leaf-path grammar is the cross-format contract (restore_auto
    # hands the same template to either format), so there is exactly one
    # implementation: repro.ckpt.treepaths.  Imported at call time —
    # repro.ckpt's package init imports this module, so a module-level
    # import here would cycle.
    from repro.ckpt.treepaths import leaf_paths
    return leaf_paths(tree)


def _sanitize(path: str) -> str:
    from repro.ckpt.treepaths import sanitize
    return sanitize(path)


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True
         ) -> Optional[threading.Thread]:
    """Save a pytree.  blocking=False returns the writer thread (async)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _leaf_paths(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
            if v is not None}

    def write():
        manifest = {"step": step, "leaves": {}}
        for k, arr in host.items():
            fname = _sanitize(k) + ".npy"
            maybe_fire("legacy.write")
            np.save(os.path.join(ckpt_dir, fname), arr)
            manifest["leaves"][k] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()) & 0xffffffff,
            }
        tmp = os.path.join(ckpt_dir, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        maybe_fire("legacy.manifest", path=tmp)
        os.replace(tmp, os.path.join(ckpt_dir, "manifest.json"))

    if blocking:
        write()
        return None
    t = _WriterThread(write)
    t.start()
    return t


class _WriterThread(threading.Thread):
    """Async-save writer whose ``join`` re-raises write failures.

    A daemon thread that swallowed ENOSPC/EPERM would make a failed
    checkpoint indistinguishable from a committed one — the trainer
    would run for hours believing it is protected.  ``Trainer`` joins
    the pending writer before each new save (and in its ``finally``), so
    failures surface at the next checkpoint boundary at the latest.
    Shared by both checkpoint formats (this module is upstream of
    ``repro.ckpt``, which imports it here).
    """

    def __init__(self, fn):
        super().__init__(daemon=True)
        self._fn = fn
        self.exc: Optional[BaseException] = None

    def run(self):
        try:
            self._fn()
        except BaseException as e:      # noqa: BLE001 — re-raised in join
            self.exc = e

    def join(self, timeout=None):
        super().join(timeout)
        if self.exc is not None:
            raise self.exc


class CorruptCheckpointError(RuntimeError):
    pass


def restore(ckpt_dir: str, template, *, shardings=None,
            verify: bool = True):
    """Restore into ``template``'s structure.

    ``shardings``: optional same-structure tree of NamedShardings — arrays
    are device_put with them (resharding onto a new mesh is just restoring
    with different shardings: elastic scaling path).
    Returns (step, tree).
    """
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _leaf_paths(template)
    flat_s = _leaf_paths(shardings) if shardings is not None else {}
    out = {}
    for k, leaf in flat_t.items():
        if leaf is None:
            out[k] = None
            continue
        meta = manifest["leaves"].get(k)
        if meta is None:
            raise CorruptCheckpointError(f"missing leaf {k}")
        leaf_path = os.path.join(ckpt_dir, meta["file"])
        maybe_fire("legacy.read", path=leaf_path)
        arr = np.load(leaf_path)
        want = np.dtype(meta["dtype"])
        if arr.dtype != want:     # np.save round-trips bf16 as void16
            arr = arr.view(want)
        if (hasattr(leaf, "shape")
                and tuple(arr.shape) != tuple(leaf.shape)):
            # fail here with a clear error instead of deep inside the
            # jitted step; the gathered format cannot reshard — that is
            # what repro.ckpt's shard+manifest format is for
            raise CorruptCheckpointError(
                f"shape mismatch for {k}: saved {tuple(arr.shape)} vs "
                f"template {tuple(leaf.shape)} — the legacy gathered "
                f"format cannot reshard onto a different layout (save "
                f"with repro.ckpt.save_sharded for that)")
        if hasattr(leaf, "dtype") and arr.dtype != np.dtype(
                str(leaf.dtype)):
            raise CorruptCheckpointError(
                f"dtype mismatch for {k}: saved {arr.dtype} vs "
                f"template {leaf.dtype}")
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xffffffff
            if crc != meta["crc32"]:
                raise CorruptCheckpointError(f"checksum mismatch for {k}")
        sh = flat_s.get(k)
        out[k] = (jax.device_put(arr, sh) if sh is not None
                  else jax.numpy.asarray(arr))

    from repro.ckpt.treepaths import rebuild
    return manifest["step"], rebuild(template, out)


# committed step dirs match exactly; anything else — in-flight temp dirs
# from the atomic rename protocol ("step_00000010.tmp-1234"), editor
# droppings, torn copies — is skipped instead of crashing int()
_STEP_DIR_RE = re.compile(r"^step_(\d+)$")


def committed_steps(base_dir: str) -> List[int]:
    """All *committed* steps in ``base_dir``, sorted ascending.

    A step dir counts only if its name matches ``step_<digits>`` exactly
    AND it contains a manifest that parses as JSON whose ``step`` equals
    the directory's digits — the commit marker both checkpoint formats
    write last, verified rather than merely present.  Partially-written
    dirs (crash mid-save, torn temp dirs awaiting their atomic rename,
    a manifest whose write was itself torn) are ignored, never raised
    on: a restart after a mid-checkpoint crash must resume from the
    previous good step, not die enumerating the wreckage.

    This is the history the CRC-quarantine fallback walks newest-first
    (``repro.faults.recovery``) and the driver restart path replays
    against its event schedule.
    """
    if not os.path.isdir(base_dir):
        return []
    steps = []
    for d in os.listdir(base_dir):
        m = _STEP_DIR_RE.match(d)
        if not m:
            continue
        man_path = os.path.join(base_dir, d, "manifest.json")
        try:
            with open(man_path) as f:
                man = json.load(f)
        except (OSError, ValueError):
            continue
        if isinstance(man, dict) and man.get("step") == int(m.group(1)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(base_dir: str) -> Optional[int]:
    """Largest committed step in ``base_dir`` (see ``committed_steps``)."""
    steps = committed_steps(base_dir)
    return steps[-1] if steps else None


def step_dir(base_dir: str, step: int) -> str:
    return os.path.join(base_dir, f"step_{step:08d}")
