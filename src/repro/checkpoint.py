"""Sharded checkpoint save/restore with resharding and async writes.

Used three ways:
- fault tolerance for the training loop (periodic save, restart-resume);
- the drain-required suspend/resume cycle the simulator charges (C4);
- elastic re-meshing (restore onto a different mesh/shardings).

Format: one ``.npy`` per pytree leaf (path-encoded filename) + a JSON
manifest with the treedef, dtypes/shapes, step and CRC32 checksums.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}.{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}[{i}]", v)
        else:
            flat[prefix] = node
    walk("", tree)
    return flat


def _sanitize(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.\[\]-]", "_", path)


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True
         ) -> Optional[threading.Thread]:
    """Save a pytree.  blocking=False returns the writer thread (async)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _leaf_paths(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
            if v is not None}

    def write():
        manifest = {"step": step, "leaves": {}}
        for k, arr in host.items():
            fname = _sanitize(k) + ".npy"
            np.save(os.path.join(ckpt_dir, fname), arr)
            manifest["leaves"][k] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()) & 0xffffffff,
            }
        tmp = os.path.join(ckpt_dir, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, os.path.join(ckpt_dir, "manifest.json"))

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


class CorruptCheckpointError(RuntimeError):
    pass


def restore(ckpt_dir: str, template, *, shardings=None,
            verify: bool = True):
    """Restore into ``template``'s structure.

    ``shardings``: optional same-structure tree of NamedShardings — arrays
    are device_put with them (resharding onto a new mesh is just restoring
    with different shardings: elastic scaling path).
    Returns (step, tree).
    """
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _leaf_paths(template)
    flat_s = _leaf_paths(shardings) if shardings is not None else {}
    out = {}
    for k, leaf in flat_t.items():
        if leaf is None:
            out[k] = None
            continue
        meta = manifest["leaves"].get(k)
        if meta is None:
            raise CorruptCheckpointError(f"missing leaf {k}")
        arr = np.load(os.path.join(ckpt_dir, meta["file"]))
        want = np.dtype(meta["dtype"])
        if arr.dtype != want:     # np.save round-trips bf16 as void16
            arr = arr.view(want)
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xffffffff
            if crc != meta["crc32"]:
                raise CorruptCheckpointError(f"checksum mismatch for {k}")
        sh = flat_s.get(k)
        out[k] = (jax.device_put(arr, sh) if sh is not None
                  else jax.numpy.asarray(arr))

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}.{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rebuild(f"{prefix}[{i}]", v)
                    for i, v in enumerate(node)]
            return type(node)(vals) if not hasattr(node, "_fields") \
                else type(node)(*vals)
        return out[prefix]

    return manifest["step"], rebuild("", template)


def latest_step(base_dir: str) -> Optional[int]:
    if not os.path.isdir(base_dir):
        return None
    steps = []
    for d in os.listdir(base_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(base_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def step_dir(base_dir: str, step: int) -> str:
    return os.path.join(base_dir, f"step_{step:08d}")
