"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS`` before first jax init and everything else must see the real
device count.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.core.aggregation import leaves_to_mesh
from repro.core.leaves import TpuLeaf, TpuSliceTopology
from repro.parallel.mesh import make_production_mesh  # noqa: F401 (re-export)
from repro.sharding import MeshRules, make_rules


def production_rules(mesh: Mesh, *, long_ctx: bool = False,
                     seq_shard: bool = False) -> MeshRules:
    return make_rules(mesh, long_ctx=long_ctx, seq_shard=seq_shard)


def make_leaf_mesh(n_leaves: int, *, model_parallel: int,
                   topology: Optional[TpuSliceTopology] = None,
                   order: str = "grouped") -> Mesh:
    """Flex-MIG style job mesh: ``n_leaves`` chips aggregated one-to-many.

    The leaf pool comes from the TPU-slice topology; device order follows
    the topology-aware placement policy (core/aggregation.py).
    """
    topo = topology or TpuSliceTopology()
    leaves = topo.leaves()[:n_leaves]
    assert n_leaves % model_parallel == 0
    shape = (n_leaves // model_parallel, model_parallel)
    return leaves_to_mesh(leaves, shape, ("data", "model"), order=order)
