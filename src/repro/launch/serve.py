"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the batched continuous-batching server on synthetic requests.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.models.registry import ARCH_IDS, build_model, get_config, \
    reduced_config
from repro.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced_config(cfg)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    server = BatchedServer(model, params, max_batch=args.max_batch,
                           max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=int(rng.integers(2, 10))
                              ).astype(np.int32)
        server.submit(Request(rid, prompt, max_new=args.max_new))
    server.run_until_drained()
    for req in sorted(server.completed, key=lambda r: r.rid):
        print(f"request {req.rid}: {len(req.out)} tokens -> {req.out}")


if __name__ == "__main__":
    main()
