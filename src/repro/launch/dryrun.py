import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, into artifacts/dryrun/:
  - memory_analysis (per-device bytes: proves it fits 16 GB HBM),
  - cost_analysis FLOPs/bytes (XLA's view; while bodies counted once),
  - trip-count-corrected dot FLOPs / HBM bytes / collective traffic from
    the post-optimization HLO (repro.analysis.hlo),
  - the three roofline terms + dominant bottleneck (§Roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--cells a,b,...]
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.analysis import hlo as hlo_analysis
from repro.configs.base import SHAPES_BY_NAME, ShapeConfig, shape_applicable
from repro.launch.mesh import make_production_mesh, production_rules
from repro.models.registry import (ARCH_IDS, active_param_count,
                                   build_model, get_config, param_count)
from repro.serve import make_prefill_step, make_serve_step
from repro.sharding import MeshRules, tree_shardings, use_rules
from repro.train import make_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__),
                         "..", "..", "..", "artifacts", "dryrun")


def pick_accum(cfg, shape: ShapeConfig, total_dp: int) -> int:
    """Gradient-accumulation depth: keeps per-chip microbatch at a size
    class that fits activations in 16 GB (giants -> 1 seq/chip)."""
    n = param_count(cfg)
    per_dp = max(1, shape.global_batch // total_dp)
    mb = 1 if n > 3e10 else (2 if n > 5e9 else 4)
    return max(1, per_dp // mb)


def batch_shardings(rules: MeshRules, specs: Dict[str, Any]):
    out = {}
    for k, v in specs.items():
        if v.ndim == 0:
            out[k] = NamedSharding(rules.mesh, P())
        else:
            bspec = rules.rules.get("batch")
            n = 1
            if bspec is not None:
                names = (bspec,) if isinstance(bspec, str) else bspec
                for a in names:
                    n *= rules.mesh.shape[a]
            spec = bspec if (n > 1 and v.shape[0] % n == 0) else None
            out[k] = NamedSharding(rules.mesh, P(spec))
    return out


def _opt_axes(model, use_master: bool = True):
    pax = model.param_logical_axes()
    return optim.OptState(step=(), mu=pax, nu=pax,
                          master=pax if use_master else None)


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               cross_pod_mode: str = "xla",
               order: str = "grouped", seq_parallel: bool = False,
               fsdp: bool = True, accum_override: int = 0,
               use_master: bool = True):
    """Returns (lowered, meta) for one cell."""
    shape = SHAPES_BY_NAME[shape_name]
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": reason}
    model = build_model(cfg, remat=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    long_ctx = shape_name == "long_500k"
    from repro.sharding import make_rules
    rules = make_rules(mesh, long_ctx=long_ctx,
                       seq_shard=(shape.kind == "decode" and not long_ctx),
                       fsdp=fsdp, seq_parallel=seq_parallel)
    n_chips = mesh.size
    total_dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)

    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    pax = model.param_logical_axes()
    param_sh = tree_shardings(mesh, rules, params_shapes, pax)
    in_specs = model.input_specs(shape)
    batch_sh = batch_shardings(rules, in_specs)

    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "n_chips": n_chips,
        "params": param_count(cfg),
        "active_params": active_param_count(cfg),
        "tokens": shape.tokens,
        "knobs": {"seq_parallel": seq_parallel, "fsdp": fsdp,
                  "cross_pod_mode": cross_pod_mode,
                  "accum_override": accum_override,
                  "use_master": use_master},
    }

    if shape.kind == "train":
        accum = accum_override or pick_accum(cfg, shape, total_dp)
        meta["accum"] = accum
        ocfg = optim.AdamWConfig(use_master=use_master)
        opt_shapes = jax.eval_shape(
            functools.partial(optim.init, ocfg), params_shapes)
        # ZeRO-1 when fsdp is off: optimizer states stay data-sharded
        opt_rules = rules if fsdp else make_rules(
            mesh, long_ctx=long_ctx, fsdp=True,
            seq_parallel=seq_parallel)
        opt_sh = tree_shardings(mesh, opt_rules, opt_shapes,
                                _opt_axes(model, use_master))
        step = make_train_step(model, ocfg, accum=accum, rules=rules,
                               cross_pod_mode=cross_pod_mode)

        def wrapped(params, opt_state, batch):
            with use_rules(rules):
                return step(params, opt_state, batch)

        jitted = jax.jit(wrapped, donate_argnums=(0, 1),
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, None))
        with mesh:
            lowered = jitted.lower(params_shapes, opt_shapes, in_specs)
        meta["model_flops"] = 6.0 * active_param_count(cfg) * shape.tokens
    elif shape.kind == "prefill":
        def pre(params, batch):
            with use_rules(rules):
                logits, _ = model.forward_logits(params, batch)
                return logits
        jitted = jax.jit(pre, in_shardings=(param_sh, batch_sh))
        with mesh:
            lowered = jitted.lower(params_shapes, in_specs)
        meta["model_flops"] = 2.0 * active_param_count(cfg) * shape.tokens
    else:                          # decode
        cache_shapes = jax.eval_shape(
            functools.partial(model.init_cache, shape.global_batch,
                              shape.seq_len))
        cache_sh = tree_shardings(mesh, rules, cache_shapes,
                                  model.cache_logical_axes())

        def dec(params, cache, tokens, pos):
            with use_rules(rules):
                return model.decode_step(params, cache, tokens, pos)

        jitted = jax.jit(
            dec, donate_argnums=(1,),
            in_shardings=(param_sh, cache_sh,
                          batch_sh["tokens"], batch_sh["pos"]))
        with mesh:
            lowered = jitted.lower(params_shapes, cache_shapes,
                                   in_specs["tokens"], in_specs["pos"])
        meta["model_flops"] = 2.0 * active_param_count(cfg) * shape.tokens
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             cross_pod_mode: str = "xla", order: str = "grouped",
             out_dir: Optional[str] = None, seq_parallel: bool = False,
             fsdp: bool = True, accum_override: int = 0,
             use_master: bool = True,
             tag: str = "") -> Dict[str, Any]:
    t0 = time.time()
    try:
        lowered, meta = build_cell(arch, shape_name, multi_pod=multi_pod,
                                   cross_pod_mode=cross_pod_mode,
                                   order=order, seq_parallel=seq_parallel,
                                   fsdp=fsdp,
                                   accum_override=accum_override,
                                   use_master=use_master)
        if lowered is None:
            meta.update({"arch": arch, "shape": shape_name,
                         "mesh": "2x16x16" if multi_pod else "16x16",
                         "status": "skipped"})
            return _write(meta, out_dir, tag)
        meta["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        meta["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        meta["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.temp_size_in_bytes),
            "fits_16gb": (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes) < 16e9,
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0] if ca else {}
        meta["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        cpp = 256 if multi_pod else None
        stats = hlo_analysis.analyze(compiled.as_text(),
                                     chips_per_pod=cpp)
        rf = hlo_analysis.roofline(
            stats, n_chips=meta["n_chips"],
            model_flops_global=meta["model_flops"])
        meta["hlo"] = {
            "dot_flops_per_device": stats.dot_flops,
            "hbm_bytes_per_device": stats.hbm_bytes,
            "collective_bytes_per_device": stats.collective_bytes,
            "collective_operand_bytes": stats.collective_operand_bytes,
            "cross_pod_bytes_per_device": stats.cross_pod_bytes,
            "collective_ops": stats.collective_ops,
        }
        meta["roofline"] = rf.to_dict()
        meta["status"] = "ok"
    except Exception as e:                      # noqa: BLE001
        meta = {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:]}
    meta["total_s"] = time.time() - t0
    return _write(meta, out_dir, tag)


def _write(meta: Dict[str, Any], out_dir: Optional[str], tag: str):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{meta['arch']}__{meta['shape']}__{meta['mesh']}"
        if tag:
            name += f"__{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(meta, f, indent=1, default=str)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    # 'compressed' retired: multi-pod meshes raise NotImplementedError
    # in make_train_step (use hier_bucketed + slow_compress_bits=8)
    ap.add_argument("--cross-pod-mode", default="xla",
                    choices=["xla"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="ZeRO-1: replicate params over data, shard only "
                         "optimizer states")
    ap.add_argument("--accum", type=int, default=0)
    ap.add_argument("--no-master", action="store_true",
                    help="AdamW without f32 master weights (bf16 params as master)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACTS))
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES_BY_NAME:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for multi_pod in meshes:
        for arch, shape in cells:
            meta = run_cell(arch, shape, multi_pod=multi_pod,
                            cross_pod_mode=args.cross_pod_mode,
                            seq_parallel=args.seq_parallel,
                            fsdp=not args.no_fsdp,
                            accum_override=args.accum,
                            use_master=not args.no_master,
                            out_dir=args.out, tag=args.tag)
            status = meta.get("status")
            line = (f"[{meta.get('mesh')}] {arch:24s} {shape:12s} "
                    f"{status:8s}")
            if status == "ok":
                m = meta["memory"]
                r = meta["roofline"]
                line += (f" mem={m['peak_estimate_bytes']/1e9:6.2f}GB"
                         f" fits={m['fits_16gb']}"
                         f" dom={r['dominant']:10s}"
                         f" bound={r['bound_s']*1e3:8.2f}ms"
                         f" compile={meta['compile_s']:5.1f}s")
            elif status == "error":
                failures += 1
                line += " " + meta["error"][:120]
            else:
                line += " " + meta.get("skipped", "")[:80]
            print(line, flush=True)
            if status == "ok":
                print("  memory:", meta["memory"], flush=True)
                print("  cost:", meta["cost_analysis"],
                      "collectives:", meta["hlo"]["collective_ops"],
                      flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
