"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

On the CPU container this trains reduced configs on a single device; on a
real TPU runtime the same entrypoint builds the production mesh and runs
the sharded step (the dry-run proves those lower+compile).
"""
from __future__ import annotations

import argparse

import jax

from repro import optim
from repro.data import DataConfig
from repro.models.registry import ARCH_IDS, build_model, get_config, \
    reduced_config
from repro.sharding import make_rules
from repro.train import Trainer, TrainerConfig


def _parse_reconfig_schedule(spec: str):
    """'10:4x1,20:1x4' -> [ReconfigEvent(step=10, mesh_shape=(4, 1)), …]"""
    from repro.elastic_driver import ReconfigEvent
    events = []
    for item in spec.split(","):
        try:
            step_s, shape_s = item.strip().split(":")
            pod_s, data_s = shape_s.lower().split("x")
            events.append(ReconfigEvent(step=int(step_s),
                                        mesh_shape=(int(pod_s),
                                                    int(data_s))))
        except ValueError as e:
            raise SystemExit(
                f"bad --reconfig-at entry {item!r} (want STEP:PODxDATA,"
                f" e.g. '10:4x1'): {e}")
    return events


def _run_elastic(args, cfg, model) -> None:
    """--reconfig-at path: the elastic preemption/repack driver."""
    from repro.data import DataConfig
    from repro.elastic_driver import ElasticDriver

    if not args.data_parallel:
        raise SystemExit("--reconfig-at needs --data-parallel (the "
                         "data axis of the initial factorization)")
    if args.model_parallel != 1:
        raise SystemExit("the elastic driver trains hier_bucketed_zero1 "
                         "on a pure (pod, data) mesh; --model-parallel "
                         "must be 1")
    # the driver pins its training configuration; reject sync flags it
    # would otherwise silently ignore ('xla' is the untouched default)
    if args.cross_pod_mode not in ("xla", "hier_bucketed_zero1"):
        raise SystemExit(
            f"--reconfig-at implies cross_pod_mode=hier_bucketed_zero1; "
            f"{args.cross_pod_mode!r} is not supported by the elastic "
            f"driver")
    if args.overlap:
        raise SystemExit("--overlap has no pipeline under the driver's "
                         "deterministic reduce")
    if args.slow_compress_bits and not (args.slow_compress_bits == 8
                                        and args.error_feedback):
        raise SystemExit(
            "the elastic driver compresses the slow hop only as int8 "
            "with error feedback (--slow-compress-bits 8 "
            "--error-feedback)")
    schedule = _parse_reconfig_schedule(args.reconfig_at)
    n_devices = args.pod_parallel * args.data_parallel
    for e in schedule:
        if e.mesh_shape[0] * e.mesh_shape[1] != n_devices:
            raise SystemExit(
                f"reconfig target {e.mesh_shape} is not a factorization "
                f"of {n_devices} devices")
        if e.step >= args.steps:
            raise SystemExit(
                f"reconfig step {e.step} is past the run "
                f"(--steps {args.steps}); it would silently never fire")
    from repro.faults.retry import RetryPolicy
    drv = ElasticDriver(
        model,
        optim.AdamWConfig(peak_lr=args.lr, warmup_steps=20,
                          total_steps=args.steps),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch),
        base_dir=args.ckpt_dir, bucket_bytes=args.bucket_mb << 20,
        accum=args.accum, mode=args.reconfig_mode,
        error_feedback=args.error_feedback,
        retry=RetryPolicy(max_retries=args.max_restore_retries),
        fallback_on_corrupt=args.fallback_on_corrupt)
    out = drv.run(args.steps, schedule,
                  initial_shape=(args.pod_parallel, args.data_parallel),
                  resume=args.resume)
    if out.start_step:
        print(f"resumed from committed step {out.start_step}")
    if out.recovery is not None and out.recovery.quarantined:
        for q in out.recovery.quarantined:
            print(f"quarantined corrupt step {q.step} -> "
                  f"{q.quarantined_to}")
    for i, (loss, shape) in enumerate(zip(out.losses, out.mesh_shapes),
                                      start=out.start_step):
        print(f"step {i:4d}  loss {loss:.4f}  mesh {shape}")
    for m in out.measurements:
        print(f"reconfig@{m.step}: {m.from_shape}->{m.to_shape} "
              f"[{m.mode}] save {m.save_s*1e3:.0f} ms, restore "
              f"{m.restore_s*1e3:.0f} ms, recompile "
              f"{m.compile_s*1e3:.0f} ms, verified={m.verified}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="devices for a (dp, mp) mesh; 0 = single device")
    ap.add_argument("--model-parallel", type=int, default=1)
    from repro.train import CROSS_POD_MODES
    ap.add_argument("--cross-pod-mode", default="xla",
                    choices=CROSS_POD_MODES,
                    help="gradient sync schedule (bucketed modes need a "
                         "pure data-parallel mesh)")
    ap.add_argument("--bucket-mb", type=int, default=32,
                    help="bucket capacity for the hier_bucketed* modes")
    ap.add_argument("--overlap", action="store_true",
                    help="pipeline bucket i+1's fast reduce-scatter under "
                         "bucket i's slow hop (hier_bucketed* modes; "
                         "bitwise-identical losses)")
    ap.add_argument("--slow-compress-bits", type=int, default=0,
                    choices=(0, 8, 16),
                    help="compress the slow (cross-pod) hop: 16=bf16, "
                         "8=int8+scale")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry int8 quantization residuals across steps "
                         "(requires --slow-compress-bits 8 and a "
                         "hier_bucketed* mode)")
    ap.add_argument("--deterministic-reduce", action="store_true",
                    help="mesh-factorization-invariant gradient reduce "
                         "(hier_bucketed* modes): bitwise-identical "
                         "training across (pod, data) factorizations, so "
                         "sharded checkpoints reshard-restore exactly "
                         "onto a repacked mesh")
    ap.add_argument("--resume", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="resume from the latest committed checkpoint in "
                         "--ckpt-dir (--no-resume starts from scratch)")
    ap.add_argument("--max-restore-retries", type=int, default=0,
                    help="bounded exponential-backoff retries for "
                         "transient I/O (EIO/ENOSPC/...) during "
                         "checkpoint save and restore")
    ap.add_argument("--fallback-on-corrupt", action="store_true",
                    help="if the newest committed checkpoint fails its "
                         "CRC/manifest validation at resume, quarantine "
                         "it on disk and fall back to the previous "
                         "committed step instead of dying")
    ap.add_argument("--save-sharded", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="write per-rank shard + manifest checkpoints "
                         "(repro.ckpt); --no-save-sharded keeps the "
                         "legacy gathered per-leaf format")
    ap.add_argument("--reconfig-at", default="",
                    help="elastic repack schedule 'STEP:PODxDATA[,...]' "
                         "(e.g. '10:4x1,20:1x4'): run the elastic "
                         "driver, executing a save -> reshard-restore "
                         "-> continue cycle at each step; implies "
                         "hier_bucketed_zero1 + deterministic reduce")
    ap.add_argument("--reconfig-mode", default="handoff",
                    choices=("drain", "handoff"),
                    help="how --reconfig-at events move state: "
                         "'handoff' = committed sharded save + "
                         "reshard-restore (drain-free); 'drain' = "
                         "legacy gathered save + full restore (the "
                         "incumbent cycle, for cost comparison)")
    ap.add_argument("--pod-parallel", type=int, default=1,
                    help="pod axis of the initial (pod, data) "
                         "factorization for --reconfig-at runs")
    args = ap.parse_args()

    # the recovery knobs act at restore time; with --no-resume there is
    # no restore, so accepting them would silently do nothing
    if not args.resume and args.fallback_on_corrupt:
        raise SystemExit("--fallback-on-corrupt is a resume-time "
                         "recovery knob; it does nothing with "
                         "--no-resume — drop one of the two")
    if not args.resume and args.max_restore_retries and not args.reconfig_at:
        raise SystemExit("--max-restore-retries needs a restore to "
                         "retry; with --no-resume (and no --reconfig-at "
                         "handoffs) it does nothing — drop one of the "
                         "two")
    if args.max_restore_retries < 0:
        raise SystemExit("--max-restore-retries must be >= 0")

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced_config(cfg)
    model = build_model(cfg, remat=args.full_config)

    if args.reconfig_at:
        _run_elastic(args, cfg, model)
        return

    rules = None
    if args.data_parallel:
        mesh = jax.make_mesh((args.data_parallel, args.model_parallel),
                             ("data", "model"))
        # manual sync modes keep params replicated (train._check_manual_
        # sync_rules rejects FSDP rules), so build ZeRO-1-style rules
        from repro.train import MANUAL_SYNC_MODES
        rules = make_rules(
            mesh, fsdp=args.cross_pod_mode not in MANUAL_SYNC_MODES)

    trainer = Trainer(
        model,
        optim.AdamWConfig(peak_lr=args.lr, warmup_steps=20,
                          total_steps=args.steps),
        TrainerConfig(n_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, log_every=10,
                      accum=args.accum,
                      cross_pod_mode=args.cross_pod_mode,
                      bucket_bytes=args.bucket_mb << 20,
                      slow_compress_bits=args.slow_compress_bits,
                      overlap=args.overlap,
                      slow_error_feedback=args.error_feedback,
                      deterministic_reduce=args.deterministic_reduce,
                      save_sharded=args.save_sharded),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch),
        rules=rules)
    out = trainer.run(resume=args.resume)
    for h in out["history"]:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"{h['sec_per_step']*1e3:.0f} ms")


if __name__ == "__main__":
    main()
