"""CLI for the multi-tenant cluster runtime.

Runs several training jobs as co-scheduled subprocesses over one shared
fake-device pool, printing every scheduler-driven repack and the
measured per-boundary handoff costs.  Jobs come from a CSV trace file
(:func:`repro.core.traces.load_trace` — the optional ``tenant`` /
``priority_tier`` columns select tenancy) or from ``--demo``, the
canonical 3-job/2-tenant contention scenario (one defrag repack forced
by a single-host-pinned tier-0 arrival, one rebalance repack after it
departs).

Usage:
  python -m repro.launch.cluster --demo
  python -m repro.launch.cluster --trace jobs.csv --pool 2x4 \\
      --policy backfill --quota beta=6 --steps 8 --segment-steps 4
"""
from __future__ import annotations

import argparse
import json
import tempfile

from repro.cluster import ClusterJobSpec, ClusterRuntime, DevicePool
from repro.core.job import TIER_HIGH
from repro.core.scheduler import Scheduler
from repro.core.traces import load_trace


def demo_specs(steps: int, segment_steps: int):
    """3 jobs, 2 tenants, mixed tiers on a 2x4 pool: j1's departure
    leaves the pool fragmented for the single-host-pinned j2, forcing a
    defrag repack of j0; j2's departure triggers j0's rebalance."""
    return [
        ClusterJobSpec("j0", size=4, n_steps=max(steps, 12),
                       segment_steps=segment_steps, tenant="acme"),
        ClusterJobSpec("j1", size=2, n_steps=2, segment_steps=2,
                       tenant="beta"),
        ClusterJobSpec("j2", size=4, n_steps=2, segment_steps=2,
                       tenant="beta", priority_tier=TIER_HIGH,
                       after="j1"),
    ]


def specs_from_trace(path: str, *, steps: int, segment_steps: int):
    jobs = load_trace(path)
    return [ClusterJobSpec(j.job_id, size=j.size, n_steps=steps,
                           segment_steps=segment_steps, tenant=j.tenant,
                           priority_tier=j.priority_tier, seed=i)
            for i, j in enumerate(jobs)]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--trace", help="CSV trace file")
    src.add_argument("--demo", action="store_true",
                     help="canonical 3-job contention scenario")
    ap.add_argument("--pool", default="2x4",
                    help="HOSTSxDEVICES_PER_HOST (default 2x4)")
    ap.add_argument("--policy", default="backfill",
                    choices=("fifo", "backfill"))
    ap.add_argument("--depth", type=int, default=8,
                    help="backfill window")
    ap.add_argument("--quota", action="append", default=[],
                    metavar="TENANT=N",
                    help="per-tenant device quota (repeatable)")
    ap.add_argument("--steps", type=int, default=15,
                    help="steps per trace job (demo: long job)")
    ap.add_argument("--segment-steps", type=int, default=3)
    ap.add_argument("--base-dir", default=None,
                    help="work dir (default: a fresh temp dir)")
    ap.add_argument("--no-rebalance", action="store_true")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also dump the run summary to this file")
    args = ap.parse_args(argv)

    n_hosts, dph = (int(x) for x in args.pool.lower().split("x"))
    quotas = {}
    for q in args.quota:
        tenant, n = q.split("=")
        quotas[tenant] = int(n)

    if args.demo:
        specs = demo_specs(args.steps, args.segment_steps)
    else:
        specs = specs_from_trace(args.trace, steps=args.steps,
                                 segment_steps=args.segment_steps)

    rt = ClusterRuntime(
        specs, pool=DevicePool(n_hosts, dph),
        base_dir=args.base_dir or tempfile.mkdtemp(prefix="cluster_"),
        scheduler=Scheduler(args.policy, depth=args.depth,
                            quotas=quotas or None),
        rebalance=not args.no_rebalance)
    res = rt.run()

    print(f"pool {n_hosts}x{dph}  jobs {len(specs)}  "
          f"repacks {res.n_repacks}  wall {res.wall_s:.1f}s")
    for r in res.repacks:
        print(f"  repack {r.job_id}: {r.reason} at step {r.at_step}  "
              f"{r.from_shape}->{r.to_shape}"
              + (f"  (admits {r.requested_by})" if r.requested_by
                 else ""))
    for jid in sorted(res.jobs):
        o = res.jobs[jid]
        print(f"  {jid}: {len(o.losses)} steps, shapes "
              f"{o.shapes}, restarts {o.restarts}, "
              f"final loss {o.losses[-1]:.4f}")
    for m in res.measurements:
        print(f"  handoff {m['job_id']}@{m['step']}: "
              f"save {m['save_s'] * 1e3:.0f}ms  restore "
              f"{m['restore_s'] * 1e3:.0f}ms  repack={m['repack']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({
                "repacks": [r.to_dict() for r in res.repacks],
                "measurements": res.measurements,
                "jobs": {jid: {"losses": o.losses,
                               "shapes": [list(s) for s in o.shapes],
                               "restarts": o.restarts}
                         for jid, o in res.jobs.items()},
            }, f, indent=2)


if __name__ == "__main__":
    main()
