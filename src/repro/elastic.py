"""Fault tolerance & elasticity: heartbeats, stragglers, elastic re-mesh.

The one-to-many model makes elasticity natural: a job's resources are a
*set of leaves*, so losing a host shrinks the set; the job re-meshes over
the survivors and restores from the latest checkpoint with new shardings
(checkpoint.restore handles the re-device_put).  This is the runtime
counterpart of the simulator's drain-free operation (I3).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.leaves import TpuLeaf


class HeartbeatMonitor:
    """Tracks per-worker heartbeats; reports workers past the timeout."""

    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last: Dict[int, float] = {}

    def beat(self, worker: int, t: Optional[float] = None) -> None:
        self.last[worker] = time.time() if t is None else t

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return [w for w, t in self.last.items()
                if now - t > self.timeout_s]


class StragglerDetector:
    """Flags steps slower than median + k*MAD (straggler mitigation
    trigger: re-shard away from the slow worker / skip its contribution)."""

    def __init__(self, k: float = 5.0, window: int = 50):
        self.k = k
        self.window = window
        self.durations: List[float] = []
        self.flagged: List[int] = []

    def record(self, dt: float) -> bool:
        self.durations.append(dt)
        tail = self.durations[-self.window:]
        if len(tail) < 8:
            return False
        med = statistics.median(tail)
        # MAD floored at 5% of the median: near-constant step times must
        # not turn ordinary jitter into straggler alarms
        mad = max(statistics.median([abs(x - med) for x in tail]),
                  0.05 * med)
        slow = dt > med + self.k * mad
        if slow:
            self.flagged.append(len(self.durations) - 1)
        return slow

    def summary(self) -> Dict[str, float]:
        if not self.durations:
            return {"steps": 0, "stragglers": 0}
        return {"steps": len(self.durations),
                "stragglers": len(self.flagged),
                "median_s": statistics.median(self.durations)}


@dataclasses.dataclass(frozen=True)
class CheckpointHandoff:
    """The state handoff a remesh rides on: which committed checkpoint
    the re-meshed job restores from, and how.

    ``sharded`` names the per-rank shard + manifest format
    (:mod:`repro.ckpt`): each new rank reads only its own slices of the
    flat bucket address space, so the restore is drain-free — no rank
    ever gathers a full optimizer bucket while the job reconfigures.
    """

    base_dir: str
    step: int
    step_dir: str
    sharded: bool


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    surviving: Tuple[TpuLeaf, ...]
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_hosts: Tuple[Tuple[int, int], ...]
    # the checkpoint the re-meshed job resumes from (None when the plan
    # was made without a checkpoint directory — pre-PR-4 callers)
    handoff: Optional[CheckpointHandoff] = None


def plan_elastic_remesh(leaves: Sequence[TpuLeaf],
                        failed_hosts: Sequence[Tuple[int, int]],
                        *, model_parallel: int,
                        ckpt_base_dir: Optional[str] = None
                        ) -> RemeshPlan:
    """Shrink the data axis to the largest size the survivors support.

    Keeps 'model' intact (parameter shards must stay complete) and drops
    whole data-parallel groups containing failed hosts — the standard
    elastic-DP policy.

    ``ckpt_base_dir`` names the checkpoint handoff: the plan then
    carries the latest *committed* step the re-meshed job restores from
    (torn/in-flight step dirs are never selected).  A remesh without any
    committed checkpoint is refused — reconfiguring a job whose state
    cannot be recovered silently restarts it from scratch, which is
    exactly the failure mode drain-free reconfiguration exists to avoid.
    """
    failed = set(failed_hosts)
    surviving = [l for l in leaves if (l.pod, l.host) not in failed]
    n = len(surviving)
    if n < model_parallel:
        raise RuntimeError("not enough leaves for one model shard")
    data = n // model_parallel
    # power-of-two friendly shrink for clean microbatching
    while data > 1 and (n % (data * model_parallel)):
        data -= 1
    used = surviving[:data * model_parallel]
    handoff = None
    if ckpt_base_dir is not None:
        from repro import ckpt as ckpt_lib
        step = ckpt_lib.latest_step(ckpt_base_dir)
        if step is None:
            raise RuntimeError(
                f"remesh requested with checkpoint handoff, but "
                f"{ckpt_base_dir!r} holds no committed checkpoint")
        sdir = ckpt_lib.step_dir(ckpt_base_dir, step)
        handoff = CheckpointHandoff(
            base_dir=ckpt_base_dir, step=step, step_dir=sdir,
            sharded=ckpt_lib.is_sharded_dir(sdir))
    return RemeshPlan(tuple(used), (data, model_parallel),
                      ("data", "model"), tuple(sorted(failed)),
                      handoff=handoff)


def repack_on_failure(leaves: Sequence[TpuLeaf],
                      failed_hosts: Sequence[Tuple[int, int]],
                      *, model_parallel: int = 1,
                      ckpt_base_dir: Optional[str] = None
                      ) -> Optional[RemeshPlan]:
    """Remesh a job after an *unplanned* host failure.

    Differs from :func:`plan_elastic_remesh` (the planned-repack path)
    in how it degrades: a planned handoff with no committed checkpoint
    is a caller bug and is refused, but a *failure* can strike before
    the first commit, and the honest answer there is a full restart —
    so a ``ckpt_base_dir`` with no committed step is dropped rather
    than raised on (the plan carries ``handoff=None``: restart from
    scratch), and losing too many hosts to form even one model shard
    returns ``None`` (no viable repack; the scheduler requeues the
    job).  The simulator's MTBF failure events recover through this
    entry point and charge the result via
    :meth:`repro.core.jct_model.ReconfigCostModel.failure_restart_s`.
    """
    if ckpt_base_dir is not None:
        from repro import ckpt as ckpt_lib
        if ckpt_lib.latest_step(ckpt_base_dir) is None:
            ckpt_base_dir = None
    try:
        return plan_elastic_remesh(leaves, failed_hosts,
                                   model_parallel=model_parallel,
                                   ckpt_base_dir=ckpt_base_dir)
    except RuntimeError:
        return None
