"""Post-optimization HLO accounting for the roofline report.

``compiled.cost_analysis()`` on this backend counts every ``while`` body
once, which undercounts scanned layer stacks by ~n_layers.  This module
walks the shared HLO IR (:mod:`repro.analysis.ir`) call-graph, multiplies
through ``backend_config known_trip_count`` on while ops, and accounts:

- dot FLOPs (the MXU term; elementwise FLOPs are negligible at LM shapes),
- HBM bytes at fusion/op granularity (operands + results of non-free ops),
- collective traffic per op kind with a ring model
  (all-reduce 2x, all-gather/reduce-scatter (n-1)/n x full tensor, ...).

All numbers are per device (the SPMD program is per device).  The parser,
replica-group decoding and pod-cut classification live in
:mod:`repro.analysis.ir` (shared with :mod:`repro.analysis.lint`);
``analyze``/``slow_collective_chains`` accept either raw HLO text or an
already-parsed :class:`~repro.analysis.ir.Module`, so callers that run
several checkers over one program parse it once.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple, Union

from repro.analysis import ir
from repro.analysis.ir import (Computation, Module, Op,  # noqa: F401
                               parse_module, type_bytes)

_COLLECTIVES = ir.COLLECTIVE_PREFIXES
_FREE_OPS = ir.FREE_OPS
_TYPE_RE = ir.TYPE_RE
_parse_replica_groups = ir.parse_replica_groups
_crosses_pod = ir.crosses_pod

ModuleLike = Union[str, Module]


def _as_module(src: ModuleLike) -> Module:
    return src if isinstance(src, Module) else ir.parse(src)


def _dot_flops(op: Op, types: Dict[str, str]) -> float:
    res = op.result_type
    out_elems = 1
    tm = _TYPE_RE.search(res)
    if tm and tm.group(2):
        for d in tm.group(2).split(","):
            out_elems *= int(d)
    lhs_t = types.get(op.operands[0], "") if op.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1
    if m and lhs_t:
        lm = _TYPE_RE.search(lhs_t)
        if lm and lm.group(2):
            dims = [int(x) for x in lm.group(2).split(",")]
            for idx in m.group(1).split(","):
                if idx:
                    contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _collective_traffic(op: Op, types: Dict[str, str]) -> float:
    """Ring-model bytes moved per device for one collective op."""
    operand_bytes = sum(type_bytes(types.get(o, "")) for o in op.operands)
    result_bytes = type_bytes(op.result_type)
    kind = op.opcode.replace("-start", "")
    if kind.startswith("all-reduce"):
        return 2.0 * operand_bytes
    if kind.startswith("all-gather"):
        return max(result_bytes - operand_bytes, 0)
    if kind.startswith("reduce-scatter"):
        return max(operand_bytes - result_bytes, 0)
    if kind.startswith("all-to-all"):
        return operand_bytes
    if kind.startswith("collective-permute"):
        return operand_bytes
    return operand_bytes


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0          # ring-model traffic
    collective_operand_bytes: float = 0.0  # spec-literal operand sum
    cross_pod_bytes: float = 0.0           # traffic crossing the pod cut
    cross_pod_operand_bytes: float = 0.0   # payload bytes handed to those ops
    collective_ops: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "HloStats", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_operand_bytes += \
            other.collective_operand_bytes * mult
        self.cross_pod_bytes += other.cross_pod_bytes * mult
        self.cross_pod_operand_bytes += other.cross_pod_operand_bytes * mult
        for k, v in other.collective_ops.items():
            self.collective_ops[k] = (self.collective_ops.get(k, 0)
                                      + int(v * mult))


def analyze(src: ModuleLike, *,
            chips_per_pod: Optional[int] = None) -> HloStats:
    mod = _as_module(src)
    comps = mod.computations
    memo: Dict[str, HloStats] = {}

    def visit(name: str, stack=()) -> HloStats:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HloStats()
        c = comps[name]
        types = c.result_types()
        st = HloStats()
        for op in c.ops:
            oc = op.opcode
            if oc == "while":
                trips = mod.trip_count(op)
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if bm:
                    st.add(visit(bm.group(1), stack + (name,)), trips)
                continue
            if oc == "conditional":
                bm = re.findall(r"%?([\w.\-]+)", op.attrs.split(
                    "branch_computations", 1)[-1].split("}", 1)[0])
                bm = [b for b in bm if b in comps]
                if bm:
                    subs = [visit(b, stack + (name,)) for b in bm]
                    best = max(subs, key=lambda s: s.dot_flops
                               + s.hbm_bytes)
                    st.add(best)
                continue
            if oc in ("fusion", "call", "async-start"):
                cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.attrs)
                if cm:
                    sub = visit(cm.group(1), stack + (name,))
                    # only dot flops counted from inside fusions; bytes are
                    # accounted at the fusion call site below
                    only = HloStats(dot_flops=sub.dot_flops,
                                    collective_bytes=sub.collective_bytes,
                                    collective_operand_bytes=(
                                        sub.collective_operand_bytes),
                                    cross_pod_bytes=sub.cross_pod_bytes,
                                    cross_pod_operand_bytes=(
                                        sub.cross_pod_operand_bytes),
                                    collective_ops=sub.collective_ops)
                    st.add(only)
            if oc in ("dot", "convolution"):
                st.dot_flops += _dot_flops(op, types)
            if op.is_collective:
                traffic = _collective_traffic(op, types)
                operand = sum(type_bytes(types.get(o, ""))
                              for o in op.operands)
                st.collective_bytes += traffic
                st.collective_operand_bytes += operand
                if chips_per_pod and _crosses_pod(op, chips_per_pod):
                    st.cross_pod_bytes += traffic
                    st.cross_pod_operand_bytes += operand
                k = oc.replace("-start", "")
                st.collective_ops[k] = st.collective_ops.get(k, 0) + 1
            if oc not in _FREE_OPS and not oc.endswith("-done"):
                st.hbm_bytes += type_bytes(op.result_type) + sum(
                    type_bytes(types.get(o, "")) for o in op.operands)
        memo[name] = st
        return st

    if mod.entry is None:
        return HloStats()
    return visit(mod.entry.name)


# ---------------------------------------------------------------------------
# slow-collective dependency chains (pipelinability)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SlowChain:
    """Data-dependency structure of the slow (cross-pod) collectives.

    ``max_depth`` is the length of the longest chain of slow collectives
    connected by data dependencies: 1 means every slow collective is
    independent of every other — the overlapped bucket schedule's
    pipelinability invariant (each bucket's slow hop can be in flight
    while other buckets' fast phases run).  ``dependent_pairs`` lists
    (ancestor, descendant) witnesses when the chain is deeper.
    """

    n_slow: int
    max_depth: int
    dependent_pairs: List[Tuple[str, str]]

    @property
    def independent(self) -> bool:
        return self.max_depth <= 1

    def to_dict(self):
        return {"n_slow": self.n_slow, "max_depth": self.max_depth,
                "independent": self.independent,
                "dependent_pairs": [list(p) for p in
                                    self.dependent_pairs[:16]]}


def slow_collective_chains(src: ModuleLike, *,
                           chips_per_pod: int) -> SlowChain:
    """Prove (or refute) slow-collective independence from lowered HLO.

    Walks the def-use graph of the module: every collective op whose
    replica groups cross the pod cut (``ir.crosses_pod``) becomes a node,
    and node B depends on node A when A is in the transitive operand
    cone of B.  Called computations (fusion/call/while bodies) are
    followed with parameter-index binding (``parameter(i)`` ops take the
    i-th call-operand's cone); ``-done`` halves of async pairs pass
    their cone through without counting again.  While bodies get one
    extra cone-propagation pass with the first pass's result folded
    into the carry (without re-registering the body's collectives), so
    the while op's consumers see cross-iteration reachability; chains
    *between iterations of the same while* are not claimed as depth —
    a trip-counted loop serializes its body regardless, and the flat
    (scan-free) sync schedules this checker gates contain no whiles.
    """
    mod = _as_module(src)
    comps = mod.computations
    depth: Dict[int, int] = {}
    names: Dict[int, str] = {}
    pairs: List[Tuple[str, str]] = []
    counter = iter(range(1 << 30))

    def register(op: Op, qual: str, cone: frozenset) -> frozenset:
        sid = next(counter)
        names[sid] = qual
        depth[sid] = 1 + max((depth[a] for a in cone), default=0)
        for a in sorted(cone):
            if len(pairs) < 64:
                pairs.append((names[a], qual))
        return cone | {sid}

    def visit(comp_name: str, param_cones: Tuple[frozenset, ...],
              stack: Tuple[str, ...], *,
              register_nodes: bool = True) -> frozenset:
        c = comps.get(comp_name)
        if c is None or comp_name in stack:
            return frozenset()
        cones: Dict[str, frozenset] = {}
        for pname, pc in zip(c.params, param_cones):
            cones[pname] = pc
        out = None
        for op in c.ops:
            if op.opcode == "parameter":
                # bind by parameter index: `%p = f32[..] parameter(i)`
                # re-declares a computation parameter as an op; its cone
                # is the matching call operand's, never empty
                idx = int(op.operands[0]) if (
                    op.operands and op.operands[0].isdigit()) else -1
                if 0 <= idx < len(param_cones):
                    cones[op.name] = param_cones[idx]
                if op.is_root or (out is None and op is c.ops[-1]):
                    out = cones.get(op.name, frozenset())
                continue
            cone = frozenset().union(
                *(cones.get(o, frozenset()) for o in op.operands)) \
                if op.operands else frozenset()
            subs = mod.called_computations(op)
            if subs:
                sub_params = tuple(cones.get(o, frozenset())
                                   for o in op.operands)
                for sub in subs:
                    sub_cone = visit(sub, sub_params,
                                     stack + (comp_name,),
                                     register_nodes=register_nodes)
                    if op.opcode == "while":
                        # fold the first pass's result back into the
                        # carry so the while's consumers see
                        # cross-iteration reachability; propagation
                        # only — the body's collectives registered on
                        # the first pass
                        sub_cone = sub_cone | visit(
                            sub, tuple(pc | sub_cone
                                       for pc in sub_params),
                            stack + (comp_name,), register_nodes=False)
                    cone = cone | sub_cone
            if (register_nodes and op.is_collective
                    and not op.is_async_done
                    and chips_per_pod
                    and _crosses_pod(op, chips_per_pod)):
                cone = register(op, f"{comp_name}/{op.name}", cone)
            cones[op.name] = cone
            if op.is_root or (out is None and op is c.ops[-1]):
                out = cone
        return out if out is not None else frozenset()

    entry = mod.entry
    if entry is not None:
        visit(entry.name, (frozenset(),) * len(entry.params), ())
    return SlowChain(n_slow=len(depth),
                     max_depth=max(depth.values(), default=0),
                     dependent_pairs=pairs)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12               # bf16 / chip (TPU v5e)
HBM_BW = 819e9                    # bytes/s / chip
ICI_BW = 50e9                     # bytes/s / link
DCN_BW_PER_CHIP = 6.25e9 / 4      # 50 Gb/s NIC per 4-chip host


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_device: float
    useful_ratio: float
    bound_s: float
    cross_pod_s: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline(stats: HloStats, *, n_chips: int,
             model_flops_global: float) -> Roofline:
    compute_s = stats.dot_flops / PEAK_FLOPS
    memory_s = stats.hbm_bytes / HBM_BW
    in_pod = stats.collective_bytes - stats.cross_pod_bytes
    cross_s = stats.cross_pod_bytes / DCN_BW_PER_CHIP
    coll_s = in_pod / ICI_BW + cross_s
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dom = max(terms, key=terms.get)
    useful = model_flops_global / max(stats.dot_flops * n_chips, 1e-9)
    return Roofline(compute_s, memory_s, coll_s, dom,
                    model_flops_global, stats.dot_flops, useful,
                    max(terms.values()), cross_pod_s=cross_s)
