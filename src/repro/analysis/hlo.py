"""Post-optimization HLO analysis for the roofline report.

``compiled.cost_analysis()`` on this backend counts every ``while`` body
once, which undercounts scanned layer stacks by ~n_layers.  This module
parses ``compiled.as_text()`` into a computation call-graph, multiplies
through ``backend_config known_trip_count`` on while ops, and accounts:

- dot FLOPs (the MXU term; elementwise FLOPs are negligible at LM shapes),
- HBM bytes at fusion/op granularity (operands + results of non-free ops),
- collective traffic per op kind with a ring model
  (all-reduce 2x, all-gather/reduce-scatter (n-1)/n x full tensor, ...).

All numbers are per device (the SPMD program is per device).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "add-dependency", "partition-id",
             "replica-id", "iota"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, shape = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if shape:
            for d in shape.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    ops: List[Op]


_COMP_HEADER = re.compile(
    r"^(?:ENTRY )?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")


def _parse_operands(rest: str) -> Tuple[List[str], str]:
    """Split the operand list (up to the matching close paren) from attrs."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner, attrs = rest[:i], rest[i + 1:]
                ops = [o.strip() for o in _split_top(inner)]
                names = [o.split()[-1].lstrip("%") for o in ops if o]
                return names, attrs
    return [], rest


def _split_top(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and ("->" in line):
                params = {}
                for p in _split_top(m.group(2)):
                    p = p.strip()
                    if ":" in p:
                        nm, ty = p.split(":", 1)
                        params[nm.strip().lstrip("%")] = ty.strip()
                cur = Computation(m.group(1), params, [])
                if line.strip().startswith("ENTRY"):
                    entry_name = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            root, name, rtype, opcode, rest = m.groups()
            operands, attrs = _parse_operands(rest)
            cur.ops.append(Op(name, rtype, opcode, operands, attrs,
                              is_root=bool(root)))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = re.search(r'known_trip_count.*?"n":"(\d+)"', op.attrs)
    if m:
        return int(m.group(1))
    m = re.search(r"condition=%([\w.\-]+)", op.attrs)
    if m and m.group(1) in comps:
        consts = [int(x) for x in re.findall(
            r"constant\((\d+)\)", "\n".join(
                o.attrs + o.result_type for o in comps[m.group(1)].ops))]
        # also look at raw ops text
        for o in comps[m.group(1)].ops:
            if o.opcode == "constant":
                pass
        if consts:
            return max(consts)
    return 1


def _dot_flops(op: Op, types: Dict[str, str]) -> float:
    res = op.result_type
    out_elems = 1
    tm = _TYPE_RE.search(res)
    if tm and tm.group(2):
        for d in tm.group(2).split(","):
            out_elems *= int(d)
    lhs_t = types.get(op.operands[0], "") if op.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1
    if m and lhs_t:
        lm = _TYPE_RE.search(lhs_t)
        if lm and lm.group(2):
            dims = [int(x) for x in lm.group(2).split(",")]
            for idx in m.group(1).split(","):
                if idx:
                    contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _collective_traffic(op: Op, types: Dict[str, str]) -> float:
    """Ring-model bytes moved per device for one collective op."""
    operand_bytes = sum(type_bytes(types.get(o, "")) for o in op.operands)
    result_bytes = type_bytes(op.result_type)
    kind = op.opcode.replace("-start", "")
    if kind.startswith("all-reduce"):
        return 2.0 * operand_bytes
    if kind.startswith("all-gather"):
        return max(result_bytes - operand_bytes, 0)
    if kind.startswith("reduce-scatter"):
        return max(operand_bytes - result_bytes, 0)
    if kind.startswith("all-to-all"):
        return operand_bytes
    if kind.startswith("collective-permute"):
        return operand_bytes
    return operand_bytes


def _parse_replica_groups(attrs: str) -> Optional[List[List[int]]]:
    """Parse replica_groups in iota (`[2,4]<=[8]` / `...T(1,0)`) or
    explicit (`{{0,1},{2,3}}`) form.  Returns list of device-id groups."""
    m = re.search(
        r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?",
        attrs)
    if m:
        out_dims = [int(x) for x in m.group(1).split(",")]
        in_dims = [int(x) for x in m.group(2).split(",")]
        n = 1
        for d in in_dims:
            n *= d
        ids = list(range(n))
        if m.group(4):            # transpose of the reshaped iota
            perm = [int(x) for x in m.group(4).split(",")]
            import numpy as _np
            ids = list(_np.arange(n).reshape(in_dims).transpose(
                perm).reshape(-1))
        rows, cols = out_dims[0], out_dims[1] if len(out_dims) > 1 else 1
        return [[int(ids[r * cols + c]) for c in range(cols)]
                for r in range(rows)]
    m = re.search(r"replica_groups=\{(\{[^=]*?\})\}", attrs)
    if m:
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in re.findall(r"\{([\d,\s]*)\}", m.group(1))]
    return None


def _crosses_pod(op: Op, chips_per_pod: int) -> bool:
    if op.opcode.startswith("collective-permute"):
        pairs = re.findall(r"\{(\d+),(\d+)\}", op.attrs)
        return any(int(a) // chips_per_pod != int(b) // chips_per_pod
                   for a, b in pairs)
    groups = _parse_replica_groups(op.attrs)
    if groups is None:
        return True               # conservatively cross-pod
    for g in groups:
        pods = {d // chips_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0          # ring-model traffic
    collective_operand_bytes: float = 0.0  # spec-literal operand sum
    cross_pod_bytes: float = 0.0           # traffic crossing the pod cut
    cross_pod_operand_bytes: float = 0.0   # payload bytes handed to those ops
    collective_ops: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "HloStats", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_operand_bytes += \
            other.collective_operand_bytes * mult
        self.cross_pod_bytes += other.cross_pod_bytes * mult
        self.cross_pod_operand_bytes += other.cross_pod_operand_bytes * mult
        for k, v in other.collective_ops.items():
            self.collective_ops[k] = (self.collective_ops.get(k, 0)
                                      + int(v * mult))


def analyze(text: str, *, chips_per_pod: Optional[int] = None) -> HloStats:
    comps = parse_module(text)
    memo: Dict[str, HloStats] = {}

    def comp_types(c: Computation) -> Dict[str, str]:
        t = dict(c.params)
        for op in c.ops:
            t[op.name] = op.result_type
        return t

    def visit(name: str, stack=()) -> HloStats:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HloStats()
        c = comps[name]
        types = comp_types(c)
        st = HloStats()
        for op in c.ops:
            oc = op.opcode
            if oc == "while":
                trips = _trip_count(op, comps)
                bm = re.search(r"body=%([\w.\-]+)", op.attrs)
                if bm:
                    st.add(visit(bm.group(1), stack + (name,)), trips)
                continue
            if oc == "conditional":
                bm = re.findall(r"%([\w.\-]+)", op.attrs.split(
                    "branch_computations", 1)[-1].split("}", 1)[0])
                if bm:
                    subs = [visit(b, stack + (name,)) for b in bm]
                    best = max(subs, key=lambda s: s.dot_flops
                               + s.hbm_bytes)
                    st.add(best)
                continue
            if oc in ("fusion", "call", "async-start"):
                cm = re.search(r"(?:calls|to_apply)=%([\w.\-]+)", op.attrs)
                if cm:
                    sub = visit(cm.group(1), stack + (name,))
                    # only dot flops counted from inside fusions; bytes are
                    # accounted at the fusion call site below
                    only = HloStats(dot_flops=sub.dot_flops,
                                    collective_bytes=sub.collective_bytes,
                                    collective_operand_bytes=(
                                        sub.collective_operand_bytes),
                                    cross_pod_bytes=sub.cross_pod_bytes,
                                    cross_pod_operand_bytes=(
                                        sub.cross_pod_operand_bytes),
                                    collective_ops=sub.collective_ops)
                    st.add(only)
            if oc in ("dot", "convolution"):
                st.dot_flops += _dot_flops(op, types)
            if any(oc.startswith(k) for k in _COLLECTIVES):
                traffic = _collective_traffic(op, types)
                operand = sum(type_bytes(types.get(o, ""))
                              for o in op.operands)
                st.collective_bytes += traffic
                st.collective_operand_bytes += operand
                if chips_per_pod and _crosses_pod(op, chips_per_pod):
                    st.cross_pod_bytes += traffic
                    st.cross_pod_operand_bytes += operand
                k = oc.replace("-start", "")
                st.collective_ops[k] = st.collective_ops.get(k, 0) + 1
            if oc not in _FREE_OPS and not oc.endswith("-done"):
                st.hbm_bytes += type_bytes(op.result_type) + sum(
                    type_bytes(types.get(o, "")) for o in op.operands)
        memo[name] = st
        return st

    return visit("__entry__")


# ---------------------------------------------------------------------------
# slow-collective dependency chains (pipelinability)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SlowChain:
    """Data-dependency structure of the slow (cross-pod) collectives.

    ``max_depth`` is the length of the longest chain of slow collectives
    connected by data dependencies: 1 means every slow collective is
    independent of every other — the overlapped bucket schedule's
    pipelinability invariant (each bucket's slow hop can be in flight
    while other buckets' fast phases run).  ``dependent_pairs`` lists
    (ancestor, descendant) witnesses when the chain is deeper.
    """

    n_slow: int
    max_depth: int
    dependent_pairs: List[Tuple[str, str]]

    @property
    def independent(self) -> bool:
        return self.max_depth <= 1

    def to_dict(self):
        return {"n_slow": self.n_slow, "max_depth": self.max_depth,
                "independent": self.independent,
                "dependent_pairs": [list(p) for p in
                                    self.dependent_pairs[:16]]}


def slow_collective_chains(text: str, *, chips_per_pod: int) -> SlowChain:
    """Prove (or refute) slow-collective independence from lowered HLO.

    Walks the def-use graph of the module: every collective op whose
    replica groups cross the pod cut (``_crosses_pod``) becomes a node,
    and node B depends on node A when A is in the transitive operand
    cone of B.  Called computations (fusion/call/while bodies) are
    followed with parameter-index binding (``parameter(i)`` ops take the
    i-th call-operand's cone); ``-done`` halves of async pairs pass
    their cone through without counting again.  While bodies get one
    extra cone-propagation pass with the first pass's result folded
    into the carry (without re-registering the body's collectives), so
    the while op's consumers see cross-iteration reachability; chains
    *between iterations of the same while* are not claimed as depth —
    a trip-counted loop serializes its body regardless, and the flat
    (scan-free) sync schedules this checker gates contain no whiles.
    """
    comps = parse_module(text)
    depth: Dict[int, int] = {}
    names: Dict[int, str] = {}
    pairs: List[Tuple[str, str]] = []
    counter = iter(range(1 << 30))

    def called_comps(op: Op) -> List[str]:
        keys = ("calls", "to_apply", "body", "condition")
        out = []
        for k in keys:
            m = re.search(rf"\b{k}=%?([\w.\-]+)", op.attrs)
            if m and m.group(1) in comps:
                out.append(m.group(1))
        return out

    def register(op: Op, qual: str, cone: frozenset) -> frozenset:
        sid = next(counter)
        names[sid] = qual
        depth[sid] = 1 + max((depth[a] for a in cone), default=0)
        for a in sorted(cone):
            if len(pairs) < 64:
                pairs.append((names[a], qual))
        return cone | {sid}

    def visit(comp_name: str, param_cones: Tuple[frozenset, ...],
              stack: Tuple[str, ...], *,
              register_nodes: bool = True) -> frozenset:
        c = comps.get(comp_name)
        if c is None or comp_name in stack:
            return frozenset()
        cones: Dict[str, frozenset] = {}
        for pname, pc in zip(c.params, param_cones):
            cones[pname] = pc
        out = None
        for op in c.ops:
            if op.opcode == "parameter":
                # bind by parameter index: `%p = f32[..] parameter(i)`
                # re-declares a computation parameter as an op; its cone
                # is the matching call operand's, never empty
                idx = int(op.operands[0]) if (
                    op.operands and op.operands[0].isdigit()) else -1
                if 0 <= idx < len(param_cones):
                    cones[op.name] = param_cones[idx]
                if op.is_root or (out is None and op is c.ops[-1]):
                    out = cones.get(op.name, frozenset())
                continue
            cone = frozenset().union(
                *(cones.get(o, frozenset()) for o in op.operands)) \
                if op.operands else frozenset()
            subs = called_comps(op)
            if subs:
                sub_params = tuple(cones.get(o, frozenset())
                                   for o in op.operands)
                for sub in subs:
                    sub_cone = visit(sub, sub_params,
                                     stack + (comp_name,),
                                     register_nodes=register_nodes)
                    if op.opcode == "while":
                        # fold the first pass's result back into the
                        # carry so the while's consumers see
                        # cross-iteration reachability; propagation
                        # only — the body's collectives registered on
                        # the first pass
                        sub_cone = sub_cone | visit(
                            sub, tuple(pc | sub_cone
                                       for pc in sub_params),
                            stack + (comp_name,), register_nodes=False)
                    cone = cone | sub_cone
            oc = op.opcode
            if (register_nodes
                    and any(oc.startswith(k) for k in _COLLECTIVES)
                    and not oc.endswith("-done")
                    and chips_per_pod
                    and _crosses_pod(op, chips_per_pod)):
                cone = register(op, f"{comp_name}/{op.name}", cone)
            cones[op.name] = cone
            if op.is_root or (out is None and op is c.ops[-1]):
                out = cone
        return out if out is not None else frozenset()

    entry = comps.get("__entry__")
    if entry is not None:
        visit(entry.name, (frozenset(),) * len(entry.params), ())
    return SlowChain(n_slow=len(depth),
                     max_depth=max(depth.values(), default=0),
                     dependent_pairs=pairs)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12               # bf16 / chip (TPU v5e)
HBM_BW = 819e9                    # bytes/s / chip
ICI_BW = 50e9                     # bytes/s / link
DCN_BW_PER_CHIP = 6.25e9 / 4      # 50 Gb/s NIC per 4-chip host


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_device: float
    useful_ratio: float
    bound_s: float
    cross_pod_s: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline(stats: HloStats, *, n_chips: int,
             model_flops_global: float) -> Roofline:
    compute_s = stats.dot_flops / PEAK_FLOPS
    memory_s = stats.hbm_bytes / HBM_BW
    in_pod = stats.collective_bytes - stats.cross_pod_bytes
    cross_s = stats.cross_pod_bytes / DCN_BW_PER_CHIP
    coll_s = in_pod / ICI_BW + cross_s
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dom = max(terms, key=terms.get)
    useful = model_flops_global / max(stats.dot_flops * n_chips, 1e-9)
    return Roofline(compute_s, memory_s, coll_s, dom,
                    model_flops_global, stats.dot_flops, useful,
                    max(terms.values()), cross_pod_s=cross_s)
