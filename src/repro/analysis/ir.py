"""Shared HLO IR: one parser for every static-analysis consumer.

XLA prints two closely related textual dialects and this repo needs both:

- **post-optimization** (``compiled.as_text()``): ``%``-sigiled op names,
  full computation headers (``%comp (p: f32[4]) -> f32[4] {``), an
  ``input_output_alias={...}`` module attribute recording which entry
  parameters were actually donated into outputs, async collectives split
  into ``-start``/``-done`` pairs, ``while`` ops carrying
  ``known_trip_count`` backend configs.
- **pre-optimization** (``lowered.as_text("hlo")``): bare op names,
  header-less computations (params only exist as ``parameter(i)`` ops),
  a ``buffer_donor={...}`` module attribute recording which entry
  parameters the caller *offered* for donation, and ``opt-barrier`` ops
  that the backend consumes before the optimized print.

This module parses either into one :class:`Module` graph (computations,
ops, call edges with trip counts, async pairing, replica-group decoding,
donation/aliasing headers).  ``repro.analysis.hlo`` (roofline accounting,
slow-collective chains) and ``repro.analysis.lint`` (invariant rules)
both build on it — the parser is shared so a printer quirk gets fixed
once, not per checker.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

# ---------------------------------------------------------------------------
# types / bytes
# ---------------------------------------------------------------------------

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# dtypes wide enough for gradient/loss accumulation (the precision rule)
ACCUM_SAFE_DTYPES = frozenset({"f32", "f64", "s32", "u32", "s64", "u64"})

TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_PREFIXES = ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")

FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
            "bitcast", "after-all", "add-dependency", "partition-id",
            "replica-id", "iota"}


def type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in TYPE_RE.finditer(type_str):
        dt, shape = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if shape:
            for d in shape.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def type_dtypes(type_str: str) -> Tuple[str, ...]:
    """Element dtypes appearing in a (possibly tuple) HLO type string."""
    return tuple(m.group(1) for m in TYPE_RE.finditer(type_str)
                 if m.group(1) in DTYPE_BYTES)


# ---------------------------------------------------------------------------
# IR dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False

    @property
    def is_collective(self) -> bool:
        return any(self.opcode.startswith(k) for k in COLLECTIVE_PREFIXES)

    @property
    def is_async_start(self) -> bool:
        return self.opcode.endswith("-start")

    @property
    def is_async_done(self) -> bool:
        return self.opcode.endswith("-done")

    @property
    def collective_kind(self) -> Optional[str]:
        """Base collective kind with the async suffix stripped."""
        if not self.is_collective:
            return None
        k = self.opcode
        for suf in ("-start", "-done"):
            if k.endswith(suf):
                k = k[: -len(suf)]
        return k


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]             # header params (post-opt dialect)
    ops: List[Op]

    @property
    def root(self) -> Optional[Op]:
        for op in self.ops:
            if op.is_root:
                return op
        return self.ops[-1] if self.ops else None

    def op(self, name: str) -> Optional[Op]:
        for o in self.ops:
            if o.name == name:
                return o
        return None

    def result_types(self) -> Dict[str, str]:
        """name -> type for header params and every op result."""
        t = dict(self.params)
        for op in self.ops:
            t[op.name] = op.result_type
        return t


@dataclasses.dataclass
class AliasEntry:
    """One ``input_output_alias`` record: output buffer <- entry param."""

    output_index: Tuple[int, ...]
    param_number: int
    param_index: Tuple[int, ...]
    kind: str                          # "may-alias" | "must-alias"


@dataclasses.dataclass
class Module:
    """A parsed HLO module (either textual dialect)."""

    name: str
    header: str                        # the full HloModule line
    computations: Dict[str, Computation]
    entry_name: Optional[str]

    # -- structure ----------------------------------------------------------

    @property
    def entry(self) -> Optional[Computation]:
        if self.entry_name and self.entry_name in self.computations:
            return self.computations[self.entry_name]
        return None

    def ops(self) -> Iterator[Tuple[Computation, Op]]:
        for c in self.computations.values():
            for op in c.ops:
                yield c, op

    def called_computations(self, op: Op) -> List[str]:
        """Computation names an op calls (fusion/call/while/cond/async)."""
        out = []
        for key in ("calls", "to_apply", "body", "condition"):
            m = re.search(rf"\b{key}=%?([\w.\-]+)", op.attrs)
            if m and m.group(1) in self.computations:
                out.append(m.group(1))
        m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
        if m:
            for nm in re.findall(r"%?([\w.\-]+)", m.group(1)):
                if nm in self.computations:
                    out.append(nm)
        return out

    def apply_computation(self, op: Op) -> Optional[Computation]:
        """The reduction computation of a collective (``to_apply=``)."""
        m = re.search(r"\bto_apply=%?([\w.\-]+)", op.attrs)
        return self.computations.get(m.group(1)) if m else None

    def trip_count(self, op: Op) -> int:
        """Trip count of a ``while`` op (backend config, else cond consts)."""
        m = re.search(r'known_trip_count.*?"n":"(\d+)"', op.attrs)
        if m:
            return int(m.group(1))
        m = re.search(r"condition=%?([\w.\-]+)", op.attrs)
        if m and m.group(1) in self.computations:
            consts = [int(x) for x in re.findall(
                r"constant\((\d+)\)", "\n".join(
                    o.attrs + o.result_type
                    for o in self.computations[m.group(1)].ops))]
            if consts:
                return max(consts)
        return 1

    def async_pairs(self) -> Dict[str, str]:
        """``-start`` op name -> the ``-done`` op name consuming it.

        Pairing is by operand reference within the same computation — the
        printed form an async collective takes on backends that split it
        (``all-reduce-start``/``all-reduce-done``, ``all-gather-start``).
        """
        pairs: Dict[str, str] = {}
        for c in self.computations.values():
            starts = {op.name for op in c.ops if op.is_async_start}
            for op in c.ops:
                if op.is_async_done:
                    for o in op.operands:
                        if o in starts:
                            pairs[o] = op.name
        return pairs

    # -- module-header facts ------------------------------------------------

    def buffer_donors(self) -> Set[int]:
        """Entry-parameter numbers offered for donation (pre-opt header)."""
        body = _balanced_field(self.header, "buffer_donor=")
        if body is None:
            return set()
        return {int(m.group(1))
                for m in re.finditer(r"\((\d+),\s*\{[\d,\s]*\}\)", body)}

    def input_output_aliases(self) -> List[AliasEntry]:
        """Realized donation pairs (post-opt header)."""
        body = _balanced_field(self.header, "input_output_alias=")
        if body is None:
            return []
        out = []
        for m in re.finditer(
                r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\}"
                r"(?:,\s*([\w\-]+))?\)", body):
            out.append(AliasEntry(
                output_index=_int_tuple(m.group(1)),
                param_number=int(m.group(2)),
                param_index=_int_tuple(m.group(3)),
                kind=m.group(4) or "may-alias"))
        return out

    def aliased_param_numbers(self) -> Set[int]:
        return {a.param_number for a in self.input_output_aliases()}

    # -- call-graph walk ----------------------------------------------------

    def walk_entry(self) -> Iterator[Tuple[Computation, Op, float]]:
        """Yield (computation, op, multiplicity) reachable from the entry.

        Multiplicity multiplies through ``while`` trip counts; each called
        computation is visited per distinct call chain but cycles are cut.
        Conditional branches are all walked at multiplicity 1 (an upper
        bound — the lint rules care about what *can* execute).
        """
        if self.entry is None:
            return

        def visit(comp: Computation, mult: float,
                  stack: Tuple[str, ...]) -> Iterator:
            if comp.name in stack:
                return
            for op in comp.ops:
                yield comp, op, mult
                m = mult
                if op.opcode == "while":
                    m = mult * self.trip_count(op)
                for sub in self.called_computations(op):
                    if op.opcode == "while" and sub != _body_name(op):
                        # the condition runs trips+1 times but contains no
                        # accountable work; walk it once
                        yield from visit(self.computations[sub], mult,
                                         stack + (comp.name,))
                        continue
                    yield from visit(self.computations[sub], m,
                                     stack + (comp.name,))

        yield from visit(self.entry, 1.0, ())


def _body_name(op: Op) -> Optional[str]:
    m = re.search(r"\bbody=%?([\w.\-]+)", op.attrs)
    return m.group(1) if m else None


def _int_tuple(s: str) -> Tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x.strip())


def _balanced_field(header: str, key: str) -> Optional[str]:
    """Extract a ``key={...}`` module attribute with nested braces."""
    i = header.find(key)
    if i < 0:
        return None
    j = header.find("{", i)
    if j < 0:
        return None
    depth = 0
    for k in range(j, len(header)):
        if header[k] == "{":
            depth += 1
        elif header[k] == "}":
            depth -= 1
            if depth == 0:
                return header[j + 1:k]
    return None


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

_COMP_HEADER_FULL = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_COMP_HEADER_BARE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\{\s*$")
_OP_START = re.compile(r"^\s*(ROOT\s+)?%?[\w.\-]+\s*=\s*")


def _split_top(s: str) -> List[str]:
    """Split on top-level commas (outside (), [], {})."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _bracket_balance(line: str) -> int:
    """Net open-bracket count, ignoring bracket chars inside "..." strings
    (``metadata={op_name="jit(main)/..."}`` must not skew the balance)."""
    depth = 0
    in_str = False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        elif not in_str:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
    return depth


def _parse_operands(rest: str) -> Tuple[List[str], str]:
    """Split the operand list (to the matching close paren) from attrs."""
    depth = 1
    in_str = False
    for i, ch in enumerate(rest):
        if ch == '"':
            in_str = not in_str
        elif not in_str:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    inner, attrs = rest[:i], rest[i + 1:]
                    parts = [o.strip() for o in _split_top(inner)]
                    names = [o.split()[-1].lstrip("%")
                             for o in parts if o]
                    return names, attrs
    return [], rest


def _match_paren(s: str, start: int) -> int:
    """Index of the ``)`` matching the ``(`` at ``start`` (-1 if none)."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return -1


def parse_op_line(line: str) -> Optional[Op]:
    """Parse one (logical) op line in either dialect.

    Handles ``%``-sigiled and bare names, tuple result types with nested
    parens (``((f32[], f32[]), s32[])``), and attrs that were joined from
    printer-wrapped continuation lines.
    """
    s = line.strip()
    root = False
    if s.startswith("ROOT "):
        root = True
        s = s[5:].lstrip()
    m = re.match(r"%?([\w.\-]+)\s*=\s*", s)
    if not m:
        return None
    name = m.group(1)
    s = s[m.end():]
    if s.startswith("("):                      # tuple result type
        end = _match_paren(s, 0)
        if end < 0:
            return None
        rtype, s = s[:end + 1], s[end + 1:].lstrip()
        # layout suffix on the tuple, e.g. "(f32[2]{0})"
    else:
        sp = s.find(" ")
        if sp < 0:
            return None
        rtype, s = s[:sp], s[sp + 1:].lstrip()
    m = re.match(r"([\w\-]+)\(", s)
    if not m:
        return None
    opcode = m.group(1)
    operands, attrs = _parse_operands(s[m.end():])
    return Op(name=name, result_type=rtype, opcode=opcode,
              operands=operands, attrs=attrs.strip(), is_root=root)


def _logical_lines(text: str) -> Iterator[str]:
    """Join printer-wrapped op lines into single logical lines.

    An op whose attrs wrap (long ``replica_groups``, ``backend_config``)
    leaves the line with unbalanced brackets; continuation lines are
    appended until the balance closes.  Computation headers / closing
    braces are never merged.
    """
    pending: Optional[str] = None
    balance = 0
    for raw in text.splitlines():
        if pending is not None:
            pending += " " + raw.strip()
            balance += _bracket_balance(raw)
            if balance <= 0:
                yield pending
                pending = None
            continue
        stripped = raw.strip()
        if _OP_START.match(raw):
            b = _bracket_balance(raw)
            if b > 0:
                pending = stripped
                balance = b
                continue
        yield raw


def parse(text: str) -> Module:
    """Parse an HLO module in either textual dialect into a :class:`Module`."""
    header = ""
    name = ""
    comps: Dict[str, Computation] = {}
    entry_name: Optional[str] = None
    cur: Optional[Computation] = None
    for line in _logical_lines(text):
        stripped = line.strip()
        if not header and stripped.startswith("HloModule"):
            header = stripped
            m = re.match(r"HloModule\s+([\w.\-]+)", stripped)
            name = m.group(1) if m else ""
            continue
        if cur is None:
            m = _COMP_HEADER_FULL.match(stripped)
            if m:
                params = {}
                for p in _split_top(m.group(3)):
                    p = p.strip()
                    if ":" in p:
                        nm, ty = p.split(":", 1)
                        params[nm.strip().lstrip("%")] = ty.strip()
                cur = Computation(m.group(2), params, [])
                if m.group(1):
                    entry_name = m.group(2)
                continue
            m = _COMP_HEADER_BARE.match(stripped)
            if m and not _OP_START.match(line):
                cur = Computation(m.group(2), {}, [])
                if m.group(1):
                    entry_name = m.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = parse_op_line(line)
        if op is not None:
            cur.ops.append(op)
    if cur is not None:                        # unterminated tail
        comps[cur.name] = cur
    return Module(name=name, header=header, computations=comps,
                  entry_name=entry_name)


def parse_module(text: str) -> Dict[str, Computation]:
    """Legacy view: computation dict with an ``__entry__`` alias.

    The pre-IR interface of ``repro.analysis.hlo.parse_module``; kept so
    existing accounting code and tests keep working unchanged.
    """
    mod = parse(text)
    comps = dict(mod.computations)
    if mod.entry is not None:
        comps["__entry__"] = mod.entry
    return comps


# ---------------------------------------------------------------------------
# replica groups / pod-cut classification
# ---------------------------------------------------------------------------

def parse_replica_groups(attrs: str) -> Optional[List[List[int]]]:
    """Decode ``replica_groups`` in iota (``[2,4]<=[8]`` / ``...T(1,0)``)
    or explicit (``{{0,1},{2,3}}``) form into device-id groups."""
    m = re.search(
        r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?",
        attrs)
    if m:
        out_dims = [int(x) for x in m.group(1).split(",")]
        in_dims = [int(x) for x in m.group(2).split(",")]
        n = 1
        for d in in_dims:
            n *= d
        ids = list(range(n))
        if m.group(4):            # transpose of the reshaped iota
            perm = [int(x) for x in m.group(4).split(",")]
            import numpy as _np
            ids = list(_np.arange(n).reshape(in_dims).transpose(
                perm).reshape(-1))
        rows, cols = out_dims[0], out_dims[1] if len(out_dims) > 1 else 1
        return [[int(ids[r * cols + c]) for c in range(cols)]
                for r in range(rows)]
    m = re.search(r"replica_groups=\{(\{[^=]*?\})\}", attrs)
    if m:
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in re.findall(r"\{([\d,\s]*)\}", m.group(1))]
    return None


def crosses_pod(op: Op, chips_per_pod: int) -> bool:
    """Whether a collective's groups span the pod cut (slow tier)."""
    if op.opcode.startswith("collective-permute"):
        pairs = re.findall(r"\{(\d+),(\d+)\}", op.attrs)
        return any(int(a) // chips_per_pod != int(b) // chips_per_pod
                   for a, b in pairs)
    groups = parse_replica_groups(op.attrs)
    if groups is None:
        return True               # conservatively cross-pod
    for g in groups:
        pods = {d // chips_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False
