"""HLO invariant linter: machine-checked collective/determinism/donation
/precision contracts for the train-step matrix.

Every rule here encodes a bug this repo actually shipped and debugged by
hand (see ``rules.py`` docstrings for the history).  ``scripts/lint_hlo.py``
lowers the canonical ``cross_pod_mode x overlap x det x zero1`` matrix and
runs all rules against ``analysis/budgets.json``; CI fails on any finding.
"""
from repro.analysis.lint.core import (Finding, LintContext, all_rules,
                                      budget_for, load_budgets, rule,
                                      run_rules)
from repro.analysis.lint import rules as _rules  # noqa: F401  (registers)

__all__ = ["Finding", "LintContext", "all_rules", "budget_for",
           "load_budgets", "rule", "run_rules"]
