"""Built-in lint rules: each one is a bug this repo already shipped.

- ``collective-budget``   — PR 2's zero1 forward double-gathered masters
  *and* params (2x gather traffic, found by eyeballing profiles).
- ``deterministic-reduce``— PR 4's missing ``optimization_barrier`` let
  XLA refold the pinned reduction tree: 1-ulp drift across mesh
  factorizations, breaking bitwise elastic continuation.
- ``donation-aliasing``   — PR 4's ``init_bucketed`` master buckets
  aliased the param buffers they were initialized from; donation then
  silently dropped and peak memory doubled.
- ``precision``           — grad/loss accumulation must stay f32+; bf16
  is only legal on the declared compressed slow hop
  (``slow_compress_bits=16``).
- ``overlap-independence``— the overlapped bucket schedule is only
  legal when slow collectives are data-independent (PR 3's
  pipelinability invariant, previously checked ad hoc in benchmarks).
"""
from __future__ import annotations

from typing import Callable, List

from repro.analysis import hlo, ir
from repro.analysis.lint.core import Finding, LintContext, rule

_REDUCTIONS = ("all-reduce", "reduce-scatter")


def _is_reduction(op: ir.Op) -> bool:
    return op.collective_kind in _REDUCTIONS and not op.is_async_done


def _has_add_apply(mod: ir.Module, op: ir.Op) -> bool:
    ap = mod.apply_computation(op)
    return ap is not None and any(o.opcode == "add" for o in ap.ops)


def _operand_cone_contains(mod: ir.Module, comp: ir.Computation,
                           op: ir.Op,
                           pred: Callable[[ir.Op], bool]) -> bool:
    """True if ``pred`` holds anywhere in ``op``'s transitive operand
    cone (within ``comp``, descending into called computations)."""
    name2op = {o.name: o for o in comp.ops}
    seen = set()
    stack = list(op.operands)
    while stack:
        nm = stack.pop()
        if nm in seen:
            continue
        seen.add(nm)
        o = name2op.get(nm)
        if o is None:
            continue
        if pred(o):
            return True
        stack.extend(o.operands)
        for sub in mod.called_computations(o):
            sc = mod.computations.get(sub)
            if sc is not None and any(pred(so) for so in sc.ops):
                return True
    return False


# ---------------------------------------------------------------------------
# collective-budget
# ---------------------------------------------------------------------------

@rule("collective-budget")
def collective_budget(ctx: LintContext) -> List[Finding]:
    """Trip-weighted per-step collective counts must match the declared
    budget exactly, and total collective payload must stay under the
    declared multiple of the gradient bytes (the full-gather tripwire:
    an accidental param/master gather roughly doubles the payload)."""
    if not ctx.budget:
        return []
    stats = hlo.analyze(ctx.optimized, chips_per_pod=ctx.chips_per_pod)
    nb = ctx.n_buckets
    fixed = {k: int(v) for k, v in ctx.budget.get("fixed", {}).items()}
    per_bucket = {k: int(v)
                  for k, v in ctx.budget.get("per_bucket", {}).items()}
    expected = dict(fixed)
    for k, v in per_bucket.items():
        expected[k] = expected.get(k, 0) + v * nb
    findings: List[Finding] = []
    lines = []
    for k in sorted(set(expected) | set(stats.collective_ops)):
        want = expected.get(k, 0)
        got = stats.collective_ops.get(k, 0)
        if want == got:
            continue
        parts = []
        if fixed.get(k):
            parts.append(str(fixed[k]))
        if per_bucket.get(k):
            parts.append(f"{per_bucket[k]}/bucket x {nb}")
        detail = f" ({' + '.join(parts)})" if parts else ""
        lines.append(f"  {k}: budget {want}{detail}, got {got} "
                     f"({got - want:+d})")
    if lines:
        findings.append(Finding(
            "collective-budget", "error",
            "per-step collective counts drifted from "
            "analysis/budgets.json:\n" + "\n".join(lines)))
    factor = ctx.budget.get("max_operand_bytes_factor")
    grad_bytes = ctx.config.get("grad_bytes")
    if factor and grad_bytes:
        limit = float(factor) * float(grad_bytes)
        if stats.collective_operand_bytes > limit:
            findings.append(Finding(
                "collective-budget", "error",
                f"collective payload "
                f"{stats.collective_operand_bytes / 2**20:.1f} MiB exceeds "
                f"{factor}x grad bytes "
                f"({limit / 2**20:.1f} MiB) — an undeclared full gather "
                f"of params/masters is the usual culprit"))
    return findings


# ---------------------------------------------------------------------------
# deterministic-reduce
# ---------------------------------------------------------------------------

@rule("deterministic-reduce")
def deterministic_reduce(ctx: LintContext) -> List[Finding]:
    """``deterministic_reduce=True`` programs may contain **no** raw
    cross-replica reduction: every reduction is the pinned
    all-gather + fixed-tree fold, and the fold is sealed behind an
    ``optimization_barrier`` so XLA cannot refold it (the barrier only
    exists in the pre-optimization print — the backend consumes it)."""
    if not ctx.config.get("deterministic_reduce"):
        return []
    findings: List[Finding] = []
    for comp, op in ctx.optimized.ops():
        if _is_reduction(op):
            findings.append(Finding(
                "deterministic-reduce", "error",
                f"raw {op.collective_kind} in a deterministic program: "
                f"its reduction order follows the mesh factorization, "
                f"breaking bitwise elastic continuation (must be the "
                f"pinned all-gather + tree fold)",
                op=op.name, computation=comp.name))
    if ctx.lowered is not None:
        barriers = [(c, o) for c, o in ctx.lowered.ops()
                    if o.opcode == "opt-barrier"]
        if not barriers:
            findings.append(Finding(
                "deterministic-reduce", "error",
                "no optimization_barrier in the lowered program: the "
                "tree fold is unsealed and XLA may refold it "
                "(the PR 4 1-ulp drift)"))
        elif not any(_operand_cone_contains(
                ctx.lowered, c, o,
                lambda x: x.collective_kind == "all-gather")
                for c, o in barriers):
            findings.append(Finding(
                "deterministic-reduce", "error",
                "optimization_barrier present but no all-gather feeds "
                "it — the gathered tree fold is not the value being "
                "sealed", op=barriers[0][1].name,
                computation=barriers[0][0].name))
    return findings


# ---------------------------------------------------------------------------
# donation-aliasing
# ---------------------------------------------------------------------------

@rule("donation-aliasing")
def donation_aliasing(ctx: LintContext) -> List[Finding]:
    """Every entry parameter offered for donation (pre-opt
    ``buffer_donor``) must be realized as an ``input_output_alias``
    entry post-opt; a dropped donation means a live use pinned the
    buffer and peak memory grows by that buffer (PR 4's
    ``init_bucketed`` masters aliasing the params they were initialized
    from).  A parameter aliased into two outputs is corrupt either way."""
    donors = set(ctx.config.get("donated_params") or [])
    if ctx.lowered is not None:
        donors |= ctx.lowered.buffer_donors()
    entries = ctx.optimized.input_output_aliases()
    findings: List[Finding] = []
    if donors:
        aliased = {e.param_number for e in entries}
        for p in sorted(donors - aliased):
            findings.append(Finding(
                "donation-aliasing", "error",
                f"donated entry parameter {p} escapes unaliased: no "
                f"input_output_alias entry reuses its buffer, so the "
                f"donation was silently dropped (a live use of the "
                f"donated value keeps the old buffer alive)"))
    seen = {}
    for e in entries:
        key = (e.param_number, e.param_index)
        if key in seen:
            findings.append(Finding(
                "donation-aliasing", "error",
                f"entry parameter {e.param_number} (index "
                f"{list(e.param_index)}) is aliased into two outputs "
                f"{list(seen[key])} and {list(e.output_index)} — one of "
                f"them reads freed memory"))
        seen[key] = e.output_index
    return findings


# ---------------------------------------------------------------------------
# precision
# ---------------------------------------------------------------------------

@rule("precision")
def precision(ctx: LintContext) -> List[Finding]:
    """No sub-f32 additive accumulation on cross-replica reduction
    paths.  The single declared exception: ``slow_compress_bits=16``
    intentionally runs the *cross-pod* hop in bf16 (int8 compression
    never trips this — its slow hop is an all-gather + local f32
    dequant-mean, not a reduction)."""
    bits = int(ctx.config.get("slow_compress_bits") or 0)
    cpp = ctx.chips_per_pod
    findings: List[Finding] = []
    for comp, op in ctx.optimized.ops():
        if not _is_reduction(op):
            continue
        if not _has_add_apply(ctx.optimized, op):
            continue                   # min/max/and reductions: not accum
        bad = sorted(set(d for d in ir.type_dtypes(op.result_type)
                         if d not in ir.ACCUM_SAFE_DTYPES))
        if not bad:
            continue
        if bits == 16 and cpp and ir.crosses_pod(op, cpp):
            continue                   # declared bf16 compressed slow hop
        findings.append(Finding(
            "precision", "error",
            f"{op.collective_kind} accumulates in {'/'.join(bad)}: "
            f"grad/loss reduction paths must accumulate in f32 or wider "
            f"(bf16 is only legal on the slow hop when "
            f"slow_compress_bits=16 declares it)",
            op=op.name, computation=comp.name))
    return findings


# ---------------------------------------------------------------------------
# overlap-independence
# ---------------------------------------------------------------------------

@rule("overlap-independence")
def overlap_independence(ctx: LintContext) -> List[Finding]:
    """``overlap=True`` promises bucket i+1's fast phase runs under
    bucket i's slow hop — only sound when no slow collective consumes
    another's result.  Rule-ified ``hlo.slow_collective_chains``."""
    if not ctx.config.get("overlap"):
        return []
    cpp = ctx.chips_per_pod
    if not cpp:
        return []
    ch = hlo.slow_collective_chains(ctx.optimized, chips_per_pod=cpp)
    findings: List[Finding] = []
    if not ch.independent:
        for a, b in ch.dependent_pairs[:8]:
            findings.append(Finding(
                "overlap-independence", "error",
                f"slow collective {b} consumes {a}'s result (max chain "
                f"depth {ch.max_depth}): the overlapped bucket schedule "
                f"cannot pipeline a dependent slow hop", op=b))
    if ch.n_slow == 0:
        findings.append(Finding(
            "overlap-independence", "warning",
            "overlap=True but the program has no cross-pod collectives "
            "— nothing to overlap (chips_per_pod misdeclared, or the "
            "mesh has no slow axis)"))
    return findings
