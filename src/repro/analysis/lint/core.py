"""Lint-rule registry over the shared HLO IR.

A rule is a function ``(LintContext) -> List[Finding]`` registered under a
stable id with the :func:`rule` decorator.  The context carries both
textual dialects of one lowered train step — the **post-optimization**
module (``compiled.as_text()``: realized aliasing, scheduled collectives)
and the **pre-optimization** module (``lowered.as_text("hlo")``: donation
offers in ``buffer_donor``, ``opt-barrier`` ops the backend later
consumes) — because no single print carries every contract.

Budgets (expected collective counts per mode) live in the versioned
``analysis/budgets.json`` next to this package; see :func:`load_budgets`.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, List, Optional

from repro.analysis import ir

SEVERITIES = ("error", "warning")


@dataclasses.dataclass
class Finding:
    """One rule violation, locatable to an op when the rule has one."""

    rule: str
    severity: str                      # "error" | "warning"
    message: str
    op: Optional[str] = None
    computation: Optional[str] = None

    def format(self) -> str:
        loc = ""
        if self.computation or self.op:
            loc = " [%s%s]" % (self.computation or "",
                               ("/" + self.op) if self.op else "")
        return f"{self.rule} ({self.severity}){loc}: {self.message}"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LintContext:
    """Everything a rule may inspect for one train-step program.

    ``config`` mirrors the ``make_train_step`` arguments that shape the
    program, plus derived facts the rules normalize against::

        cross_pod_mode, overlap, deterministic_reduce, zero1,
        slow_compress_bits, n_buckets, chips_per_pod, grad_bytes
    """

    optimized: ir.Module               # compiled.as_text()
    lowered: Optional[ir.Module] = None  # lowered.as_text("hlo")
    config: Dict[str, Any] = dataclasses.field(default_factory=dict)
    budget: Optional[Dict[str, Any]] = None

    @property
    def chips_per_pod(self) -> Optional[int]:
        v = self.config.get("chips_per_pod")
        return int(v) if v else None

    @property
    def n_buckets(self) -> int:
        return int(self.config.get("n_buckets") or 0)


RuleFn = Callable[[LintContext], List[Finding]]
_RULES: Dict[str, RuleFn] = {}


def rule(rule_id: str) -> Callable[[RuleFn], RuleFn]:
    """Register a lint rule under a stable id (used in findings and
    ``--only`` filters); re-registration replaces (reload-friendly)."""
    def deco(fn: RuleFn) -> RuleFn:
        fn.rule_id = rule_id           # type: ignore[attr-defined]
        _RULES[rule_id] = fn
        return fn
    return deco


def all_rules() -> Dict[str, RuleFn]:
    return dict(_RULES)


def run_rules(ctx: LintContext,
              only: Optional[List[str]] = None) -> List[Finding]:
    """Run every registered rule (or the ``only`` subset) in id order."""
    if only is not None:
        unknown = sorted(set(only) - set(_RULES))
        if unknown:
            raise KeyError(f"unknown lint rules {unknown}; "
                           f"known: {sorted(_RULES)}")
    out: List[Finding] = []
    for rid in sorted(_RULES):
        if only is not None and rid not in only:
            continue
        out.extend(_RULES[rid](ctx))
    return out


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------

BUDGETS_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "budgets.json")


def load_budgets(path: Optional[str] = None) -> Dict[str, Any]:
    with open(path or BUDGETS_PATH) as f:
        budgets = json.load(f)
    if budgets.get("version") != 1:
        raise ValueError(
            f"unsupported budgets.json version {budgets.get('version')!r}")
    return budgets


def budget_for(budgets: Dict[str, Any],
               cell: str) -> Optional[Dict[str, Any]]:
    """The budget declaration for one matrix cell (None if undeclared)."""
    return budgets.get("cells", {}).get(cell)
