"""Deterministic synthetic LM data pipeline.

Zipf-distributed token streams with document packing; per-host sharded
loading (each data-parallel host materializes only its shard) and a
background prefetch thread — the substrate a real cluster run would swap
for a tokenized corpus reader.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.2
    mean_doc_len: int = 256
    eos_id: int = 0
    seed: int = 1234


class SyntheticCorpus:
    """Deterministic (seed, step, shard) -> batch generator."""

    def __init__(self, cfg: DataConfig, *, shard: int = 0,
                 n_shards: int = 1):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.local_batch = cfg.global_batch // n_shards

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.shard, 0xC0FFEE))
        n_tok = self.local_batch * (cfg.seq_len + 1)
        toks = rng.zipf(cfg.zipf_a, size=n_tok).astype(np.int64)
        toks = (toks % (cfg.vocab_size - 1)) + 1        # reserve 0 for EOS
        # document packing: EOS every ~mean_doc_len tokens
        doc_ends = rng.geometric(1.0 / cfg.mean_doc_len, size=n_tok // 16)
        pos = np.cumsum(doc_ends)
        pos = pos[pos < n_tok]
        toks[pos] = cfg.eos_id
        toks = toks.reshape(self.local_batch, cfg.seq_len + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with a bounded queue."""

    def __init__(self, corpus: SyntheticCorpus, depth: int = 2,
                 start_step: int = 0):
        self.corpus = corpus
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.corpus.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> Tuple[int, Dict[str, np.ndarray]]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
