"""Sharded checkpoint save/restore with drain-free reshard-on-restore.

Save side: every leaf whose ``jax.Array`` sharding is not fully
replicated is written as one ``.npy`` *per distinct shard* — each rank
persists only the slice it already holds (for a ZeRO-1 state that is the
1/F bucket shard; no rank ever gathers a full bucket).  Fully-replicated
leaves (params, the step counter) are written once.  Files land in a
temp directory (``<dir>.tmp-<pid>``), the manifest is written last, and
the directory is atomically renamed into place — a crash at any point
leaves either the previous committed step or an ignorable torn dir.

Restore side: the target mesh and shardings are the *restorer's*; the
saved mesh shape is irrelevant.  Each target shard is assembled from the
intersecting saved shard boxes (``jax.make_array_from_callback`` — every
device materializes only its own slice).  Restoring onto a different
(pod, data) factorization is therefore pure offset arithmetic over the
manifest's index boxes.  When a flat bucket's *padded* size differs
(bucket alignment follows the fast-axis size), the ``pad_flat`` policy
copies the common prefix and zero-fills the tail — exact, because
everything past the layout's live prefix is zeros on both sides.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

from repro.checkpoint import CorruptCheckpointError, _WriterThread
from repro.ckpt import manifest as mf
from repro.ckpt.treepaths import leaf_paths, rebuild, sanitize
from repro.faults.plan import maybe_fire
from repro.faults.retry import NO_RETRY, RetryPolicy

# restore policies (per leaf, via a same-structure policy tree):
EXACT = "exact"          # shapes must match the manifest (default)
PAD_FLAT = "pad_flat"    # 1-D flat resize: copy common prefix, zero tail
ZERO = "zero"            # shape mismatch / missing leaf -> fresh zeros


def _norm_index(index, shape) -> Tuple[Tuple[int, int], ...]:
    """Normalize a tuple of slices into per-dim (start, stop)."""
    out = []
    for sl, dim in zip(index, shape):
        start, stop, step = sl.indices(dim)
        assert step == 1, f"strided shard index {sl}"
        out.append((start, stop))
    # scalar leaves have an empty index
    return tuple(out)


def _box_shape(box) -> Tuple[int, ...]:
    return tuple(b - a for a, b in box)


# rename-protocol debris: in-flight temp dirs and moved-aside old commits,
# both tagged with the writing pid.  Quarantined dirs (".quarantined-*")
# deliberately do NOT match — they are evidence, not garbage.
_DEBRIS_RE = re.compile(r"^step_\d+\.(?:tmp|old)-(\d+)$")


def _pid_alive(pid: int) -> bool:
    if pid == os.getpid():
        return True
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except OSError:
        return True        # exists but not ours / indeterminate: keep it
    return True


def gc_debris(base_dir: str) -> list:
    """Remove rename-protocol leftovers whose writer is dead.

    A crash between the same-step rename-aside and the commit rename
    strands a ``.old-<pid>`` dir forever (its name fails the committed
    regex, so nothing ever looks at it again); a crash mid-write strands
    ``.tmp-<pid>``.  Each successful save sweeps its base dir for such
    debris from *dead* pids — a live pid may be another writer mid-save
    on a shared filesystem, so its dirs are left alone.  Returns the
    paths removed.
    """
    try:
        names = os.listdir(base_dir)
    except OSError:
        return []
    removed = []
    for name in sorted(names):
        m = _DEBRIS_RE.match(name)
        if not m or _pid_alive(int(m.group(1))):
            continue
        path = os.path.join(base_dir, name)
        shutil.rmtree(path, ignore_errors=True)
        removed.append(path)
    return removed


def save_sharded(ckpt_dir: str, step: int, tree, *, layout=None,
                 mesh=None, blocking: bool = True,
                 retry: RetryPolicy = NO_RETRY
                 ) -> Optional[threading.Thread]:
    """Save ``tree`` in the sharded per-rank format.

    ``layout`` (a ``bucketing.BucketLayout``) is recorded in the manifest
    for reshard bookkeeping; ``mesh`` records provenance.  With
    ``blocking=False`` the device->host copies happen synchronously but
    file writes run on the returned daemon thread (join it before the
    next save).  ``retry`` bounds transient-I/O retries: the whole write
    protocol is idempotent up to the commit rename (the temp dir is
    rebuilt from the already-captured host arrays), so a retried attempt
    restarts it from scratch.

    Single-process note: every addressable shard is written by this
    process; in a true multi-host deployment each host writes the shards
    it owns and rank 0 writes the replicated leaves + manifest — the
    format (per-shard files keyed by global index boxes) is already
    host-local.
    """
    flat = leaf_paths(tree)
    entries: Dict[str, mf.LeafEntry] = {}
    payload = []                               # (fname, np.ndarray)
    for key, leaf in flat.items():
        if leaf is None:
            continue
        stem = sanitize(key)
        sharding = getattr(leaf, "sharding", None)
        if (isinstance(leaf, jax.Array) and sharding is not None
                and not sharding.is_fully_replicated):
            seen: Dict[Tuple, np.ndarray] = {}
            for s in leaf.addressable_shards:
                box = _norm_index(s.index, leaf.shape)
                if box not in seen:
                    seen[box] = np.asarray(s.data)
            vol = sum(int(np.prod(_box_shape(b))) for b in seen)
            if vol != int(np.prod(leaf.shape)):
                raise ValueError(
                    f"shards of {key} cover {vol} of "
                    f"{int(np.prod(leaf.shape))} elements — "
                    f"non-addressable or overlapping sharding")
            shards = []
            for j, (box, arr) in enumerate(sorted(seen.items())):
                fname = f"{stem}.s{j}.npy"
                payload.append((fname, arr))
                shards.append(mf.ShardFile(
                    file=fname, index=box,
                    crc32=zlib.crc32(arr.tobytes()) & 0xffffffff))
            try:
                spec = tuple(sharding.spec)
            except AttributeError:
                spec = ()
            entries[key] = mf.LeafEntry(
                kind="sharded", shape=tuple(leaf.shape),
                dtype=str(leaf.dtype), shards=tuple(shards), spec=spec)
        else:
            arr = np.asarray(jax.device_get(leaf))
            fname = stem + ".npy"
            payload.append((fname, arr))
            entries[key] = mf.LeafEntry(
                kind="replicated", shape=tuple(arr.shape),
                dtype=str(arr.dtype), file=fname,
                crc32=zlib.crc32(arr.tobytes()) & 0xffffffff)

    man = mf.Manifest(step=step, leaves=entries,
                      mesh=mf.mesh_to_dict(mesh),
                      layout=mf.layout_to_dict(layout))
    tmp = f"{ckpt_dir}.tmp-{os.getpid()}"
    old = f"{ckpt_dir}.old-{os.getpid()}"

    def write_once():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for fname, arr in payload:
            fpath = os.path.join(tmp, fname)
            maybe_fire("sharded.write")
            np.save(fpath, arr)
            maybe_fire("sharded.written", path=fpath)
        man_path = os.path.join(tmp, mf.MANIFEST)
        with open(man_path, "w") as f:
            f.write(man.to_json())               # commit marker, last
        maybe_fire("sharded.manifest", path=man_path)
        if os.path.exists(ckpt_dir):
            # re-save of the same step: move the old commit ASIDE, never
            # rmtree it pre-commit — deleting first would leave a crash
            # window in which the only committed checkpoint is destroyed
            # irrecoverably.  A crash between the two renames still
            # hides this step from latest_step (the .old-* name fails
            # its regex, resume falls back to an earlier step); the
            # bytes survive on disk until the next successful save's
            # debris sweep (gc_debris) collects them.
            maybe_fire("sharded.pre_rename_aside")
            if os.path.exists(old):
                shutil.rmtree(old)
            os.rename(ckpt_dir, old)
            maybe_fire("sharded.between_renames")
        os.rename(tmp, ckpt_dir)                 # atomic commit
        maybe_fire("sharded.committed")
        shutil.rmtree(old, ignore_errors=True)
        gc_debris(os.path.dirname(ckpt_dir) or ".")

    def write():
        retry.call(write_once)

    if blocking:
        write()
        return None
    t = _WriterThread(write)
    t.start()
    return t


class ShardedCheckpoint:
    """Reader for one committed sharded checkpoint directory."""

    def __init__(self, ckpt_dir: str, *, verify: bool = True,
                 retry: RetryPolicy = NO_RETRY):
        self.dir = ckpt_dir
        self.manifest = mf.read_manifest(ckpt_dir)
        self.verify = verify
        self.retry = retry
        # restore walks target shards in order, so consecutive reads
        # usually hit the same saved file: keep exactly one file hot (a
        # full cache would hold the whole state in host RAM, the thing
        # the sharded format exists to avoid) and remember which files
        # already passed their checksum so CRC work happens once per
        # file, not once per intersecting target shard
        self._hot: Tuple[Optional[str], Optional[np.ndarray]] = (None,
                                                                 None)
        self._verified: set = set()

    @property
    def step(self) -> int:
        return self.manifest.step

    def _load_file(self, fname: str, crc: Optional[int],
                   dtype: np.dtype) -> np.ndarray:
        if self._hot[0] == fname:
            return self._hot[1]
        fpath = os.path.join(self.dir, fname)

        def load():
            maybe_fire("sharded.read", path=fpath)
            return np.load(fpath)

        arr = self.retry.call(load)
        if arr.dtype != dtype:        # np.save round-trips bf16 as void16
            arr = arr.view(dtype)
        if (self.verify and crc is not None
                and fname not in self._verified):
            got = zlib.crc32(arr.tobytes()) & 0xffffffff
            if got != crc:
                raise CorruptCheckpointError(
                    f"checksum mismatch for {fname}")
            self._verified.add(fname)
        self._hot = (fname, arr)
        return arr

    def read_box(self, path: str, box) -> np.ndarray:
        """Assemble the global index ``box`` of leaf ``path`` from the
        intersecting saved shard files.

        Never materializes more than the requested box plus one saved
        shard at a time — the reshard-on-restore memory guarantee.
        Coordinates past the saved extent are zero-filled (the flat
        bucket padding rule); entirely out-of-range boxes are all zeros.
        """
        entry = self.manifest.leaves[path]
        dtype = np.dtype(entry.dtype)
        box = tuple(box)
        out = np.zeros(_box_shape(box), dtype=dtype)
        # a replicated leaf is just one saved box covering the whole
        # array — the same intersection arithmetic serves both kinds
        shards = entry.shards or (mf.ShardFile(
            file=entry.file, index=tuple((0, d) for d in entry.shape),
            crc32=entry.crc32),)
        for sf in shards:
            inter = tuple((max(a, c), min(b, d))
                          for (a, b), (c, d) in zip(box, sf.index))
            if any(a >= b for a, b in inter):
                continue
            arr = self._load_file(sf.file, sf.crc32, dtype)
            src = tuple(slice(a - c, b - c)
                        for (a, b), (c, _) in zip(inter, sf.index))
            dst = tuple(slice(a - c, b - c)
                        for (a, b), (c, _) in zip(inter, box))
            out[dst] = arr[src]
        return out

    def read_leaf(self, path: str) -> np.ndarray:
        entry = self.manifest.leaves[path]
        return self.read_box(path, tuple((0, d) for d in entry.shape))

    def restore(self, template, *, shardings=None, policy=None,
                layout=None) -> Tuple[int, Any]:
        """Restore into ``template``'s structure; returns (step, tree).

        ``shardings``: same-structure tree of ``NamedSharding``s — leaves
        with one are assembled per-device via
        ``jax.make_array_from_callback`` (each device reads only its own
        box).  ``policy``: same-structure tree of
        EXACT / PAD_FLAT / ZERO strings controlling shape-mismatch
        behavior; default EXACT everywhere.  ``layout``: the restorer's
        ``BucketLayout`` — validated against the manifest's recorded
        slot placement, which PAD_FLAT correctness depends on.
        """
        if layout is not None and self.manifest.layout is None:
            raise CorruptCheckpointError(
                "layout validation requested but the checkpoint's "
                "manifest records no bucket layout (saved with "
                "layout=None) — cannot prove the leaf->bucket placement "
                "matches; restore without `layout` only if you know the "
                "placement is unchanged")
        if layout is not None:
            # PAD_FLAT's copy-prefix rule is only exact when the leaf ->
            # (bucket, offset) placement is unchanged; placement is
            # alignment-invariant but NOT bucket_bytes-invariant.  A
            # restore with a different bucket capacity would silently
            # scramble masters across bucket boundaries — refuse it.
            tgt = [(s.bucket, s.offset, s.size) for s in layout.slots]
            sav = [(int(s["bucket"]), int(s["offset"]), int(s["size"]))
                   for s in self.manifest.layout["slots"]]
            if tgt != sav:
                raise CorruptCheckpointError(
                    f"bucket layout mismatch: checkpoint was saved with "
                    f"a different leaf->bucket placement "
                    f"({len(sav)} slots over "
                    f"{len(self.manifest.layout['bucket_sizes'])} "
                    f"buckets vs {len(tgt)} slots over "
                    f"{layout.n_buckets}) — restore with the same "
                    f"bucket_bytes the checkpoint was trained with")
        flat_t = leaf_paths(template)
        flat_s = leaf_paths(shardings) if shardings is not None else {}
        flat_p = leaf_paths(policy) if policy is not None else {}

        def zeros(shape, dtype, sh):
            # ZERO-policy leaves must honor the target sharding too: a
            # plain jnp.zeros would materialize the full (possibly
            # GB-scale residual) array replicated on one device —
            # breaking the no-full-materialization guarantee on exactly
            # the elastic-restore path it protects
            if sh is None:
                return jax.numpy.zeros(shape, dtype)
            return jax.make_array_from_callback(
                shape, sh,
                lambda index: np.zeros(
                    _box_shape(_norm_index(index, shape)), dtype))

        out: Dict[str, Any] = {}
        for key, leaf in flat_t.items():
            if leaf is None:
                out[key] = None
                continue
            pol = flat_p.get(key, EXACT)
            entry = self.manifest.leaves.get(key)
            # templates may hold raw Python scalars (save coerced them
            # via np.asarray); np.shape/np.result_type handle both
            want_shape = tuple(np.shape(leaf))
            want_dtype = (str(leaf.dtype) if hasattr(leaf, "dtype")
                          else None)
            leaf_dtype = getattr(leaf, "dtype", None)
            if leaf_dtype is None:
                leaf_dtype = np.asarray(leaf).dtype
            if entry is None:
                if pol == ZERO:
                    out[key] = zeros(want_shape, leaf_dtype,
                                     flat_s.get(key))
                    continue
                raise CorruptCheckpointError(f"missing leaf {key}")
            if want_dtype is not None and entry.dtype != want_dtype:
                # a silent dtype swap would retrace the step at the
                # checkpoint's precision, not the configured one
                if pol == ZERO:
                    out[key] = zeros(want_shape, leaf_dtype,
                                     flat_s.get(key))
                    continue
                raise CorruptCheckpointError(
                    f"dtype mismatch for {key}: saved {entry.dtype} vs "
                    f"template {want_dtype}")
            if tuple(entry.shape) != want_shape:
                if pol == ZERO:
                    out[key] = zeros(want_shape, leaf_dtype,
                                     flat_s.get(key))
                    continue
                if pol != PAD_FLAT:
                    raise CorruptCheckpointError(
                        f"shape mismatch for {key}: saved "
                        f"{tuple(entry.shape)} vs template {want_shape} "
                        f"(policy {pol})")
                if len(entry.shape) != 1 or len(want_shape) != 1:
                    raise CorruptCheckpointError(
                        f"pad_flat policy needs 1-D leaves, got "
                        f"{entry.shape} -> {want_shape} for {key}")
                if want_shape[0] < entry.shape[0]:
                    # shrinking is only exact when the dropped tail is
                    # padding: verify it is actually all zeros instead
                    # of silently truncating live optimizer state
                    tail = self.read_box(
                        key, ((want_shape[0], entry.shape[0]),))
                    if tail.any():
                        raise CorruptCheckpointError(
                            f"pad_flat would truncate live data of "
                            f"{key}: saved extent {entry.shape[0]}, "
                            f"template {want_shape[0]}, and the dropped "
                            f"tail is not all zeros")
            if entry.kind == "sharded":
                # the save side proved its shards tiled the array; prove
                # it again on the read side — a manifest that parses but
                # lost shard entries (torn hand-edit, a multi-host save
                # missing one host's files) would otherwise zero-fill
                # the gap silently, with every surviving CRC passing
                vol = sum(int(np.prod(_box_shape(s.index)))
                          for s in entry.shards)
                if vol != int(np.prod(entry.shape)):
                    raise CorruptCheckpointError(
                        f"shards of {key} cover {vol} of "
                        f"{int(np.prod(entry.shape))} saved elements — "
                        f"manifest lost shard entries")
            sh = flat_s.get(key)
            if sh is not None:
                def cb(index, _key=key, _shape=want_shape):
                    return self.read_box(_key, _norm_index(index, _shape))
                out[key] = jax.make_array_from_callback(
                    want_shape, sh, cb)
            else:
                box = self.read_box(key, tuple((0, d)
                                               for d in want_shape))
                out[key] = jax.numpy.asarray(box)
        return self.manifest.step, rebuild(template, out)


def restore_sharded(ckpt_dir: str, template, *, shardings=None,
                    policy=None, layout=None, verify: bool = True,
                    retry: RetryPolicy = NO_RETRY) -> Tuple[int, Any]:
    return ShardedCheckpoint(ckpt_dir, verify=verify,
                             retry=retry).restore(
        template, shardings=shardings, policy=policy, layout=layout)


def restore_auto(ckpt_dir: str, template, *, shardings=None, policy=None,
                 layout=None, verify: bool = True,
                 retry: RetryPolicy = NO_RETRY) -> Tuple[int, Any]:
    """Dispatch on the on-disk format: sharded manifest or legacy
    per-leaf (``repro.checkpoint``) — old checkpoints keep restoring.

    The legacy format cannot apply ``policy`` (it has no reshard
    arithmetic); its restore instead validates saved-vs-template shapes
    and fails with a clear error on mismatch, so a re-factorized resume
    from a legacy dir dies loudly rather than deep inside the jitted
    step."""
    if mf.is_sharded_dir(ckpt_dir):
        return restore_sharded(ckpt_dir, template, shardings=shardings,
                               policy=policy, layout=layout,
                               verify=verify, retry=retry)
    from repro import checkpoint as legacy
    return legacy.restore(ckpt_dir, template, shardings=shardings,
                          verify=verify)
