"""Stable string paths for pytree leaves.

The checkpoint formats key every leaf by a deterministic path string
(``"[0].layers.attn.wq"``) derived from the container structure: dicts
walk sorted keys, lists/tuples/NamedTuples walk indices.  The same walk
produces the same keys for a template at restore time, so save/restore
never depends on pytree registration order.
"""
from __future__ import annotations

import re
from typing import Any, Dict


def leaf_paths(tree) -> Dict[str, Any]:
    """Flatten ``tree`` into {path: leaf} with deterministic paths."""
    flat: Dict[str, Any] = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}.{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}[{i}]", v)
        else:
            flat[prefix] = node
    walk("", tree)
    return flat


def rebuild(template, values: Dict[str, Any]):
    """Rebuild ``template``'s structure with leaves from ``values``.

    NamedTuples are reconstructed via their field constructor; plain
    tuples/lists keep their type.
    """
    def go(prefix, node):
        if isinstance(node, dict):
            return {k: go(f"{prefix}.{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [go(f"{prefix}[{i}]", v) for i, v in enumerate(node)]
            return type(node)(vals) if not hasattr(node, "_fields") \
                else type(node)(*vals)
        return values[prefix]

    return go("", template)


def sanitize(path: str) -> str:
    """Filesystem-safe filename stem for a leaf path."""
    return re.sub(r"[^A-Za-z0-9_.\[\]-]", "_", path)
