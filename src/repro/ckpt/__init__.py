"""Elastic sharded checkpointing: per-rank shards + manifest.

The subsystem behind drain-free reconfiguration: each data-parallel rank
saves only the ZeRO-1 bucket shards (f32 masters/moments, EF residuals)
it already holds plus the replicated small leaves, under an atomic
temp-dir-rename commit protocol; a JSON manifest records the bucket
layout, mesh, step and per-file checksums; and restore reshards the flat
bucket address space onto whatever (pod, data) mesh the *restorer* runs
— no rank ever gathers a full bucket on either side.

Public API:

- :func:`save_sharded` / :func:`restore_sharded` — the sharded format;
- :func:`restore_auto` — format dispatch (legacy per-leaf dirs keep
  restoring);
- :class:`ShardedCheckpoint` — range-level reader (reshard arithmetic);
- :func:`latest_step` / :func:`committed_steps` / :func:`step_dir` —
  step-dir bookkeeping (full manifest-verified history), shared with
  (and crash-safe against) the legacy format;
- :func:`gc_debris` — dead-writer ``.tmp-*``/``.old-*`` sweep (also run
  automatically by every successful :func:`save_sharded`);
- restore policies :data:`EXACT` / :data:`PAD_FLAT` / :data:`ZERO`.

Fault-injection points and retry/fallback recovery live in
:mod:`repro.faults` (``restore_with_fallback`` wraps
:func:`restore_auto` with the committed-history quarantine walk).

The legacy gathered per-leaf format lives on in :mod:`repro.checkpoint`
for small replicated states and old checkpoints.
"""
from repro.checkpoint import (CorruptCheckpointError, committed_steps,
                              latest_step, step_dir)
from repro.ckpt.manifest import (FORMAT, MANIFEST, VERSION, LeafEntry,
                                 Manifest, ManifestError, ShardFile,
                                 bucket_live_sizes, is_sharded_dir,
                                 read_manifest)
from repro.ckpt.sharded import (EXACT, PAD_FLAT, ZERO, ShardedCheckpoint,
                                gc_debris, restore_auto, restore_sharded,
                                save_sharded)
from repro.ckpt.treepaths import leaf_paths, rebuild, sanitize

__all__ = [
    "CorruptCheckpointError", "committed_steps", "latest_step",
    "step_dir", "gc_debris",
    "FORMAT", "MANIFEST", "VERSION", "LeafEntry", "Manifest",
    "ManifestError", "ShardFile", "bucket_live_sizes", "is_sharded_dir",
    "read_manifest",
    "EXACT", "PAD_FLAT", "ZERO", "ShardedCheckpoint", "restore_auto",
    "restore_sharded", "save_sharded",
    "leaf_paths", "rebuild", "sanitize",
]
