"""Manifest schema for the sharded checkpoint format.

One ``manifest.json`` per committed step directory records everything a
restore — possibly onto a *different* (pod, data) mesh — needs:

- ``format``/``version``: format identification (the legacy per-leaf
  format has no ``format`` key, which is how ``restore_auto`` dispatches);
- ``step``: the training step the state belongs to;
- ``mesh``: axis names + shape of the mesh the state was saved from
  (informational: restore targets its *own* mesh);
- ``layout``: the flat-bucket layout (per-leaf slots with bucket index,
  offset, size, shape, dtype; padded bucket sizes; alignment) — the
  offset arithmetic a reshard needs, serialized without the treedef;
- ``leaves``: per-leaf entries.  ``replicated`` leaves have one file;
  ``sharded`` leaves have one file per distinct shard with its global
  index box ``[[start, stop], ...]``.  Every file carries a CRC32.

The manifest is written *last* inside a temp directory which is then
atomically renamed into place: a directory containing ``manifest.json``
under its final name is a committed checkpoint, everything else is torn
and ignored by ``latest_step``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

FORMAT = "repro-ckpt-sharded"
VERSION = 1
MANIFEST = "manifest.json"


class ManifestError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class ShardFile:
    """One saved shard: its file and the global index box it covers."""

    file: str
    index: Tuple[Tuple[int, int], ...]      # per-dim [start, stop)
    crc32: int

    def to_dict(self) -> Dict[str, Any]:
        return {"file": self.file,
                "index": [list(ab) for ab in self.index],
                "crc32": self.crc32}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ShardFile":
        return ShardFile(file=d["file"],
                         index=tuple((int(a), int(b))
                                     for a, b in d["index"]),
                         crc32=int(d["crc32"]))


@dataclasses.dataclass(frozen=True)
class LeafEntry:
    """Manifest record for one pytree leaf."""

    kind: str                               # "replicated" | "sharded"
    shape: Tuple[int, ...]
    dtype: str
    file: Optional[str] = None              # replicated
    crc32: Optional[int] = None             # replicated
    shards: Tuple[ShardFile, ...] = ()      # sharded
    spec: Tuple[Any, ...] = ()              # PartitionSpec axes (info only)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, "shape": list(self.shape),
                             "dtype": self.dtype}
        if self.kind == "replicated":
            d["file"] = self.file
            d["crc32"] = self.crc32
        else:
            d["shards"] = [s.to_dict() for s in self.shards]
            d["spec"] = [list(a) if isinstance(a, (list, tuple)) else a
                         for a in self.spec]
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "LeafEntry":
        return LeafEntry(
            kind=d["kind"], shape=tuple(int(s) for s in d["shape"]),
            dtype=d["dtype"], file=d.get("file"), crc32=d.get("crc32"),
            shards=tuple(ShardFile.from_dict(s)
                         for s in d.get("shards", ())),
            spec=tuple(tuple(a) if isinstance(a, list) else a
                       for a in d.get("spec", ())))


def layout_to_dict(layout) -> Optional[Dict[str, Any]]:
    """Serialize a ``bucketing.BucketLayout`` (duck-typed; no treedef)."""
    if layout is None:
        return None
    return {
        "align": int(layout.align),
        "bucket_sizes": [int(c) for c in layout.bucket_sizes],
        "live_sizes": bucket_live_sizes(layout),
        "slots": [{"bucket": int(s.bucket), "offset": int(s.offset),
                   "size": int(s.size), "shape": list(s.shape),
                   "dtype": str(s.dtype)} for s in layout.slots],
    }


def bucket_live_sizes(layout) -> List[int]:
    """Per-bucket live (un-padded) prefix length; the rest is zeros."""
    live = [0] * len(layout.bucket_sizes)
    for s in layout.slots:
        live[s.bucket] = max(live[s.bucket], s.offset + s.size)
    return live


@dataclasses.dataclass
class Manifest:
    step: int
    leaves: Dict[str, LeafEntry]
    mesh: Optional[Dict[str, Any]] = None         # {axis_names, shape}
    layout: Optional[Dict[str, Any]] = None
    version: int = VERSION

    def to_json(self) -> str:
        return json.dumps({
            "format": FORMAT, "version": self.version, "step": self.step,
            "mesh": self.mesh, "layout": self.layout,
            "leaves": {k: v.to_dict() for k, v in self.leaves.items()},
        }, indent=1, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "Manifest":
        d = json.loads(text)
        if d.get("format") != FORMAT:
            raise ManifestError(
                f"not a {FORMAT} manifest (format={d.get('format')!r})")
        version = int(d.get("version", VERSION))
        if version > VERSION:
            raise ManifestError(
                f"manifest version {version} is newer than "
                f"supported {VERSION}")
        return Manifest(
            step=int(d["step"]),
            leaves={k: LeafEntry.from_dict(v)
                    for k, v in d["leaves"].items()},
            mesh=d.get("mesh"), layout=d.get("layout"),
            version=version)


def mesh_to_dict(mesh) -> Optional[Dict[str, Any]]:
    if mesh is None:
        return None
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names]}


def read_manifest(ckpt_dir: str) -> Manifest:
    path = os.path.join(ckpt_dir, MANIFEST)
    with open(path) as f:
        return Manifest.from_json(f.read())


def is_sharded_dir(ckpt_dir: str) -> bool:
    """True when ``ckpt_dir`` holds a committed sharded-format manifest."""
    path = os.path.join(ckpt_dir, MANIFEST)
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return json.load(f).get("format") == FORMAT
    except (OSError, ValueError):
        return False
