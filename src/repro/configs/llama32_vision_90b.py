"""llama-3.2-vision-90b — VLM decoder with interleaved cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision (scaled); unverified]  100L total,
d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256.  Every 5th layer is a
cross-attention layer over stubbed patch embeddings (20 cross + 80 self,
mirroring the 11B's 1:4 ratio).  The vision tower is a STUB: ``input_specs()``
provides precomputed patch embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    cross_every=5,
    n_media_tokens=1024,
    frontend="patch",
    sub_quadratic=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
