"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf]  38 Mamba2 blocks, d_model=2048, ssm_state=64; a single
*shared* attention+MLP block (32H MHA, d_ff=8192, vocab=32000) is applied
every 6 mamba blocks (6 applications; weights shared across applications, as
in the Zamba2 paper).  Sub-quadratic -> eligible for long_500k.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,                 # mamba blocks
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid_attn_every=6,
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2411.15242; hf",
)
