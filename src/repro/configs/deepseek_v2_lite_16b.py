"""deepseek-v2-lite-16b — MLA attention + token-choice MoE.

[arXiv:2405.04434; hf]  27L, d_model=2048, 16H (kv=16), expert d_ff=1408,
vocab=102400, MLA kv_lora=512, MoE 64 routed top-6 + 2 shared, first layer
dense (d_ff=10944).

NOTE: the assigned spec is self-contradictory ("MoE 64e top-6" vs "2
shared+160 routed top-6"); we follow the explicit `MoE 64e top-6` (see
DESIGN.md §8).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=102400,
    head_dim=128,                # v head dim; qk dims come from MLAConfig
    moe=MoEConfig(
        n_routed=64,
        n_shared=2,
        top_k=6,
        d_ff=1408,
        n_padded=64,
        capacity_factor=1.25,
        first_dense_layers=1,
        dense_d_ff=10944,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    sub_quadratic=False,
    source="arXiv:2405.04434; hf",
)
