"""whisper-tiny — encoder-decoder ASR backbone (conv audio frontend stubbed).

[arXiv:2212.04356; unverified]  4L enc + 4L dec, d_model=384, 6H (kv=6),
d_ff=1536, vocab=51865.  The conv frontend is a STUB: ``input_specs()``
provides precomputed mel-frame embeddings of length 1500.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-tiny",
    family="encdec",
    n_layers=4,                  # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    use_rope=False,              # whisper uses learned/sinusoidal positions
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    tie_embeddings=True,
    is_encdec=True,
    enc_seq_len=1500,
    frontend="audio",
    sub_quadratic=False,
    source="arXiv:2212.04356; unverified",
)
