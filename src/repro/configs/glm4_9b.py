"""glm4-9b — dense GQA transformer, RoPE, kv=2.

[hf:THUDM/glm-4-9b; hf]  40L, d_model=4096, 32H (GQA kv=2), d_ff=13696,
vocab=151552.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=10000.0,
    rope_fraction=0.5,           # glm applies rope to half the head dim
    qkv_bias=True,
    sub_quadratic=False,
    source="hf:THUDM/glm-4-9b; hf",
)
