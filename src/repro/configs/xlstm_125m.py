"""xlstm-125m — sLSTM + mLSTM blocks.

[arXiv:2405.04517; unverified]  12 blocks, d_model=768, 4H, vocab=50304,
d_ff=0 (blocks carry their own 2x up-projection).  Every 4th block is an
sLSTM (3 sLSTM + 9 mLSTM), matching the paper's mixed [7:1]-ish ratio at this
scale.  Sub-quadratic (recurrent state) -> eligible for long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    use_rope=False,
    norm="layernorm",
    slstm_every=4,
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.04517; unverified",
)
