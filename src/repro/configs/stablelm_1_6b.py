"""stablelm-1.6b — dense MHA transformer with partial RoPE.

[hf:stabilityai/stablelm-2-1_6b; unverified]  24L, d_model=2048, 32H (kv=32,
i.e. MHA), d_ff=5632, vocab=100352, 25% partial rotary, LayerNorm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rope_fraction=0.25,
    norm="layernorm",
    qkv_bias=True,
    sub_quadratic=False,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
