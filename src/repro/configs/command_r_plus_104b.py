"""command-r-plus-104b — dense GQA transformer with parallel attn||FFN blocks.

[hf:CohereForAI/c4ai-command-r-v01 (plus-scale); unverified]  64L,
d_model=12288, 96H (GQA kv=8), d_ff=33792, vocab=256000, no biases, parallel
residual block, LayerNorm (cohere style), tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75000000.0,
    norm="layernorm",
    parallel_block=True,
    tie_embeddings=True,
    sub_quadratic=False,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
