"""Architecture & shape configuration system.

Every assigned architecture gets one module in ``repro.configs`` exporting a
``CONFIG: ArchConfig``.  The registry (``repro.configs.registry``) resolves
``--arch <id>`` strings to configs and model implementations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 0            # number of routed experts (as published)
    n_shared: int = 0            # shared (always-on) experts
    top_k: int = 2
    d_ff: int = 0                # per-expert hidden dim
    n_padded: int = 0            # routed experts padded for EP divisibility
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2
    first_dense_layers: int = 0  # leading layers that use a dense FFN instead
    dense_d_ff: int = 0          # hidden dim of those dense layers

    @property
    def n_experts_padded(self) -> int:
        return self.n_padded or self.n_routed


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0   # partial RoPE (stablelm = 0.25)
    use_rope: bool = True
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    parallel_block: bool = False # command-r style attn || ffn
    qkv_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    act: str = "silu"            # silu (SwiGLU) | gelu

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # --- hybrid (zamba2): mamba backbone + shared attention block -----------
    hybrid_attn_every: int = 0   # apply the shared attn block every N ssm blocks

    # --- xlstm: block pattern --------------------------------------------
    slstm_every: int = 0         # every Nth block is an sLSTM (rest mLSTM)

    # --- encoder-decoder (whisper) ---------------------------------------
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 0         # stub frontend sequence length (audio frames)

    # --- vlm (llama-3.2-vision) -------------------------------------------
    cross_every: int = 0         # every Nth layer is a cross-attention layer
    n_media_tokens: int = 0      # stub patch-embedding token count

    # frontend stub: None | 'audio' | 'patch'
    frontend: Optional[str] = None

    # sub-quadratic? (eligible for long_500k)
    sub_quadratic: bool = False

    max_seq: int = 532_480
    source: str = ""

    # ---------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (matches init to within ties/pads)."""
        from repro.models.registry import param_count  # lazy: avoids cycle
        return param_count(self)

    def active_param_count(self) -> int:
        from repro.models.registry import active_param_count
        return active_param_count(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs; returns (ok, reason_if_skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 524k-token dense-attention "
                       "decode is the quadratic regime long_500k excludes "
                       "(see DESIGN.md §4)")
    return True, ""
