"""qwen2-moe-a2.7b — token-choice MoE, 60 routed top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L, d_model=2048, 16H (kv=16), expert
d_ff=1408, vocab=151936.  Routed experts padded 60 -> 64 so the expert axis
divides the 16-way model mesh axis (pad experts receive ~0 router mass at
init; see DESIGN.md §8).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,                      # all FFN capacity is MoE
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(
        n_routed=60,
        n_shared=4,
        top_k=4,
        d_ff=1408,
        n_padded=64,
        capacity_factor=1.25,
    ),
    sub_quadratic=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
