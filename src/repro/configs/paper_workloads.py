"""The paper's own evaluation workloads (Table 1 + Table 2).

These drive the simulator reproduction (benchmarks/fig*.py); the JCT model
lives in ``repro.core.jct_model.WORKLOADS``.  This module re-exports the
job-mix configuration so `--arch paper-workloads` style tooling and the
trace generator agree on one source of truth.
"""
from repro.core.jct_model import WORKLOADS
from repro.core.traces import (DURATION_BUCKETS, DURATION_SOURCES,
                               INFER_SIZES, SIZE_DISTS, TRAIN_SIZES)

TABLE1_MODELS = tuple(WORKLOADS)
TABLE2_SIZE_DISTS = SIZE_DISTS
TRACE_SOURCES = tuple(DURATION_SOURCES)

__all__ = ["TABLE1_MODELS", "TABLE2_SIZE_DISTS", "TRACE_SOURCES",
           "TRAIN_SIZES", "INFER_SIZES", "DURATION_BUCKETS"]
