"""Serving substrate: batched decode with KV caches + request batcher.

``make_serve_step`` produces the jit-able one-token decode used by the
decode_32k / long_500k dry-run cells; ``BatchedServer`` is a CPU-runnable
batching loop (continuous batching over a fixed slot count).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import MeshRules, use_rules


def make_serve_step(model, *, rules: Optional[MeshRules] = None):
    """Returns step(params, cache, tokens (B,1), pos ()) ->
    (logits, cache)."""

    def step(params, cache, tokens, pos):
        with use_rules(rules):
            return model.decode_step(params, cache, tokens, pos)

    return jax.jit(step, donate_argnums=(1,))


def make_prefill_step(model, *, rules: Optional[MeshRules] = None):
    """Full-sequence forward (the prefill dry-run cell)."""

    def step(params, batch):
        with use_rules(rules):
            logits, _aux = model.forward_logits(params, batch)
            return logits

    return jax.jit(step)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based continuous batching (greedy sampling).

    Prompts are fed token-by-token through the decode step (prefill-by-
    decode; fine at demo scale — the prefill dry-run path covers the bulk
    prefill compute on the production mesh).
    """

    def __init__(self, model, params, *, max_batch: int = 4,
                 max_seq: int = 256,
                 rules: Optional[MeshRules] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.step_fn = make_serve_step(model, rules=rules)
        self.cache = model.init_cache(max_batch, max_seq)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = [0] * max_batch
        self.pending: "queue.Queue[Request]" = queue.Queue()
        self.completed: List[Request] = []
        self.pos = 0                # global position (lockstep decode)

    def submit(self, req: Request) -> None:
        self.pending.put(req)

    def _fill_slots(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and not self.pending.empty():
                self.slots[i] = self.pending.get()
                self.slot_pos[i] = 0

    def _current_tokens(self) -> np.ndarray:
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = self.slot_pos[i]
            if p < len(req.prompt):
                toks[i, 0] = req.prompt[p]
            elif req.out:
                toks[i, 0] = req.out[-1]
        return toks

    def step(self) -> None:
        self._fill_slots()
        if all(s is None for s in self.slots):
            return
        toks = jnp.asarray(self._current_tokens())
        logits, self.cache = self.step_fn(
            self.params, self.cache, toks, jnp.int32(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                req.out.append(int(nxt[i]))
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.completed.append(req)
                    self.slots[i] = None
        self.pos += 1

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if (self.pending.empty()
                    and all(s is None for s in self.slots)):
                break
            if self.pos >= self.max_seq - 1:
                break
            self.step()
