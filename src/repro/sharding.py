"""Logical-axis sharding rules.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", ...).  A ``MeshRules`` mapping — chosen per mesh — resolves logical
names to physical mesh axes.  Outside a rules context (unit tests on one CPU
device) all annotations are no-ops, so the same model code runs everywhere.

This is the layer that implements Flex-MIG's "logical aggregation" on TPU: a
job's leaves form a mesh, and these rules decide which collective rides the
fast intra-pod axis vs the slow cross-pod axis (SHM vs NET in paper terms).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel import axes_size as _axes_size
from repro.parallel import axis_tuple as _axis_tuple
from repro.parallel import manual_axes as _manual_axes

Axes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Maps logical axis names to physical mesh axis names (or None)."""

    rules: Dict[str, Axes]
    mesh: Optional[Mesh] = None

    def to_pspec(self, logical: Tuple[Optional[str], ...]) -> P:
        phys = []
        for name in logical:
            if name is None:
                phys.append(None)
            else:
                if name not in self.rules:
                    raise KeyError(f"unknown logical axis {name!r}; "
                                   f"known: {sorted(self.rules)}")
                phys.append(self.rules[name])
        return P(*phys)


_current: contextvars.ContextVar[Optional[MeshRules]] = contextvars.ContextVar(
    "mesh_rules", default=None)


def current_rules() -> Optional[MeshRules]:
    return _current.get()


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    tok = _current.set(rules)
    try:
        yield rules
    finally:
        _current.reset(tok)


def shard(x, *logical: Optional[str]):
    """Annotate ``x`` with a sharding constraint for the active rules.

    Axes whose mesh extent does not divide the tensor dim are dropped
    (e.g. whisper's 6 heads under a 16-way model axis stay replicated),
    as are axes currently mapped manually by an enclosing shard_map.
    """
    rules = _current.get()
    if rules is None or rules.mesh is None:
        return x                  # no mesh: constraints are meaningless
    manual = _manual_axes()

    def keep(ax: Axes) -> Axes:
        if ax is None or not manual:
            return ax
        if isinstance(ax, str):
            return None if ax in manual else ax
        kept = tuple(a for a in ax if a not in manual)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    spec = rules.to_pspec(tuple(logical))
    spec = P(*(keep(ax) for ax in spec))
    if rules.mesh is not None:
        fixed = []
        for dim, axes in zip(x.shape, tuple(spec) + (None,) * (
                x.ndim - len(spec))):
            n = _axes_size(rules.mesh, axes)
            fixed.append(axes if (n > 1 and dim % n == 0) or n == 1
                         else None)
        spec = P(*fixed)
    if manual and all(ax is None for ax in spec):
        # every axis is manually mapped by the enclosing shard_map: the
        # constraint is vacuous per-rank, and an all-None constraint would
        # demand a mesh context manager at the call site for no effect
        # (outside shard_map an all-None spec still means "replicate", so
        # it is only skipped in the manual case)
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def pspec(*logical: Optional[str]) -> P:
    rules = _current.get()
    if rules is None:
        return P()
    return rules.to_pspec(tuple(logical))


def named_sharding(mesh: Mesh, rules: MeshRules,
                   logical: Tuple[Optional[str], ...]) -> NamedSharding:
    return NamedSharding(mesh, rules.to_pspec(logical))


def batch_axes(rules: Optional[MeshRules] = None) -> Tuple[str, ...]:
    """Physical axes the batch dim is sharded over (for shard_map specs)."""
    rules = rules or _current.get()
    if rules is None:
        return ()
    return _axis_tuple(rules.rules.get("batch"))


def model_axes(rules: Optional[MeshRules] = None) -> Tuple[str, ...]:
    rules = rules or _current.get()
    if rules is None:
        return ()
    return _axis_tuple(rules.rules.get("expert"))


def grad_sync_axes(mesh: Optional[Mesh]
                   ) -> Tuple[Optional[str], Optional[str]]:
    """(fast_axis, slow_axis) for explicit gradient synchronization.

    The manual (shard_map) gradient-sync modes reduce over the
    data-parallel fast axis and the cross-pod slow axis; a mesh carrying
    any *other* non-trivial axis (tensor/expert parallelism) cannot keep
    params replicated inside a fully-manual step, so it is rejected here
    rather than silently miscomputing.
    """
    if mesh is None:
        return None, None
    names = tuple(mesh.axis_names)
    extra = [a for a in names if a not in ("data", "pod")
             and mesh.shape[a] > 1]
    if extra:
        raise ValueError(
            f"manual gradient-sync modes support (pod, data) meshes only; "
            f"mesh has non-trivial axes {extra!r} (use cross_pod_mode="
            f"'xla' for tensor/expert-parallel meshes)")
    fast = "data" if "data" in names else None
    slow = "pod" if "pod" in names else None
    return fast, slow


# ---------------------------------------------------------------------------
# Standard rule sets
# ---------------------------------------------------------------------------

def make_rules(mesh: Mesh, *, seq_shard: bool = False,
               long_ctx: bool = False, fsdp: bool = True,
               seq_parallel: bool = False) -> MeshRules:
    """Production rules for ("pod","data","model") / ("data","model") meshes.

    - batch       -> all data-parallel axes (pod outermost)
    - embed       -> 'data' (FSDP / ZeRO-3 parameter+optimizer sharding)
    - heads/ff/vocab/expert -> 'model' (tensor / expert parallelism)
    - kv_seq      -> 'model' when seq_shard (sequence-parallel long decode)
    """
    names = tuple(mesh.axis_names)
    dp: Axes
    if "pod" in names:
        dp = ("pod", "data")
    elif "data" in names:
        dp = "data"
    else:
        dp = None
    rules: Dict[str, Axes] = {
        "batch": dp,
        # fsdp=False: ZeRO-1 — params replicated over data, optimizer
        # states still sharded (the dry-run passes a second rules set for
        # the opt-state shardings)
        "embed": "data" if (fsdp and "data" in names) else None,
        "heads": "model" if "model" in names else None,
        "kv_heads": None,          # GQA kv heads often don't divide TP; replicate
        "ff": "model" if "model" in names else None,
        "vocab": "model" if "model" in names else None,
        "expert": "model" if "model" in names else None,
        # seq_parallel: residual-stream carriers sharded over 'model' on
        # the sequence dim between layers (Megatron-SP)
        "seq": ("model" if seq_parallel and "model" in names else None),
        "kv_seq": ("model" if seq_shard and "model" in names else None),
        "kv_batch": dp,
        "state": None,
        "conv": None,
        "norm": None,
        "lora": None,
    }
    if long_ctx:
        # long_500k: global_batch=1 -> batch axes replicated; the KV/state
        # sequence axis carries the parallelism instead (SP decode)
        rules["batch"] = None
        rules["kv_batch"] = None
        seq_axes = tuple(a for a in ("data", "model") if a in names)
        rules["kv_seq"] = seq_axes if seq_axes else None
    return MeshRules(rules=rules, mesh=mesh)


def tree_shardings(mesh: Mesh, rules: MeshRules, shapes_tree, axes_tree):
    """NamedShardings for a pytree given logical axes + shapes.

    Non-dividing axes are dropped per-dim (uneven GSPMD shardings are legal
    but we keep params exactly shardable to make memory analysis exact).
    """
    def one(shape_leaf, axes):
        spec = rules.to_pspec(axes)
        fixed = []
        for dim, ax in zip(shape_leaf.shape, tuple(spec) + (None,) * (
                len(shape_leaf.shape) - len(spec))):
            n = _axes_size(mesh, ax)
            fixed.append(ax if (n > 1 and dim % n == 0) or n == 1
                         else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda v: isinstance(v, tuple) and all(
                            isinstance(x, (str, type(None))) for x in v))


def without_axes(rules: MeshRules, drop: frozenset) -> MeshRules:
    """Rules with some physical axes removed (e.g. inside a shard_map that
    maps those axes manually, constraints must not mention them)."""
    new: Dict[str, Axes] = {}
    for k, ax in rules.rules.items():
        if ax is None:
            new[k] = None
        elif isinstance(ax, str):
            new[k] = None if ax in drop else ax
        else:
            kept = tuple(a for a in ax if a not in drop)
            new[k] = kept if len(kept) > 1 else (kept[0] if kept else None)
    return MeshRules(rules=new, mesh=rules.mesh)


def single_device_rules() -> MeshRules:
    return MeshRules(rules={k: None for k in (
        "batch", "embed", "heads", "kv_heads", "ff", "vocab", "expert",
        "seq", "kv_seq", "kv_batch", "state", "conv", "norm", "lora")})
