#!/usr/bin/env python
"""Schema/acceptance gate for ``BENCH_*.json`` artifacts.

CI's bench-smoke job used to only *upload* the bench JSONs — a bench
that silently degraded (missing sections, acceptance booleans flipped
false) still produced a green job.  This script fails the job instead:

- every file passed on the command line must exist and parse as JSON;
- known bench files must contain their required top-level keys;
- every *boolean* found inside any ``acceptance`` object (recursively)
  must be True.

Usage: ``python scripts/check_bench.py BENCH_*.json`` (no arguments:
checks every ``BENCH_*.json`` in the repo root, requiring at least one).
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import List

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# required top-level keys per bench artifact
REQUIRED_KEYS = {
    "BENCH_grad_sync.json": ("arch", "sync_hlo", "jct_model",
                             "step_wallclock_us", "acceptance"),
    "BENCH_ckpt.json": ("accounting", "wallclock", "acceptance"),
    "BENCH_elastic.json": ("measurements", "cost_model", "replay",
                           "acceptance"),
    "BENCH_fault.json": ("recovery", "replay", "acceptance"),
    "BENCH_cluster.json": ("pool", "measurements", "cost_model",
                           "replay", "repacks", "acceptance"),
    "BENCH_sched.json": ("matrix", "table", "fleet", "acceptance"),
}


def _acceptance_failures(node, path: str, out: List[str]) -> None:
    """Collect every False boolean under an ``acceptance`` object."""
    if isinstance(node, dict):
        for k, v in node.items():
            sub = f"{path}.{k}" if path else k
            if isinstance(v, bool):
                if v is False:
                    out.append(sub)
            else:
                _acceptance_failures(v, sub, out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _acceptance_failures(v, f"{path}[{i}]", out)


def check_file(path: str) -> List[str]:
    """Returns a list of human-readable failures for one bench JSON."""
    name = os.path.basename(path)
    if not os.path.exists(path):
        return [f"{name}: missing (bench did not write it)"]
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{name}: unreadable JSON ({e})"]
    if not isinstance(data, dict):
        return [f"{name}: top level is not an object"]
    failures: List[str] = []
    for key in REQUIRED_KEYS.get(name, ()):
        if key not in data:
            failures.append(f"{name}: missing required key {key!r}")
    acc = data.get("acceptance")
    if isinstance(acc, bool):       # degenerate "acceptance": false
        if acc is False:
            failures.append(f"{name}: acceptance is false")
    elif acc is not None:
        falses: List[str] = []
        _acceptance_failures(acc, "acceptance", falses)
        failures.extend(f"{name}: {p} is false" for p in falses)
    return failures


def main(argv: List[str]) -> int:
    paths = argv or sorted(glob.glob(os.path.join(REPO, "BENCH_*.json")))
    if not paths:
        print("check_bench: no BENCH_*.json found and none given",
              file=sys.stderr)
        return 1
    failures: List[str] = []
    for p in paths:
        failures.extend(check_file(p))
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if failures:
        return 1
    for p in paths:
        print(f"OK {os.path.basename(p)}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
