#!/usr/bin/env python
"""Seeded fleet-trace profiling harness for the discrete-event simulator.

Produces the before/after numbers the fleet-scale hardening is judged
by: wall-clock split into trace-generation vs simulation, simulator
events/sec, and (``--cprofile``) a per-function breakdown of the
simulate call — the view that originally surfaced the three superlinear
hot spots (the per-pass full-queue tier scan, the O(hosts^2 x leaves)
``choose_host`` rescans, and the dict-tombstone head peeks).

Deterministic by construction: the trace is seeded, so two runs of

    PYTHONPATH=src python scripts/profile_sim.py --n-jobs 32000

simulate the identical event sequence and differences are pure
machine/implementation speed.  Sweep sizes to see the scaling curve:

    PYTHONPATH=src python scripts/profile_sim.py \
        --n-jobs 8000 32000 128000 --policy fifo
"""
from __future__ import annotations

import argparse
import cProfile
import io
import pstats
import sys
import time

sys.path.insert(0, "src")

from repro.core.simulator import simulate          # noqa: E402
from repro.core.traces import generate_fleet_trace  # noqa: E402


def profile_once(n_jobs: int, *, seed: int, n_hosts: int, policy: str,
                 placement: str, with_cprofile: bool) -> dict:
    t0 = time.perf_counter()
    jobs = generate_fleet_trace(n_jobs, seed=seed)
    t_gen = time.perf_counter() - t0

    prof = cProfile.Profile() if with_cprofile else None
    t0 = time.perf_counter()
    if prof:
        prof.enable()
    res = simulate(jobs, "FM", n_hosts=n_hosts, policy=policy,
                   placement=placement)
    if prof:
        prof.disable()
    t_sim = time.perf_counter() - t0

    row = {
        "n_jobs": n_jobs,
        "gen_s": t_gen,
        "sim_s": t_sim,
        "n_events": res.n_events,
        "events_per_s": res.n_events / t_sim if t_sim > 0 else 0.0,
        "completed": len(res.jct_by_job),
        "makespan_s": res.makespan,
        "avg_frag_slices": res.avg_frag_slices,
    }
    if prof:
        buf = io.StringIO()
        pstats.Stats(prof, stream=buf).sort_stats("cumulative") \
            .print_stats(25)
        row["cprofile"] = buf.getvalue()
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-jobs", type=int, nargs="+",
                    default=[8000, 32000])
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--n-hosts", type=int, default=32)
    ap.add_argument("--policy", default="fifo",
                    choices=("fifo", "backfill"))
    ap.add_argument("--placement", default="default",
                    choices=("default", "frag_aware"))
    ap.add_argument("--cprofile", action="store_true",
                    help="attach cProfile to the simulate call and "
                         "print the top-25 cumulative breakdown")
    args = ap.parse_args(argv)

    print(f"# fleet profile: hosts={args.n_hosts} policy={args.policy} "
          f"placement={args.placement} seed={args.seed}")
    print(f"{'n_jobs':>9} {'gen_s':>7} {'sim_s':>8} {'events':>9} "
          f"{'events/s':>9} {'frag':>7}")
    for n in args.n_jobs:
        row = profile_once(n, seed=args.seed, n_hosts=args.n_hosts,
                           policy=args.policy, placement=args.placement,
                           with_cprofile=args.cprofile)
        print(f"{row['n_jobs']:>9} {row['gen_s']:>7.2f} "
              f"{row['sim_s']:>8.2f} {row['n_events']:>9} "
              f"{row['events_per_s']:>9.0f} "
              f"{row['avg_frag_slices']:>7.2f}")
        if row["completed"] != n:
            print(f"  WARNING: only {row['completed']}/{n} jobs "
                  f"completed", file=sys.stderr)
        if args.cprofile:
            print(row["cprofile"])
    return 0


if __name__ == "__main__":
    sys.exit(main())
