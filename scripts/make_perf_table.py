"""Render the §Perf iteration tables from tagged dry-run artifacts."""
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

PAIRS = {
    "Pair A — llama3.2-1b train_4k @ 16x16": [
        ("llama3.2-1b__train_4k__16x16", "baseline (FSDP, accum 4)"),
        ("llama3.2-1b__train_4k__16x16__zero1", "+ZeRO-1 (no FSDP)"),
        ("llama3.2-1b__train_4k__16x16__accum1", "accum 1 only"),
        ("llama3.2-1b__train_4k__16x16__zero1_accum1",
         "+ZeRO-1 +accum 1"),
        ("llama3.2-1b__train_4k__16x16__zero1_accum1_sp",
         "+ZeRO-1 +accum 1 +SP"),
        ("llama3.2-1b__train_4k__16x16__zero1_accum1_sp_pbf16",
         "+ZeRO-1 +accum 1 +SP +bf16-p"),
    ],
    "Pair B — command-r-plus-104b train_4k @ 16x16": [
        ("command-r-plus-104b__train_4k__16x16", "baseline (accum 16)"),
        ("command-r-plus-104b__train_4k__16x16__sp", "+SP"),
        ("command-r-plus-104b__train_4k__16x16__sp_accum8",
         "+SP, accum 8"),
        ("command-r-plus-104b__train_4k__16x16__sp_accum4",
         "+SP, accum 4"),
        ("command-r-plus-104b__train_4k__16x16__sp_accum8_pbf16",
         "+SP, accum 8, +bf16-p"),
        ("command-r-plus-104b__train_4k__16x16__sp_nomaster",
         "+SP, bf16-master AdamW (fits!)"),
        ("command-r-plus-104b__train_4k__16x16__sp_accum32",
         "counter-probe: accum 32"),
    ],
    "Pair C — llama-3.2-vision-90b train_4k @ 2x16x16": [
        ("llama-3.2-vision-90b__train_4k__2x16x16", "baseline (accum 8)"),
        ("llama-3.2-vision-90b__train_4k__2x16x16__sp", "+SP"),
        ("llama-3.2-vision-90b__train_4k__2x16x16__mediapin",
         "+media sharding pin"),
        ("llama-3.2-vision-90b__train_4k__2x16x16__mediapin_sp",
         "+media pin +SP"),
        ("llama-3.2-vision-90b__train_4k__2x16x16__comp",
         "int8 cross-pod grads (XLA-blocked)"),
    ],
}


def main():
    for title, rows in PAIRS.items():
        print(f"\n#### {title}\n")
        print("| iteration | compute s | memory s | collective s "
              "| cross-pod s | bound s | vs base | mem GB | fits |")
        print("|---|---|---|---|---|---|---|---|---|")
        base = None
        for stem, label in rows:
            fn = os.path.join(ART, stem + ".json")
            if not os.path.exists(fn):
                print(f"| {label} | (missing) | | | | | | | |")
                continue
            m = json.load(open(fn))
            if m.get("status") != "ok":
                err = m.get("error", "?")[:60].replace("|", "/")
                print(f"| {label} | FAILED: {err} | | | | | | | |")
                continue
            r = m["roofline"]
            if base is None:
                base = r["bound_s"]
            mem = m["memory"]["peak_estimate_bytes"] / 1e9
            fits = "Y" if m["memory"]["fits_16gb"] else "N"
            print(f"| {label} | {r['compute_s']:.1f} "
                  f"| {r['memory_s']:.1f} | {r['collective_s']:.1f} "
                  f"| {r.get('cross_pod_s', 0):.1f} "
                  f"| **{r['bound_s']:.1f}** "
                  f"| {base / r['bound_s']:.2f}x | {mem:.1f} | {fits} |")


if __name__ == "__main__":
    main()
