#!/usr/bin/env bash
# Tier-1 verification: the ROADMAP.md gate every PR must keep green.
#
# Pass 1 runs the ROADMAP tier-1 command as-is.  Per tests/conftest.py the
# main pytest process must stay at the platform's real device count (the
# bf16 numerical tolerances are calibrated for an unsplit CPU thread
# pool); every multi-device test forks a subprocess with its own
# --xla_force_host_platform_device_count (4 or 8).
#
# Pass 2 reruns the SPMD runtime-layer suite with 4 forced host devices in
# the main process, so mesh construction / collectives are also exercised
# in-process on a multi-device backend.
#
# Pass 1 respects an ambient XLA_FLAGS: CI additionally runs the whole
# suite with --xla_force_host_platform_device_count=8 (the ci.yml device
# matrix) so the (pod, data) mesh paths execute multi-device in the main
# process too.  Subprocess-forking tests pin their own device counts
# either way (tests/conftest.py).
#
# Exits nonzero on any failure or collection error in either pass.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: full suite =="
python -m pytest -x -q "$@"

echo "== tier-1: SPMD layer on 4 forced host devices =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -x -q tests/test_parallel_compat.py

# Pass 3: static HLO verification of the train-step matrix (the script
# re-execs itself with its own pinned 4-device CPU backend, so the
# ambient XLA_FLAGS cannot skew the budgets).  Zero findings required.
echo "== tier-1: HLO invariant lint over the train-step matrix =="
python scripts/lint_hlo.py
