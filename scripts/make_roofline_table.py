"""Render the EXPERIMENTS.md roofline/dry-run tables from artifacts."""
import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["whisper-tiny", "llama-3.2-vision-90b",
              "command-r-plus-104b", "glm4-9b", "stablelm-1.6b",
              "llama3.2-1b", "qwen2-moe-a2.7b", "deepseek-v2-lite-16b",
              "zamba2-1.2b", "xlstm-125m"]


def load(mesh, tag=""):
    out = {}
    for fn in glob.glob(os.path.join(ART, "*.json")):
        parts = os.path.basename(fn)[:-5].split("__")
        if len(parts) < 3:
            continue
        arch, shape, m = parts[0], parts[1], parts[2]
        t = parts[3] if len(parts) > 3 else ""
        if m != mesh or t != tag:
            continue
        with open(fn) as f:
            out[(arch, shape)] = json.load(f)
    return out


def fraction(meta):
    useful_s = (meta["model_flops"] / meta["n_chips"]) / 197e12
    return useful_s / max(meta["roofline"]["bound_s"], 1e-12)


def table(mesh, tag=""):
    cells = load(mesh, tag)
    print(f"\n### mesh {mesh}{' tag=' + tag if tag else ''}\n")
    print("| arch | shape | status | mem GB | fits | compute s | "
          "memory s | collective s | dominant | useful | RL frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            meta = cells.get((arch, shape))
            if meta is None:
                continue
            if meta.get("status") == "skipped":
                print(f"| {arch} | {shape} | skipped (sub-quadratic-only"
                      f" shape) | | | | | | | | |")
                continue
            if meta.get("status") != "ok":
                print(f"| {arch} | {shape} | ERROR | | | | | | | | |")
                continue
            r = meta["roofline"]
            m = meta["memory"]
            print(f"| {arch} | {shape} | ok "
                  f"| {m['peak_estimate_bytes']/1e9:.1f} "
                  f"| {'Y' if m['fits_16gb'] else 'N'} "
                  f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                  f"| {r['collective_s']:.3f} | {r['dominant']} "
                  f"| {r['useful_ratio']:.2f} | {fraction(meta):.4f} |")


if __name__ == "__main__":
    table("16x16")
    table("2x16x16")
