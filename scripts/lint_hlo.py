#!/usr/bin/env python
"""Lower the canonical train-step matrix and lint every program.

Runs the ``cross_pod_mode x overlap x det x zero1`` matrix (every valid
combination — overlap and deterministic_reduce are bucketed-only and
mutually exclusive) on a (pod=2, data=2) mesh over 4 forced host CPU
devices with the reduced llama3.2-1b, then runs every
``repro.analysis.lint`` rule over both HLO dialects of each cell
against the declared budgets in ``src/repro/analysis/budgets.json``.

Usage::

    python scripts/lint_hlo.py                      # full matrix, exit 1 on findings
    python scripts/lint_hlo.py --cells xla zero1_det
    python scripts/lint_hlo.py --update-budgets     # regenerate budgets.json
    python scripts/lint_hlo.py --json /tmp/lint.json

The script re-executes itself with a pinned
``--xla_force_host_platform_device_count=4`` CPU backend so the mesh
shape (and therefore the budgets) is identical no matter the ambient
XLA_FLAGS (CI also runs tier-1 under an 8-device flag).
"""
import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

N_DEVICES = 4
MESH_SHAPE = (2, 2)                    # (pod, data)
CHIPS_PER_POD = 2
BUCKET_BYTES = 1 << 20
ARCH = "llama3.2-1b"

# every valid cell of the matrix; overlap/det apply to bucketed modes
# only and are mutually exclusive (make_train_step validates both)
CELLS = {
    "xla": dict(cross_pod_mode="xla"),
    "hier": dict(cross_pod_mode="hier"),
    "hier_bucketed": dict(cross_pod_mode="hier_bucketed"),
    "hier_bucketed_overlap": dict(cross_pod_mode="hier_bucketed",
                                  overlap=True),
    "hier_bucketed_det": dict(cross_pod_mode="hier_bucketed",
                              deterministic_reduce=True),
    "zero1": dict(cross_pod_mode="hier_bucketed_zero1"),
    "zero1_overlap": dict(cross_pod_mode="hier_bucketed_zero1",
                          overlap=True),
    "zero1_det": dict(cross_pod_mode="hier_bucketed_zero1",
                      deterministic_reduce=True),
}


def _reexec(argv):
    env = dict(os.environ)
    env["_LINT_HLO_INNER"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES}")
    env["PYTHONPATH"] = SRC + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.call([sys.executable, os.path.abspath(__file__)]
                           + argv, env=env)


def _split_budget(count, n_buckets):
    """Heuristic (fixed, per_bucket) split of a measured count.

    Per-bucket collectives dominate in the bucketed modes, so the
    integer quotient is attributed per bucket and the remainder (loss /
    grad-norm reductions) is fixed.  budgets.json is versioned — edit
    the split by hand when the heuristic misattributes."""
    if n_buckets > 1 and count >= n_buckets:
        per = count // n_buckets
        return count - per * n_buckets, per
    return count, 0


def run_matrix(args):
    import jax  # noqa: E402  (after the re-exec pinned the backend)
    from repro import optim, train
    from repro.analysis import hlo, ir
    from repro.analysis.lint import (LintContext, budget_for,
                                     load_budgets, run_rules)
    from repro.models.registry import build_model, get_config, \
        reduced_config
    from repro.sharding import make_rules

    assert jax.device_count() == N_DEVICES, jax.devices()
    mesh = jax.make_mesh(MESH_SHAPE, ("pod", "data"))
    # fsdp=False for every cell: the manual sync modes require
    # replicated params, and keeping the xla cell on the same rules
    # makes the budgets comparable across the matrix
    rules = make_rules(mesh, fsdp=False)
    cfg = reduced_config(get_config(ARCH))
    model = build_model(cfg, remat=False)
    ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=5,
                             total_steps=100)
    budgets = None if args.update_budgets else load_budgets()

    cells = args.cells or list(CELLS)
    unknown = sorted(set(cells) - set(CELLS))
    if unknown:
        sys.exit(f"unknown cells {unknown}; known: {sorted(CELLS)}")

    report = {}
    measured = {}
    n_findings = 0
    for name in cells:
        kw = CELLS[name]
        h = train.train_step_hlo(model, ocfg, rules=rules,
                                 bucket_bytes=BUCKET_BYTES, **kw)
        optimized = ir.parse(h.compiled_text)
        lowered = ir.parse(h.lowered_text)
        config = {
            "cell": name,
            "cross_pod_mode": kw["cross_pod_mode"],
            "overlap": bool(kw.get("overlap")),
            "deterministic_reduce": bool(kw.get("deterministic_reduce")),
            "slow_compress_bits": int(kw.get("slow_compress_bits", 0)),
            "chips_per_pod": CHIPS_PER_POD,
            "n_buckets": h.n_buckets,
            "grad_bytes": h.grad_bytes,
        }
        if args.update_budgets:
            stats = hlo.analyze(optimized, chips_per_pod=CHIPS_PER_POD)
            fixed, per_bucket = {}, {}
            for k, c in sorted(stats.collective_ops.items()):
                f, p = _split_budget(c, h.n_buckets)
                if f:
                    fixed[k] = f
                if p:
                    per_bucket[k] = p
            cell = {"fixed": fixed, "per_bucket": per_bucket,
                    "max_operand_bytes_factor": round(
                        stats.collective_operand_bytes
                        / h.grad_bytes * 1.25, 2)}
            measured[name] = cell
            findings = []
        else:
            ctx = LintContext(optimized=optimized, lowered=lowered,
                              config=config,
                              budget=budget_for(budgets, name))
            findings = run_rules(ctx, only=args.only or None)
        report[name] = {"config": config,
                        "findings": [f.to_dict() for f in findings]}
        status = "clean" if not findings else f"{len(findings)} finding(s)"
        print(f"[lint-hlo] {name:24s} n_buckets={h.n_buckets} {status}")
        for f in findings:
            print("  " + f.format().replace("\n", "\n  "))
        n_findings += len(findings)

    if args.update_budgets:
        from repro.analysis.lint.core import BUDGETS_PATH
        out = {
            "version": 1,
            "comment": ("per-step collective budgets for the lint "
                        "matrix; regenerate with "
                        "scripts/lint_hlo.py --update-budgets"),
            "arch": ARCH + " (reduced)",
            "mesh": list(MESH_SHAPE),
            "bucket_bytes": BUCKET_BYTES,
            "cells": measured,
        }
        with open(BUDGETS_PATH, "w") as f:
            json.dump(out, f, indent=2, sort_keys=False)
            f.write("\n")
        print(f"[lint-hlo] wrote {BUDGETS_PATH}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    if n_findings:
        print(f"[lint-hlo] FAIL: {n_findings} finding(s)")
        return 1
    print(f"[lint-hlo] OK: {len(cells)} cell(s) clean")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cells", nargs="*", default=None,
                    help="subset of matrix cells (default: all)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of lint rules to run")
    ap.add_argument("--update-budgets", action="store_true",
                    help="rewrite analysis/budgets.json from measured "
                         "collective counts (with 25%% bytes headroom)")
    ap.add_argument("--json", default=None,
                    help="write the full report to this path")
    args = ap.parse_args()
    if os.environ.get("_LINT_HLO_INNER") != "1":
        sys.exit(_reexec(sys.argv[1:]))
    sys.exit(run_matrix(args))


if __name__ == "__main__":
    main()
