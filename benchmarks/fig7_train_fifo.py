"""Fig. 7: FM vs DM vs SM under FIFO, training-only, max size 4."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.metrics import ModeComparison, summarize
from repro.core.simulator import simulate
from repro.core.traces import DURATION_SOURCES, TraceCategory, \
    generate_trace


def run(seeds=(0, 1, 2)) -> dict:
    out = {}
    for size_dist in ("small", "balanced", "large"):
        fm_dm, fm_sm = [], []
        reconfigs = []
        frag = []
        for src in DURATION_SOURCES:
            for seed in seeds:
                cat = TraceCategory(src, size_dist, "train")
                jobs = generate_trace(cat, seed=seed, double=True,
                                      max_size=4)
                fm = simulate(jobs, "FM", policy="fifo")
                dm = simulate(jobs, "DM", policy="fifo")
                sm = simulate(jobs, "SM", policy="fifo")
                fm_dm.append(ModeComparison.of(fm, dm))
                fm_sm.append(ModeComparison.of(fm, sm))
                reconfigs.append(dm.n_reconfigs)
                frag.append(dm.avg_ext_frag_delay * len(jobs)
                            / max(dm.makespan, 1e-9))
        out[size_dist] = {
            "fm_dm": summarize(fm_dm),
            "fm_sm": summarize(fm_sm),
            "dm_reconfigs_mean": float(np.mean(reconfigs)),
            "dm_frag_frac": float(np.mean(frag)),
        }
    return out


def main() -> None:
    us = time_fn(lambda: run(seeds=(0,)), warmup=0, iters=1)
    out = run()
    for sd, o in out.items():
        emit(f"fig7_{sd}", us / 3,
             f"FMvDM_makespan={o['fm_dm']['makespan_ratio_mean']:.3f};"
             f"FMvDM_wait={o['fm_dm']['wait_ratio_mean']:.3f};"
             f"FMvDM_jct={o['fm_dm']['jct_ratio_mean']:.3f};"
             f"FMvSM_makespan={o['fm_sm']['makespan_ratio_mean']:.3f};"
             f"dm_reconfigs={o['dm_reconfigs_mean']:.1f}")


if __name__ == "__main__":
    main()
