"""Multi-tenant cluster benchmark: co-scheduled jobs, measured repacks.

Three stages, mirroring ``elastic_bench`` one level up the stack:

1. **Measured cluster run** (subprocess-per-segment, shared fake-device
   pool): :class:`repro.cluster.ClusterRuntime` co-schedules the
   canonical 3-job / 2-tenant contention scenario over a 2x4 pool with
   per-tenant quotas — a single-host-pinned tier-0 arrival forces a
   *defrag* repack of the long job, and its departure triggers a
   *rebalance* repack back.  Every job's stitched losses are asserted
   *bitwise identical* to an uninterrupted single-segment reference of
   the same width (the factorization-invariance guarantee, now crossing
   process and placement boundaries).

2. **Calibration**: the stitched per-boundary handoff measurements
   (committed save -> reshard restore -> recompile, keyed by state
   bytes and rank count) calibrate a
   :class:`repro.core.jct_model.ReconfigCostModel`;
   :func:`repro.core.jct_model.summarize_by_size` reports the per-size
   medians.

3. **Trace replay**: the fig7 (philly/balanced/train/fifo) category
   replays under DM with the drain cost model vs. the cluster-measured
   handoff model.

Writes ``BENCH_cluster.json`` (checked by ``scripts/check_bench.py`` in
CI) and emits the usual ``name,us,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(REPO, "BENCH_cluster.json")
POOL = (2, 4)                      # hosts x devices_per_host
QUOTAS = {"beta": 6}

REPLAY_TRACE = ("fig7_philly_balanced_train_fifo", "philly", "balanced",
                "train", "fifo")


def _specs(quick: bool):
    """The contention scenario.  Full mode lengthens j1 to two segments
    so a width-2 boundary measurement exists (multi-size calibration);
    quick keeps j1 single-segment so its early departure pins the
    defrag to j0's first boundary (the CI-smoke-validated timing)."""
    from repro.cluster import ClusterJobSpec
    from repro.core.job import TIER_HIGH
    return [
        ClusterJobSpec("j0", size=4, n_steps=12 if quick else 15,
                       segment_steps=3, tenant="acme"),
        ClusterJobSpec("j1", size=2, n_steps=2 if quick else 4,
                       segment_steps=2, tenant="beta"),
        ClusterJobSpec("j2", size=4, n_steps=2, segment_steps=2,
                       tenant="beta", priority_tier=TIER_HIGH,
                       after="j1"),
    ]


def _reference_losses(spec, work_dir: str, timeout_s: float = 600.0):
    """Uninterrupted single-segment run of one job (same width, the
    (1, size) factorization — bitwise equality with the repacked
    cluster run is exactly the invariant under test)."""
    import time

    from repro.cluster import JobManager

    ref = dataclasses.replace(spec, job_id=spec.job_id + "_ref",
                              segment_steps=spec.n_steps, after=None)
    m = JobManager(ref, work_dir)
    m.launch((1, ref.size))
    t0 = time.monotonic()
    while True:
        ev = m.poll()
        if ev is not None:
            break
        if time.monotonic() - t0 > timeout_s:
            raise RuntimeError(f"{ref.job_id}: reference run timed out")
        time.sleep(0.1)
    kind, payload = ev
    if kind != "ok":
        raise RuntimeError(f"{ref.job_id}: reference run died "
                           f"(rc={payload})\n{m.tail_log()}")
    return payload.losses


def _inner(out_path: str, quick: bool) -> None:
    """Measured part: the cluster run plus per-job references."""
    import shutil
    import tempfile

    from repro.cluster import ClusterRuntime, DevicePool
    from repro.core.scheduler import Scheduler

    class RecordingScheduler(Scheduler):
        """Scheduler that also records the peak per-tenant usage it was
        shown — the quota invariant is then checked on observations,
        not assumed."""

        def __init__(self, *a, **k):
            super().__init__(*a, **k)
            self.max_usage = {}

        def candidates(self, queue, usage=None):
            for t, n in (usage or {}).items():
                self.max_usage[t] = max(self.max_usage.get(t, 0), n)
            return super().candidates(queue, usage=usage)

    specs = _specs(quick)
    sched = RecordingScheduler("backfill", depth=8, quotas=QUOTAS)
    base = tempfile.mkdtemp(prefix="cluster_bench_")
    try:
        rt = ClusterRuntime(specs, pool=DevicePool(*POOL),
                            base_dir=base, scheduler=sched,
                            timeout_s=1500.0)
        res = rt.run()
        refs = {s.job_id: _reference_losses(s, base) for s in specs}
    finally:
        shutil.rmtree(base, ignore_errors=True)

    out = {
        "pool": {"n_hosts": POOL[0], "devices_per_host": POOL[1]},
        "quotas": QUOTAS,
        "specs": [{"job_id": s.job_id, "size": s.size,
                   "n_steps": s.n_steps,
                   "segment_steps": s.segment_steps,
                   "tenant": s.tenant,
                   "priority_tier": s.priority_tier,
                   "after": s.after} for s in specs],
        "wall_s": res.wall_s,
        "repacks": [r.to_dict() for r in res.repacks],
        "measurements": res.measurements,
        "max_usage": sched.max_usage,
        "jobs": {jid: {"losses": o.losses,
                       "shapes": [list(s) for s in o.shapes],
                       "segments": len(o.segments),
                       "restarts": o.restarts,
                       "losses_ref": refs[jid],
                       "bitwise": o.losses == refs[jid]}
                 for jid, o in res.jobs.items()},
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"WROTE {out_path}")


def _replay(cost_model, quick: bool) -> dict:
    """fig7 replay: DM drained vs DM with the cluster-measured model."""
    import numpy as np

    from repro.core.simulator import simulate
    from repro.core.traces import TraceCategory, generate_trace

    label, src, size_dist, mix, policy = REPLAY_TRACE
    seeds = (0,) if quick else (0, 1, 2)
    rows = []
    for seed in seeds:
        jobs = generate_trace(TraceCategory(src, size_dist, mix),
                              seed=seed, double=True, max_size=4)
        dm_drain = simulate(jobs, "DM", policy=policy)
        dm_handoff = simulate(jobs, "DM", policy=policy,
                              reconfig_mode="handoff",
                              reconfig_cost=cost_model)
        delta = ((dm_drain.makespan - dm_handoff.makespan)
                 / max(dm_drain.makespan, 1e-9))
        rows.append({
            "seed": seed,
            "dm_drain_makespan": dm_drain.makespan,
            "dm_handoff_makespan": dm_handoff.makespan,
            "makespan_delta_frac": delta,
            "drain_cost_s": dm_drain.drain_cost_s,
            "handoff_cost_s": dm_handoff.handoff_cost_s,
        })
    return {
        label: {"runs": rows},
        "makespan_delta_mean": float(np.mean(
            [r["makespan_delta_frac"] for r in rows])),
    }


def main(quick: bool = False, out_path: str = DEFAULT_OUT) -> None:
    from benchmarks.common import emit
    from repro.core.jct_model import ReconfigCostModel, summarize_by_size

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, "-m", "benchmarks.cluster_bench", "--inner",
           "--out", out_path] + (["--quick"] if quick else [])
    res = subprocess.run(cmd, capture_output=True, text=True,
                         timeout=2400, env=env, cwd=REPO)
    if res.returncode != 0:
        raise RuntimeError(f"cluster bench inner failed:\n"
                           f"{res.stderr[-4000:]}")
    with open(out_path) as f:
        measured = json.load(f)

    cm = ReconfigCostModel.from_measurements(measured["measurements"])
    by_size = summarize_by_size(measured["measurements"])
    replay = _replay(cm, quick)

    reasons = [r["reason"] for r in measured["repacks"]]
    sizes_measured = sorted({int(m["n_ranks"])
                             for m in measured["measurements"]})
    all_bitwise = all(j["bitwise"] for j in measured["jobs"].values())
    quota_ok = all(measured["max_usage"].get(t, 0) <= q
                   for t, q in measured["quotas"].items())
    # quick keeps j1 single-segment (see _specs), so only the width-4
    # boundaries exist there — the multi-size gate binds in full mode
    cover = set(sizes_measured) >= {2, 4} or quick
    acceptance = {
        "all_bitwise": bool(all_bitwise),
        "n_repacks_ge_2": len(measured["repacks"]) >= 2,
        "defrag_repack_seen": "defrag" in reasons,
        "quota_never_exceeded": bool(quota_ok),
        "measurements_cover_sizes": bool(cover),
        "sizes_measured": sizes_measured,
        "repack_reasons": reasons,
        "pass": bool(all_bitwise and len(measured["repacks"]) >= 2
                     and "defrag" in reasons and quota_ok and cover),
    }

    out = {
        "quick": quick,
        "pool": measured["pool"],
        "driver": measured,
        "measurements": measured["measurements"],
        "repacks": measured["repacks"],
        "cost_model": {
            "mode": cm.mode,
            "save_bps": cm.save_bps,
            "restore_bps": cm.restore_bps,
            "recompile_s": cm.recompile_s,
            "coord_s": cm.coord_s,
            "by_size": by_size,
        },
        "replay": replay,
        "acceptance": acceptance,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)

    for m in measured["measurements"]:
        emit(f"cluster_handoff_{m['job_id']}_step{m['step']}",
             (m["save_s"] + m["restore_s"] + m["setup_s"]
              + m["compile_s"]) * 1e6,
             f"{tuple(m['from_shape'])}->{tuple(m['to_shape'])};"
             f"repack={m['repack']};save={m['save_s']:.3f}s;"
             f"restore={m['restore_s']:.3f}s")
    for r in measured["repacks"]:
        emit(f"cluster_repack_{r['job_id']}_{r['reason']}", 0.0,
             f"at={r['at_step']};{tuple(r['from_shape'])}->"
             f"{tuple(r['to_shape'])};admits={r['requested_by']}")
    emit("cluster_cost_model", 0.0,
         f"save_bps={cm.save_bps:.3g};restore_bps={cm.restore_bps:.3g};"
         f"recompile_s={cm.recompile_s:.2f};sizes={sizes_measured}")
    emit("cluster_run", measured["wall_s"] * 1e6,
         f"jobs={len(measured['jobs'])};repacks="
         f"{len(measured['repacks'])};bitwise={all_bitwise};"
         f"pass={acceptance['pass']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.inner:
        _inner(args.out, args.quick)
    else:
        main(args.quick, args.out)
