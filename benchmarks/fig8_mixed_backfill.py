"""Fig. 8: FM vs DM, aggressive backfilling, all type mixes."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.metrics import ModeComparison, summarize
from repro.core.simulator import simulate
from repro.core.traces import TraceCategory, generate_trace


def run(seeds=(0, 1, 2)) -> dict:
    out = {}
    for size_dist in ("small", "balanced", "large"):
        comps = []
        for mix in ("train", "inference", "mixed"):
            for seed in seeds:
                cat = TraceCategory("helios_earth", size_dist, mix)
                jobs = generate_trace(cat, seed=seed, double=True)
                fm = simulate(jobs, "FM", policy="backfill")
                dm = simulate(jobs, "DM", policy="backfill")
                comps.append(ModeComparison.of(fm, dm))
        s = summarize(comps)
        jcts = [c.jct_ratio for c in comps]
        s["jct_le_1.10_frac"] = float(np.mean([j <= 1.10 for j in jcts]))
        out[size_dist] = s
    return out


def main() -> None:
    us = time_fn(lambda: run(seeds=(0,)), warmup=0, iters=1)
    out = run()
    for sd, s in out.items():
        emit(f"fig8_{sd}", us / 3,
             f"makespan={s['makespan_ratio_mean']:.3f};"
             f"wait={s['wait_ratio_mean']:.3f};"
             f"jct={s['jct_ratio_mean']:.3f};"
             f"util={s['util_ratio_mean']:.3f}")


if __name__ == "__main__":
    main()
