"""Scheduling bake-off at fleet scale -> ``BENCH_sched.json``.

Fig. 7/8-style policy matrix over (policy, trace) cells:

- **policies**: FM one-to-many (paper default), FM with
  fragmentation-aware placement (``placement="frag_aware"``,
  arXiv 2512.16099 / 2511.18906 scoring), each under FIFO and
  aggressive backfilling; DM and SM under FIFO as the incumbent
  baselines;
- **traces**: the paper's philly/helios figure traces
  (:func:`repro.core.traces.generate_trace`) plus synthetic
  fleet-scale traces (:func:`repro.core.traces.generate_fleet_trace`:
  heavy-tailed Pareto interarrivals, mixed train+serve, multi-tenant
  labels) at 16x the figure host count.

Each cell reports makespan / avg JCT / avg wait / fragmentation
(time-averaged stranded-fragment score, the quantity frag-aware
placement minimizes) / utilization, plus simulator throughput
(events/sec).  The fleet section carries the simulator-scale tripwire:
a >= 1M-event trace must simulate inside ``FLEET_BUDGET_S`` wall-clock
— quick mode includes it, so CI catches superlinear regressions in the
event loop, not just correctness bugs.

Usage: ``python -m benchmarks.sched_bench [--quick] [--out PATH]``.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.common import emit
from repro.core.simulator import simulate
from repro.core.traces import (TraceCategory, generate_fleet_trace,
                               generate_trace)

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_sched.json")

# (cell name, simulate kwargs).  SM only supports sizes <= 4, which the
# figure traces guarantee via max_size=4; fleet traces go up to size 8,
# so the fleet section restricts itself to the FM cells.
CELLS: Tuple[Tuple[str, Dict[str, str]], ...] = (
    ("fm/fifo", {"mode": "FM", "policy": "fifo"}),
    ("fm/backfill", {"mode": "FM", "policy": "backfill"}),
    ("fm-frag/fifo", {"mode": "FM", "policy": "fifo",
                      "placement": "frag_aware"}),
    ("fm-frag/backfill", {"mode": "FM", "policy": "backfill",
                          "placement": "frag_aware"}),
    ("dm/fifo", {"mode": "DM", "policy": "fifo"}),
    ("sm/fifo", {"mode": "SM", "policy": "fifo"}),
)
FLEET_CELLS = ("fm/fifo", "fm/backfill", "fm-frag/fifo",
               "fm-frag/backfill")

# figure-trace families: (name, source, seed)
FAMILIES: Tuple[Tuple[str, str, int], ...] = (
    ("philly", "philly", 7),
    ("helios_earth", "helios_earth", 7),
)

N_HOSTS = 4                 # bake-off table hosts (host choice matters)
FLEET_N_HOSTS = 64          # 16x the figure scale
FLEET_N_JOBS = 20_000       # per fleet policy cell
FLEET_SEED = 11
TRIPWIRE_N_JOBS = 500_000   # >= 1M events (arrival+finish per job)
TRIPWIRE_N_HOSTS = 32
FLEET_BUDGET_S = 240.0      # CI wall-clock budget for the tripwire


def _run_cell(jobs, spec: Dict[str, str], n_hosts: int) -> Dict[str, float]:
    kw = dict(spec)
    mode = kw.pop("mode")
    t0 = time.perf_counter()
    res = simulate(jobs, mode, n_hosts=n_hosts, **kw)
    wall = time.perf_counter() - t0
    return {
        "makespan_s": res.makespan,
        "avg_jct_s": res.avg_jct,
        "avg_wait_s": res.avg_wait,
        "avg_frag_slices": res.avg_frag_slices,
        "frag_slice_seconds": res.frag_slice_seconds,
        "utilization": res.utilization,
        "n_jobs": res.n_jobs,
        "n_completed": len(res.jct_by_job),
        "n_events": res.n_events,
        "events_per_s": res.n_events / wall if wall > 0 else 0.0,
        "wall_s": wall,
    }


def run(quick: bool = False) -> dict:
    double = not quick
    table: Dict[str, Dict[str, Dict[str, float]]] = {}
    for family, source, seed in FAMILIES:
        cat = TraceCategory(source, "balanced", "mixed")
        jobs = generate_trace(cat, seed=seed, double=double, max_size=4)
        table[family] = {name: _run_cell(jobs, spec, N_HOSTS)
                         for name, spec in CELLS}

    # fleet-scale synthetic family (FM cells only: sizes reach 8)
    fleet_jobs = generate_fleet_trace(
        FLEET_N_JOBS if quick else 2 * FLEET_N_JOBS, seed=FLEET_SEED,
        mean_interarrival=10.0)
    fleet_table = {name: _run_cell(fleet_jobs, spec, FLEET_N_HOSTS)
                   for name, spec in CELLS if name in FLEET_CELLS}
    table["fleet"] = fleet_table

    # simulator-throughput tripwire: >= 1M events under the CI budget.
    # This is the guard on the event-loop hardening — before it, this
    # trace took ~30 minutes (572 events/s and degrading); hardened it
    # runs in ~35 s (~30k events/s, flat in trace length).
    trip_jobs = generate_fleet_trace(TRIPWIRE_N_JOBS, seed=FLEET_SEED)
    trip = _run_cell(trip_jobs, {"mode": "FM", "policy": "fifo"},
                     TRIPWIRE_N_HOSTS)

    frag_beats_fifo = {
        family: (cells["fm-frag/fifo"]["avg_frag_slices"]
                 < cells["fm/fifo"]["avg_frag_slices"])
        for family, cells in table.items()
    }
    all_complete = all(c["n_completed"] == c["n_jobs"]
                      for cells in table.values() for c in cells.values())
    acceptance = {
        # frag-aware placement must beat default FM on the fragmentation
        # metric it optimizes for at least one trace family
        "frag_aware_beats_fifo_somewhere": any(frag_beats_fifo.values()),
        "all_jobs_complete": all_complete,
        "tripwire_ge_1m_events": trip["n_events"] >= 1_000_000,
        "tripwire_all_complete": trip["n_completed"] == trip["n_jobs"],
        "tripwire_under_budget": trip["wall_s"] <= FLEET_BUDGET_S,
    }
    return {
        "matrix": {
            "cells": [name for name, _ in CELLS],
            "fleet_cells": list(FLEET_CELLS),
            "families": [f for f, _, _ in FAMILIES] + ["fleet"],
            "n_hosts": N_HOSTS,
            "fleet_n_hosts": FLEET_N_HOSTS,
            "quick": quick,
        },
        "table": table,
        "fleet": {
            "tripwire": trip,
            "tripwire_n_jobs": TRIPWIRE_N_JOBS,
            "tripwire_n_hosts": TRIPWIRE_N_HOSTS,
            "budget_s": FLEET_BUDGET_S,
            "frag_beats_fifo_by_family": frag_beats_fifo,
        },
        "acceptance": acceptance,
    }


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller figure traces + one fleet cell size "
                         "(the CI sched-bakeoff configuration)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    for family, cells in out["table"].items():
        for name, c in cells.items():
            emit(f"sched_{family}_{name}", c["wall_s"] * 1e6,
                 f"makespan={c['makespan_s']:.0f};"
                 f"jct={c['avg_jct_s']:.0f};"
                 f"wait={c['avg_wait_s']:.0f};"
                 f"frag={c['avg_frag_slices']:.2f};"
                 f"util={c['utilization']:.3f};"
                 f"ev_s={c['events_per_s']:.0f}")
    trip = out["fleet"]["tripwire"]
    emit("sched_fleet_tripwire", trip["wall_s"] * 1e6,
         f"events={trip['n_events']};ev_s={trip['events_per_s']:.0f};"
         f"budget_s={out['fleet']['budget_s']:.0f}")
    if not all(out["acceptance"].values()):
        raise SystemExit(f"sched_bench acceptance failed: "
                         f"{out['acceptance']}")


if __name__ == "__main__":
    main()
