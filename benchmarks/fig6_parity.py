"""Fig. 6: simulator-vs-measurement parity (calibration procedure §5.2).

The 'real testbed' stand-in is the simulator with stochastic concurrency
interference (x1.03-1.09); the simulator under test uses the constant
x1.06 factor.  We reproduce the paper's finding: uncalibrated simulation
underestimates makespan/JCT; after calibration the parity error collapses.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.simulator import simulate
from repro.core.traces import ALL_CATEGORIES, generate_trace


def run(n_cats: int = 12, seeds=(0, 1, 2)) -> dict:
    rows = []
    for cat in ALL_CATEGORIES[:n_cats]:
        for seed in seeds:
            jobs = generate_trace(cat, seed=seed, max_size=4)
            real = simulate(jobs, "FM", ground_truth=True, seed=seed)
            raw = simulate(jobs, "FM", calibrate=False)
            cal = simulate(jobs, "FM", calibrate=True)
            rows.append((real.makespan, raw.makespan, cal.makespan,
                         real.avg_jct, raw.avg_jct, cal.avg_jct))
    r = np.array(rows)
    out = {
        "makespan_bias_uncal": float(np.mean(r[:, 1] / r[:, 0] - 1)),
        "makespan_bias_cal": float(np.mean(r[:, 2] / r[:, 0] - 1)),
        "jct_bias_uncal": float(np.mean(r[:, 4] / r[:, 3] - 1)),
        "jct_bias_cal": float(np.mean(r[:, 5] / r[:, 3] - 1)),
        "parity_r2_cal": float(np.corrcoef(r[:, 0], r[:, 2])[0, 1] ** 2),
    }
    return out


def main() -> None:
    us = time_fn(lambda: run(n_cats=2, seeds=(0,)), warmup=0, iters=1)
    out = run()
    emit("fig6_parity", us,
         f"uncal_bias={out['makespan_bias_uncal']:+.3f};"
         f"cal_bias={out['makespan_bias_cal']:+.3f};"
         f"r2={out['parity_r2_cal']:.4f}")


if __name__ == "__main__":
    main()
