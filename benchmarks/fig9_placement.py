"""Fig. 9: normalized JCT of size-6 workloads vs physical placement split
(3-3 ... 6-0) — the evidence behind topology-aware placement."""
from __future__ import annotations

from benchmarks.common import emit, time_fn
from repro.core.jct_model import PlacementView, iteration_time

SPLITS = [(3, 3), (4, 2), (5, 1), (6, 0)]


def run(model: str = "bert-base", batch: int = 32) -> dict:
    times = {}
    for split in SPLITS:
        per = tuple(s for s in split if s > 0)
        v = PlacementView(("1g.5gb",) * 6, per, "SHM")
        times[f"{split[0]}-{split[1]}"] = iteration_time(
            model, batch, v, train=True)
    base = times["3-3"]
    return {k: t / base for k, t in times.items()}


def main() -> None:
    us = time_fn(lambda: run(), warmup=0, iters=3)
    for model in ("efficientnet-b2", "distilbert", "bert-base",
                  "t5-small"):
        norm = run(model)
        emit(f"fig9_{model}", us,
             ";".join(f"{k}={v:.3f}" for k, v in norm.items()))


if __name__ == "__main__":
    main()
