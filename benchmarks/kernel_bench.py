"""Kernel microbenchmarks (interpret mode on CPU: correctness-path timing;
the derived column reports kernel-vs-jnp-ref output agreement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import ssd
from repro.kernels.mamba_scan.ref import ssd_ref
from repro.kernels.mlstm.ops import mlstm
from repro.kernels.mlstm.ref import mlstm_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def main() -> None:
    ks = jax.random.split(jax.random.key(0), 5)

    B, S, H, Kv, D = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kv, D))
    v = jax.random.normal(ks[2], (B, S, Kv, D))
    f = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=True, block_q=64, block_k=64))
    us = time_fn(lambda: jax.block_until_ready(f(q, k, v)))
    err = float(jnp.max(jnp.abs(
        f(q, k, v) - attention_ref(q, k, v, causal=True))))
    emit("kernel_flash_attention", us, f"max_err_vs_ref={err:.2e}")

    T, Hh, P, G, N = 256, 2, 32, 1, 16
    x = jax.random.normal(ks[0], (B, T, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Hh)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, G, N))
    Cm = jax.random.normal(ks[4], (B, T, G, N))
    g = jax.jit(lambda *a: ssd(*a, chunk=64))
    us = time_fn(lambda: jax.block_until_ready(g(x, dt, A, Bm, Cm)[0]))
    err = float(jnp.max(jnp.abs(
        g(x, dt, A, Bm, Cm)[0] - ssd_ref(x, dt, A, Bm, Cm)[0])))
    emit("kernel_mamba_scan", us, f"max_err_vs_ref={err:.2e}")

    Dm = 32
    qm = jax.random.normal(ks[0], (B, T, Hh, Dm))
    km = jax.random.normal(ks[1], (B, T, Hh, Dm))
    vm = jax.random.normal(ks[2], (B, T, Hh, Dm))
    ir = jax.random.normal(ks[3], (B, T, Hh)) * 2
    fr = jax.random.normal(ks[4], (B, T, Hh)) * 2 + 3
    h = jax.jit(lambda *a: mlstm(*a, chunk=64))
    us = time_fn(lambda: jax.block_until_ready(
        h(qm, km, vm, ir, fr)[0]))
    err = float(jnp.max(jnp.abs(
        h(qm, km, vm, ir, fr)[0] - mlstm_ref(qm, km, vm, ir, fr)[0])))
    emit("kernel_mlstm", us, f"max_err_vs_ref={err:.2e}")

    xr = jax.random.normal(ks[0], (512, 768), jnp.bfloat16)
    wr = jnp.ones((768,), jnp.float32)
    r = jax.jit(rmsnorm)
    us = time_fn(lambda: jax.block_until_ready(r(xr, wr)))
    err = float(jnp.max(jnp.abs(
        (r(xr, wr) - rmsnorm_ref(xr, wr)).astype(jnp.float32))))
    emit("kernel_rmsnorm", us, f"max_err_vs_ref={err:.2e}")


if __name__ == "__main__":
    main()
