"""Kernel microbenchmarks (interpret mode on CPU: correctness-path timing;
the derived column reports kernel-vs-jnp-ref output agreement).

One table-driven loop; the warmup call's output is reused for the error
column instead of recomputing each jitted kernel a second time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import ssd
from repro.kernels.mamba_scan.ref import ssd_ref
from repro.kernels.mlstm.ops import mlstm
from repro.kernels.mlstm.ref import mlstm_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _cases():
    ks = jax.random.split(jax.random.key(0), 5)
    B = 1

    S, H, Kv, D = 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kv, D))
    v = jax.random.normal(ks[2], (B, S, Kv, D))

    T, Hh, P, G, N = 256, 2, 32, 1, 16
    x = jax.random.normal(ks[0], (B, T, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Hh)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, G, N))
    Cm = jax.random.normal(ks[4], (B, T, G, N))

    Dm = 32
    qm = jax.random.normal(ks[0], (B, T, Hh, Dm))
    km = jax.random.normal(ks[1], (B, T, Hh, Dm))
    vm = jax.random.normal(ks[2], (B, T, Hh, Dm))
    ir = jax.random.normal(ks[3], (B, T, Hh)) * 2
    fr = jax.random.normal(ks[4], (B, T, Hh)) * 2 + 3

    xr = jax.random.normal(ks[0], (512, 768), jnp.bfloat16)
    wr = jnp.ones((768,), jnp.float32)

    # (name, jitted fn, args, ref fn, pick-primary-output)
    first = lambda o: o[0]
    ident = lambda o: o
    return [
        ("kernel_flash_attention",
         jax.jit(lambda q, k, v: flash_attention(
             q, k, v, causal=True, block_q=64, block_k=64)),
         (q, k, v),
         lambda q, k, v: attention_ref(q, k, v, causal=True), ident),
        ("kernel_mamba_scan",
         jax.jit(lambda *a: ssd(*a, chunk=64)),
         (x, dt, A, Bm, Cm), ssd_ref, first),
        ("kernel_mlstm",
         jax.jit(lambda *a: mlstm(*a, chunk=64)),
         (qm, km, vm, ir, fr), mlstm_ref, first),
        ("kernel_rmsnorm", jax.jit(rmsnorm), (xr, wr), rmsnorm_ref, ident),
    ]


def main() -> None:
    for name, fn, args, ref_fn, pick in _cases():
        out = jax.block_until_ready(fn(*args))     # compile + warmup
        us = time_fn(lambda: jax.block_until_ready(fn(*args)), warmup=0)
        ref = pick(ref_fn(*args))
        err = float(jnp.max(jnp.abs(
            (pick(out) - ref).astype(jnp.float32))))
        emit(name, us, f"max_err_vs_ref={err:.2e}")


if __name__ == "__main__":
    main()
