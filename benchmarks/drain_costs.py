"""§2.3.3: drain-required reconfiguration cost structure (C4/I3) and its
rate across size distributions (the '~14 vs ~5 reconfigs' observation)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.jct_model import ReconfigCostModel, ckpt_state_bytes
from repro.core.modes import (CKPT_LOAD_S, CKPT_SAVE_S, POD_CHURN_S,
                              RECONFIGURE_S, ReconfigPlan)
from repro.core.job import Job
from repro.core.simulator import simulate
from repro.core.traces import TraceCategory, generate_trace


def run(seeds=(0, 1, 2)) -> dict:
    out = {"reconfigure_s": RECONFIGURE_S,
           "ckpt_s": CKPT_SAVE_S + CKPT_LOAD_S,
           "pod_churn_s": POD_CHURN_S}
    for sd in ("small", "balanced", "large"):
        counts = []
        for seed in seeds:
            jobs = generate_trace(
                TraceCategory("philly", sd, "train"), seed=seed,
                double=True, max_size=4)
            counts.append(simulate(jobs, "DM").n_reconfigs)
        out[f"reconfigs_{sd}"] = float(np.mean(counts))
    j = Job("x", "bert-base", "train", 2, 32, 1000.0)
    plan = ReconfigPlan(0, 0, j, ("a", "b"))
    out["example_drain_s"] = plan.duration
    # the same event priced as a software-coordinated handoff (default
    # calibration; benchmarks/elastic_bench.py replaces it with measured
    # save/restore/recompile wallclock)
    cm = ReconfigCostModel(mode="handoff")
    out["example_handoff_s"] = cm.job_suspension_s(
        ckpt_state_bytes("bert-base"), drain_s=plan.duration,
        n_ranks_old=j.size, n_ranks_new=j.size)
    return out


def main() -> None:
    us = time_fn(lambda: run(seeds=(0,)), warmup=0, iters=1)
    o = run()
    emit("drain_costs", us,
         f"reconfigure_s={o['reconfigure_s']:.0f};"
         f"2job_drain_s={o['example_drain_s']:.0f};"
         f"2job_handoff_s={o['example_handoff_s']:.1f};"
         f"reconfigs_small={o['reconfigs_small']:.1f};"
         f"reconfigs_balanced={o['reconfigs_balanced']:.1f};"
         f"reconfigs_large={o['reconfigs_large']:.1f}")


if __name__ == "__main__":
    main()
