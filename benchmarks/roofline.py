"""Roofline table: reads artifacts/dryrun/*.json (written by
repro.launch.dryrun) and prints the per-cell three-term analysis."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(art_dir: Optional[str] = None,
               mesh: str = "16x16", tag: str = "") -> List[Dict]:
    cells = []
    for fn in sorted(glob.glob(os.path.join(art_dir or ART, "*.json"))):
        with open(fn) as f:
            meta = json.load(f)
        parts = os.path.basename(fn)[:-5].split("__")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if meta.get("mesh") != mesh or cell_tag != tag:
            continue
        cells.append(meta)
    return cells


def fraction(meta: Dict) -> float:
    """Roofline fraction: useful-compute time / dominant-term time."""
    r = meta["roofline"]
    useful_s = (meta["model_flops"] / meta["n_chips"]) / 197e12
    return useful_s / max(r["bound_s"], 1e-12)


def main() -> None:
    cells = load_cells()
    if not cells:
        emit("roofline", 0.0, "no_dryrun_artifacts_found")
        return
    for meta in cells:
        if meta.get("status") == "skipped":
            emit(f"roofline_{meta['arch']}_{meta['shape']}", 0.0,
                 "skipped")
            continue
        if meta.get("status") != "ok":
            emit(f"roofline_{meta['arch']}_{meta['shape']}", 0.0,
                 f"error={meta.get('error', '?')[:60]}")
            continue
        r = meta["roofline"]
        emit(f"roofline_{meta['arch']}_{meta['shape']}",
             r["bound_s"] * 1e6,
             f"dom={r['dominant']};"
             f"compute_s={r['compute_s']:.4f};"
             f"memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"useful_ratio={r['useful_ratio']:.3f};"
             f"roofline_frac={fraction(meta):.4f};"
             f"fits16GB={meta['memory']['fits_16gb']}")


if __name__ == "__main__":
    main()
