"""Table 1-driven job-level measurements: the size-aware prioritization
evidence (1g.10gb 10-30% faster for size-1; no benefit when mixed)."""
from __future__ import annotations

from benchmarks.common import emit, time_fn
from repro.core.jct_model import (WORKLOADS, PlacementView,
                                  iteration_time)


def run() -> dict:
    out = {}
    for name in WORKLOADS:
        t5 = iteration_time(name, 64, PlacementView(
            ("1g.5gb",), (1,), "NONE"), train=True)
        t10 = iteration_time(name, 64, PlacementView(
            ("1g.10gb",), (1,), "NONE"), train=True)
        pure = iteration_time(name, 64, PlacementView(
            ("1g.5gb",) * 2, (1, 1), "SHM"), train=True)
        mixed = iteration_time(name, 64, PlacementView(
            ("1g.5gb", "1g.10gb"), (1, 1), "SHM"), train=True)
        out[name] = {"boost_10gb": t5 / t10, "mixed_gain": pure / mixed}
    return out


def main() -> None:
    us = time_fn(run, warmup=0, iters=3)
    for name, o in run().items():
        emit(f"table1_{name}", us,
             f"size1_10gb_speedup={o['boost_10gb']:.3f};"
             f"mixed_vs_pure={o['mixed_gain']:.3f}")


if __name__ == "__main__":
    main()
