"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Roofline rows read the dry-run
artifacts (run ``python -m repro.launch.dryrun --all --both-meshes``
first for the full table).
"""
from __future__ import annotations

import traceback

from benchmarks import (ckpt_bench, cluster_bench, drain_costs,
                        elastic_bench, fault_bench, fig6_parity,
                        fig7_train_fifo, fig8_mixed_backfill,
                        fig9_placement, fig10_transport,
                        fig11_allreduce_bw, grad_sync_bench,
                        kernel_bench, roofline, sched_bench,
                        table1_workloads)

MODULES = [
    ("table1_workloads", table1_workloads),
    ("drain_costs", drain_costs),
    ("fig6_parity", fig6_parity),
    ("fig7_train_fifo", fig7_train_fifo),
    ("fig8_mixed_backfill", fig8_mixed_backfill),
    ("fig9_placement", fig9_placement),
    ("fig10_transport", fig10_transport),
    ("fig11_allreduce_bw", fig11_allreduce_bw),
    ("grad_sync_bench", grad_sync_bench),
    ("ckpt_bench", ckpt_bench),
    ("elastic_bench", elastic_bench),
    ("cluster_bench", cluster_bench),
    ("fault_bench", fault_bench),
    ("sched_bench", sched_bench),
    ("kernel_bench", kernel_bench),
    ("roofline", roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        try:
            mod.main()
        except Exception as e:                 # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
