"""Elastic reconfiguration benchmark: measured handoff costs, fed back.

Three stages:

1. **Measured handoff** (fake-device subprocess, 4 devices): the real
   :class:`repro.elastic_driver.ElasticDriver` executes a
   (2,2) -> (4,1) -> (1,4) repack schedule — committed sharded save,
   ``plan_elastic_remesh`` handoff, reshard-restore, jit recompile,
   continue — and the run's losses are asserted *bitwise identical* to
   the uninterrupted reference (the PR-4 invariant, now exercised by a
   reconfiguration schedule).  A drain-mode run (legacy gathered
   save/full restore) measures the incumbent cycle on the same state.

2. **Calibration**: the measured save/restore/recompile wallclock
   calibrates a :class:`repro.core.jct_model.ReconfigCostModel` — the
   simulator's handoff price is now a measurement, not an assumption.

3. **Trace replay**: the fig7/fig8 trace categories replay under DM with
   the drain cost model vs. the *measured* handoff cost model, reporting
   the makespan delta software-coordinated handoff buys (FM makespans
   included for reference).

Writes ``BENCH_elastic.json`` (checked by ``scripts/check_bench.py`` in
CI) and emits the usual ``name,us,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(REPO, "BENCH_elastic.json")
ARCH = "llama3.2-1b"
INITIAL_SHAPE = (2, 2)
N_DEVICES = 4

# (step, target factorization): quick = CI smoke, full = the real run
SCHEDULE_QUICK = ((2, (4, 1)), (3, (1, 4)))
SCHEDULE_FULL = ((4, (4, 1)), (8, (1, 4)))
N_STEPS = {"quick": 5, "full": 12}

REPLAY_TRACES = (
    # (label, duration_source, size_dist, type_mix, policy) — the fig7
    # (train/fifo) and fig8 (mixed/backfill) replay paths
    ("fig7_philly_balanced_train_fifo", "philly", "balanced", "train",
     "fifo"),
    ("fig8_helios_balanced_mixed_backfill", "helios_earth", "balanced",
     "mixed", "backfill"),
)


def _inner(out_path: str, quick: bool) -> None:
    """Measured part (runs with forced fake host devices)."""
    import shutil
    import tempfile

    from repro import optim
    from repro.data import DataConfig
    from repro.elastic_driver import ElasticDriver, ReconfigEvent
    from repro.models.registry import build_model, get_config, \
        reduced_config

    sched_spec = SCHEDULE_QUICK if quick else SCHEDULE_FULL
    n_steps = N_STEPS["quick" if quick else "full"]
    schedule = [ReconfigEvent(step=s, mesh_shape=shape)
                for s, shape in sched_spec]

    cfg = reduced_config(get_config(ARCH))
    model = build_model(cfg, remat=False)
    ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                             total_steps=n_steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=8)

    def drive(mode, events):
        base = tempfile.mkdtemp()
        try:
            drv = ElasticDriver(model, ocfg, dcfg, base_dir=base,
                                mode=mode)
            return drv.run(n_steps, events, initial_shape=INITIAL_SHAPE)
        finally:
            shutil.rmtree(base, ignore_errors=True)

    ref = drive("handoff", ())
    handoff = drive("handoff", schedule)
    drain = drive("drain", schedule)

    out = {
        "arch": ARCH,
        "n_steps": n_steps,
        "initial_shape": list(INITIAL_SHAPE),
        "schedule": [{"step": e.step, "mesh_shape": list(e.mesh_shape)}
                     for e in schedule],
        "losses_ref": ref.losses,
        "losses_handoff": handoff.losses,
        "losses_drain": drain.losses,
        "steady_step_s": handoff.steady_step_s,
        "measurements": [m.to_dict() for m in handoff.measurements],
        "drain_measurements": [m.to_dict() for m in drain.measurements],
        "bitwise_continuation": handoff.losses == ref.losses,
        "drain_bitwise": drain.losses == ref.losses,
        "handoffs_verified": all(m.verified
                                 for m in handoff.measurements),
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"WROTE {out_path}")


def _replay(cost_model, quick: bool) -> dict:
    """Trace replays: DM drained vs DM with the measured handoff model."""
    import numpy as np

    from repro.core.simulator import simulate
    from repro.core.traces import TraceCategory, generate_trace

    seeds = (0,) if quick else (0, 1, 2)
    out = {}
    deltas = []
    for label, src, size_dist, mix, policy in REPLAY_TRACES:
        rows = []
        for seed in seeds:
            jobs = generate_trace(TraceCategory(src, size_dist, mix),
                                  seed=seed, double=True, max_size=4)
            dm_drain = simulate(jobs, "DM", policy=policy)
            dm_handoff = simulate(jobs, "DM", policy=policy,
                                  reconfig_mode="handoff",
                                  reconfig_cost=cost_model)
            fm = simulate(jobs, "FM", policy=policy)
            delta = ((dm_drain.makespan - dm_handoff.makespan)
                     / max(dm_drain.makespan, 1e-9))
            rows.append({
                "seed": seed,
                "dm_drain_makespan": dm_drain.makespan,
                "dm_handoff_makespan": dm_handoff.makespan,
                "fm_makespan": fm.makespan,
                "makespan_delta_frac": delta,
                "n_drains": dm_drain.n_drains,
                "n_handoffs": dm_handoff.n_handoffs,
                "drain_cost_s": dm_drain.drain_cost_s,
                "handoff_cost_s": dm_handoff.handoff_cost_s,
            })
            deltas.append(delta)
        out[label] = {
            "runs": rows,
            "makespan_delta_mean": float(np.mean(
                [r["makespan_delta_frac"] for r in rows])),
        }
    out["makespan_delta_mean"] = float(np.mean(deltas))
    return out


def main(quick: bool = False, out_path: str = DEFAULT_OUT) -> None:
    from benchmarks.common import emit
    from repro.core.jct_model import (WORKLOADS, ReconfigCostModel,
                                      ckpt_state_bytes)

    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{N_DEVICES}")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, "-m", "benchmarks.elastic_bench", "--inner",
           "--out", out_path] + (["--quick"] if quick else [])
    res = subprocess.run(cmd, capture_output=True, text=True,
                         timeout=1800, env=env, cwd=REPO)
    if res.returncode != 0:
        raise RuntimeError(f"elastic bench inner failed:\n"
                           f"{res.stderr[-4000:]}")
    with open(out_path) as f:
        measured = json.load(f)

    cm = ReconfigCostModel.from_measurements(measured["measurements"])
    replay = _replay(cm, quick)

    # the claim the calibration must support, checked on the *uncapped*
    # handoff time (job_suspension_s min()s against the drain by
    # construction, so gating on it would be tautological): for the
    # median Table-1 workload, the measured save+restore+recompile beats
    # a 1-job drain outright.  Median, not all: the largest workloads on
    # a slow CI disk legitimately approach the cap, and the cap itself
    # (fall back to draining) is part of the operational model.
    import numpy as np

    from repro.core.modes import (CKPT_LOAD_S, CKPT_SAVE_S, POD_CHURN_S,
                                  RECONFIGURE_S)
    # the 1-job drain duration the simulator actually charges
    # (ReconfigPlan.duration with one affected job)
    drain_ref = RECONFIGURE_S + CKPT_SAVE_S + CKPT_LOAD_S + POD_CHURN_S
    uncapped = sorted(cm.handoff_s(ckpt_state_bytes(w))
                      for w in WORKLOADS)
    handoff_le_drain = bool(
        float(np.median(uncapped)) <= drain_ref + 1e-9)
    frac_below_drain = float(np.mean(
        [u <= drain_ref + 1e-9 for u in uncapped]))

    # the stable signal: total suspension charged to reconfiguring jobs
    # (makespan also improves on average, but individual seeds can
    # reorder under backfill — that is scheduling noise, not cost)
    runs = [r for t in replay.values() if isinstance(t, dict)
            for r in t.get("runs", ())]
    drain_total = sum(r["drain_cost_s"] for r in runs)
    handoff_total = sum(r["handoff_cost_s"] for r in runs)
    charge_reduced = handoff_total < drain_total
    # quick mode replays a single seed per trace — exactly the quantity
    # the per-seed comment above calls scheduling noise — so only the
    # multi-seed full run hard-gates on the makespan direction (quick
    # still reports makespan_delta_mean; check_bench fails on any false
    # acceptance boolean, so the noisy observation must not become one)
    not_worse_gate = (replay["makespan_delta_mean"] >= -0.01) or quick
    acceptance = {
        "bitwise_continuation": bool(measured["bitwise_continuation"]),
        "drain_bitwise": bool(measured["drain_bitwise"]),
        "handoffs_verified": bool(measured["handoffs_verified"]),
        "handoff_cost_le_drain": bool(handoff_le_drain),
        "handoff_frac_below_drain": frac_below_drain,
        "replay_drain_cost_s": drain_total,
        "replay_handoff_cost_s": handoff_total,
        "handoff_charge_reduced": bool(charge_reduced),
        "makespan_delta_mean": replay["makespan_delta_mean"],
        "handoff_not_worse": bool(not_worse_gate),
        "pass": bool(measured["bitwise_continuation"]
                     and measured["drain_bitwise"]
                     and measured["handoffs_verified"]
                     and handoff_le_drain
                     and charge_reduced and not_worse_gate),
    }
    # the drain-mode run grounds the simulator's §2.3.3 checkpoint
    # constants: the measured legacy gathered save+restore cycle is the
    # per-job CKPT_SAVE_S + CKPT_LOAD_S portion of every charged drain
    # (the mig-manager RECONFIGURE_S remains unmeasurable off-hardware)
    drain_cycles = [m["save_s"] + m["restore_s"]
                    for m in measured["drain_measurements"]]
    drain_check = {
        "measured_gathered_cycle_s": drain_cycles,
        "assumed_ckpt_s": CKPT_SAVE_S + CKPT_LOAD_S,
        "measured_over_assumed": [
            c / (CKPT_SAVE_S + CKPT_LOAD_S) for c in drain_cycles],
    }

    out = {
        "quick": quick,
        "driver": measured,
        "measurements": measured["measurements"],
        "drain_check": drain_check,
        "cost_model": {
            "mode": cm.mode,
            "save_bps": cm.save_bps,
            "restore_bps": cm.restore_bps,
            "recompile_s": cm.recompile_s,
            "coord_s": cm.coord_s,
        },
        "replay": replay,
        "acceptance": acceptance,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)

    for m in measured["measurements"]:
        emit(f"elastic_handoff_step{m['step']}",
             (m["save_s"] + m["restore_s"] + m["setup_s"]
              + m["compile_s"]) * 1e6,
             f"{tuple(m['from_shape'])}->{tuple(m['to_shape'])};"
             f"save={m['save_s']:.3f}s;restore={m['restore_s']:.3f}s;"
             f"setup={m['setup_s']:.3f}s;compile={m['compile_s']:.3f}s")
    emit("elastic_cost_model", 0.0,
         f"save_bps={cm.save_bps:.3g};restore_bps={cm.restore_bps:.3g};"
         f"recompile_s={cm.recompile_s:.2f}")
    emit("elastic_replay", 0.0,
         f"makespan_delta={replay['makespan_delta_mean']:.3f};"
         f"bitwise={acceptance['bitwise_continuation']};"
         f"pass={acceptance['pass']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.inner:
        _inner(args.out, args.quick)
    else:
        main(args.quick, args.out)
