"""Gradient-sync benchmark: collective-op counts, slow-axis bytes, step time.

Compares the cross-pod gradient-sync schedules on a (pod, data) mesh:

- ``flat``             single-level psum over both tiers (stock-NCCL
                       workaround baseline);
- ``hier_per_tensor``  hierarchical schedule per gradient leaf (3
                       collectives + pad per tensor — latency-bound);
- ``hier_bucketed``    the schedule once per flat f32 bucket; without
                       compute/comm overlap the optimal bucket size is
                       "everything", so the headline entry fuses the whole
                       gradient set into one bucket and a sweep over
                       bucket sizes shows the curve;
- ``hier_bucketed_int8``  + int8 slow hop;
- ``hier_bucketed_overlap``  the multi-bucket software pipeline
                       (``overlap=True``): bucket i+1's fast reduce-scatter
                       issues under bucket i's slow hop.

Collective-op counts and slow-axis bytes come from the compiled HLO via
``repro.analysis.hlo`` (the Fig. 11 methodology: ``cross_pod_bytes`` is
ring-model traffic crossing the pod cut, ``cross_pod_operand_bytes`` the
payload handed to those ops).  Every entry also runs the
``slow_collective_chains`` dependency checker: ``independent=True``
proves from the lowered HLO that no bucket's slow collective
data-depends on another's — the pipelinability invariant the overlapped
schedule relies on.  ``jct_model`` prices the serial vs pipelined
schedules analytically (``core.jct_model.hier_sync_makespan`` over the
ICI/DCN tier bandwidths): ``serial - overlapped`` is the slow-tier
latency the pipeline hides.  The XLA CPU pipeline does not merge
manual-mode collectives, so the counts are exactly what the schedule
issues.  Step wall-clock times real train steps per ``cross_pod_mode`` on
the reduced config over 8 fake host devices.

Writes ``BENCH_grad_sync.json`` (CI uploads ``BENCH_*.json`` artifacts)
and emits the usual ``name,us,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(REPO, "BENCH_grad_sync.json")
ARCH = "llama3.2-1b"
MESH_SHAPE = (2, 4)                    # (pod, data) over 8 fake devices
BUCKET_MB_SWEEP = (64, 512)


def _inner(quick: bool, out_path: str) -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import optim
    from repro import parallel as PX
    from repro.analysis import ir
    from repro.analysis.hlo import (DCN_BW_PER_CHIP, ICI_BW, analyze,
                                    slow_collective_chains)
    from repro.collectives import bucketing as BK
    from repro.core.jct_model import (bucket_sync_times,
                                      exposed_slow_fraction,
                                      hier_sync_makespan)
    from repro.collectives.hierarchical import (flat_all_reduce_mean,
                                                hier_all_reduce_mean)
    from repro.models.registry import build_model, get_config, \
        reduced_config
    from repro.sharding import make_rules
    from repro.train import make_bucket_layout, make_jitted_train_step
    from benchmarks.common import time_fn

    mesh = jax.make_mesh(MESH_SHAPE, ("pod", "data"))
    n_pod, n_data = MESH_SHAPE

    # ---------------- HLO accounting over the gradient pytree ------------
    cfg = get_config(ARCH)
    if quick:
        cfg = reduced_config(cfg)
    shapes = jax.eval_shape(build_model(cfg).init, jax.random.key(0))
    grads = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), shapes)
    n_leaves = len(jax.tree.leaves(grads))
    total_bytes = sum(4 * math.prod(l.shape)
                      for l in jax.tree.leaves(grads))

    def per_tensor_sync(compress_bits=0, flat=False):
        def fn(g):
            if flat:
                return jax.tree.map(
                    lambda x: flat_all_reduce_mean(
                        x, axes=("pod", "data")), g)
            return jax.tree.map(
                lambda x: hier_all_reduce_mean(
                    x, fast_axis="data", slow_axis="pod",
                    compress_bits=compress_bits), g)
        return fn, None

    def bucketed_sync(bucket_bytes, compress_bits=0, overlap=False):
        layout = BK.plan_buckets(grads, bucket_bytes=bucket_bytes,
                                 align=n_data)

        def fn(g):
            b = BK.flatten_to_buckets(layout, g)
            s = BK.hier_reduce_bucket_shards(
                b, fast_axis="data", slow_axis="pod",
                compress_bits=compress_bits, overlap=overlap)
            full = BK.all_gather_buckets(s, fast_axis="data")
            return BK.unflatten_from_buckets(layout, full,
                                             dtype=jnp.float32)
        return fn, layout

    fuse_all = total_bytes + 4 * n_data          # one bucket for everything
    pipeline_bytes = -(-total_bytes // 4)        # >= 2 buckets to pipeline
    sync_cases = [
        ("flat", per_tensor_sync(flat=True), None),
        ("hier_per_tensor", per_tensor_sync(), None),
        ("hier_bucketed", bucketed_sync(fuse_all), fuse_all),
        ("hier_bucketed_int8", bucketed_sync(fuse_all, compress_bits=8),
         fuse_all),
        ("hier_bucketed_overlap",
         bucketed_sync(pipeline_bytes, overlap=True), pipeline_bytes),
    ] + [(f"hier_bucketed_{mb}mb", bucketed_sync(mb << 20), mb << 20)
         for mb in (() if quick else BUCKET_MB_SWEEP)]

    specs = jax.tree.map(lambda _: P(), grads)
    sync_hlo = {}
    for name, (fn, layout), bucket_bytes in sync_cases:
        jitted = jax.jit(PX.shard_map(
            fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False, axis_names={"pod", "data"}))
        # parse once into the shared IR; both checkers accept a Module
        mod = ir.parse(jitted.lower(grads).compile().as_text())
        st = analyze(mod, chips_per_pod=n_data)
        chain = slow_collective_chains(mod, chips_per_pod=n_data)
        sync_hlo[name] = {
            "collective_ops": st.collective_ops,
            "n_collective_ops": int(sum(st.collective_ops.values())),
            "cross_pod_bytes": st.cross_pod_bytes,
            "cross_pod_operand_bytes": st.cross_pod_operand_bytes,
            "slow_operand_frac": st.cross_pod_operand_bytes / total_bytes,
            "n_buckets": layout.n_buckets if layout else None,
            "bucket_bytes": bucket_bytes,
            "slow_chain": chain.to_dict(),
        }

    # ------------- analytic schedule pricing (serial vs pipelined) --------
    ov_layout = BK.plan_buckets(grads, bucket_bytes=pipeline_bytes,
                                align=n_data)
    stage_times = bucket_sync_times(
        ov_layout.bucket_sizes, nf=n_data, ns=n_pod,
        fast_bps=ICI_BW, slow_bps=DCN_BW_PER_CHIP)
    serial_s = hier_sync_makespan(*stage_times, overlap=False)
    overlapped_s = hier_sync_makespan(*stage_times, overlap=True)
    jct = {
        "n_buckets": ov_layout.n_buckets,
        "bucket_numels": list(ov_layout.bucket_sizes),
        "serial_s": serial_s,
        "overlapped_s": overlapped_s,
        "hidden_slow_s": serial_s - overlapped_s,
        "speedup": serial_s / max(overlapped_s, 1e-12),
        "exposed_slow_frac_serial": exposed_slow_fraction(
            *stage_times, overlap=False),
        "exposed_slow_frac_overlap": exposed_slow_fraction(
            *stage_times, overlap=True),
    }

    # ---------------- step wall-clock on the reduced config --------------
    rcfg = reduced_config(get_config(ARCH))
    model = build_model(rcfg, remat=False)
    rules = make_rules(mesh, fsdp=False)
    B, S = 16, 32
    rng = jax.random.key(1)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0,
                                          rcfg.vocab_size),
             "targets": jax.random.randint(rng, (B, S), 0,
                                           rcfg.vocab_size)}
    ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=5,
                             total_steps=100)
    # 'compressed' is absent: its partial-manual shard_map (auto 'data'
    # inside manual 'pod') trips a fatal XLA check on jax 0.4.37's CPU
    # backend for (pod, data) meshes — same class of crash PR 1 hit with
    # flash-decode, uncatchable from Python
    multibucket = 1 << 20          # several buckets on the reduced config
    step_cases = [("hier", "hier", {}),
                  ("hier_bucketed", "hier_bucketed", {}),
                  ("hier_bucketed_multibucket", "hier_bucketed",
                   {"bucket_bytes": multibucket}),
                  ("hier_bucketed_multibucket_overlap", "hier_bucketed",
                   {"bucket_bytes": multibucket, "overlap": True})]
    if not quick:
        step_cases = ([("xla", "xla", {})] + step_cases +
                      [("hier_bucketed_zero1", "hier_bucketed_zero1", {}),
                       ("hier_bucketed_zero1_overlap",
                        "hier_bucketed_zero1",
                        {"bucket_bytes": multibucket, "overlap": True})])
    step_us = {}
    iters = 2 if quick else 5
    for label, mode, kw in step_cases:
        params = model.init(jax.random.key(0))
        if mode == "hier_bucketed_zero1":
            layout = make_bucket_layout(
                params, mesh,
                bucket_bytes=kw.get("bucket_bytes",
                                    BK.DEFAULT_BUCKET_BYTES))
            state = optim.init_bucketed(ocfg, params, layout)
        else:
            state = optim.init(ocfg, params)
        step = make_jitted_train_step(model, ocfg, accum=1, rules=rules,
                                      cross_pod_mode=mode, **kw)
        box = [params, state]

        def run():
            p, s, m = step(box[0], box[1], batch)
            box[0], box[1] = p, s
            jax.block_until_ready(m["loss"])

        with mesh:
            step_us[label] = time_fn(run, warmup=1, iters=iters)

    # ---------------- acceptance summary ---------------------------------
    op_reduction = (sync_hlo["hier_per_tensor"]["n_collective_ops"]
                    / max(sync_hlo["hier_bucketed"]["n_collective_ops"], 1))
    slow_frac = sync_hlo["hier_bucketed"]["slow_operand_frac"]
    slow_bound = 1.0 / n_data + 0.05
    ov = sync_hlo["hier_bucketed_overlap"]
    overlap_ok = bool(ov["n_buckets"] >= 2
                      and ov["slow_chain"]["independent"]
                      and jct["overlapped_s"] < jct["serial_s"])
    out = {
        "arch": ARCH,
        "quick": quick,
        "mesh": {"pod": n_pod, "data": n_data},
        "n_grad_leaves": n_leaves,
        "total_grad_bytes": total_bytes,
        "sync_hlo": sync_hlo,
        "jct_model": jct,
        "step_wallclock_us": step_us,
        "acceptance": {
            "op_reduction_bucketed_vs_per_tensor": op_reduction,
            "op_reduction_target": 10.0,
            "slow_operand_frac_bucketed": slow_frac,
            "slow_frac_bound": slow_bound,
            "overlap_n_buckets": ov["n_buckets"],
            "overlap_slow_collectives_independent": (
                ov["slow_chain"]["independent"]),
            "overlap_hidden_slow_s": jct["hidden_slow_s"],
            "overlap_pipelinable": overlap_ok,
            "pass": bool(op_reduction >= 10.0 and slow_frac <= slow_bound
                         and overlap_ok),
        },
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"WROTE {out_path}")


def main(quick: bool = False, out_path: str = DEFAULT_OUT) -> None:
    """Run the measurement in a fake-device subprocess, emit CSV rows."""
    from benchmarks.common import emit

    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{MESH_SHAPE[0] * MESH_SHAPE[1]}")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, "-m", "benchmarks.grad_sync_bench", "--inner",
           "--out", out_path] + (["--quick"] if quick else [])
    res = subprocess.run(cmd, capture_output=True, text=True,
                         timeout=3000, env=env, cwd=REPO)
    if res.returncode != 0:
        raise RuntimeError(
            f"grad_sync inner failed:\n{res.stderr[-4000:]}")
    with open(out_path) as f:
        data = json.load(f)
    for name, row in data["sync_hlo"].items():
        emit(f"grad_sync_{name}", 0.0,
             f"n_collectives={row['n_collective_ops']};"
             f"slow_operand_frac={row['slow_operand_frac']:.4f};"
             f"slow_chain_depth={row['slow_chain']['max_depth']}")
    for mode, us in data["step_wallclock_us"].items():
        emit(f"grad_sync_step_{mode}", us, "reduced-config train step")
    jct = data["jct_model"]
    emit("grad_sync_overlap_model", jct["overlapped_s"] * 1e6,
         f"serial_us={jct['serial_s']*1e6:.1f};"
         f"speedup={jct['speedup']:.2f}x;"
         f"exposed_slow_frac={jct['exposed_slow_frac_overlap']:.3f}")
    acc = data["acceptance"]
    emit("grad_sync_acceptance", 0.0,
         f"op_reduction={acc['op_reduction_bucketed_vs_per_tensor']:.1f}x;"
         f"slow_frac={acc['slow_operand_frac_bucketed']:.4f}"
         f"<=bound={acc['slow_frac_bound']:.4f};"
         f"overlap_pipelinable={acc['overlap_pipelinable']};"
         f"pass={acc['pass']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.inner:
        _inner(args.quick, args.out)
    else:
        main(args.quick, args.out)
