"""Fault-tolerance benchmark: recovery micro-costs + failures-at-scale.

Two stages, both host-side (no device mesh needed):

1. **Recovery micro-bench**: a real two-commit sharded checkpoint
   history has its newest shard bit-flipped; ``restore_with_fallback``
   must quarantine it on disk and restore the previous committed step.
   The wallclock of the clean restore, the corrupt-detect+fallback
   cycle, and a transient-EIO retried restore are measured on the
   Table-1-shaped state.

2. **Failure replay**: the fig7 trace categories replay under DM with a
   seeded MTBF :class:`~repro.core.simulator.FailureModel` armed, once
   with the drain cost model and once with the handoff model.  Failures
   strike the *same* seeded sequence in both, so the per-run restart
   charge is directly comparable — the paper's claim is that
   software-coordinated handoff makes unplanned recovery no more
   expensive than the incumbent reload (``failure_restart_s`` min-caps
   at the drain constant), while goodput accounting surfaces the lost
   work that checkpoint cadence, not recovery mechanism, governs.

Writes ``BENCH_fault.json`` (checked by ``scripts/check_bench.py`` in
CI) and emits the usual ``name,us,derived`` CSV rows.  Deterministic
for a fixed seed: run twice, byte-identical JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(REPO, "BENCH_fault.json")

MTBF_S = 3 * 3600.0
CKPT_INTERVAL_S = 600.0

FAILURE_TRACES = (
    ("fig7_philly_balanced_train_fifo", "philly", "balanced", "train",
     "fifo"),
    ("fig7_philly_small_train_fifo", "philly", "small", "train",
     "fifo"),
)


def _state_tree(n_leaves: int, leaf_elems: int) -> dict:
    rng = np.random.default_rng(0)
    return {f"p{i:03d}": rng.standard_normal(leaf_elems)
            .astype(np.float32) for i in range(n_leaves)}


def _recovery_bench(quick: bool) -> dict:
    """Stage 1: corrupt-quarantine-fallback on a real shard history."""
    from repro import ckpt as ckpt_lib
    from repro.faults import FaultPlan, FaultSpec, RetryPolicy, install
    from repro.faults.recovery import restore_with_fallback

    n_leaves, leaf_elems = (8, 1 << 12) if quick else (32, 1 << 16)
    tree = _state_tree(n_leaves, leaf_elems)
    base = tempfile.mkdtemp(prefix="fault_bench_")
    try:
        for step in (10, 20):
            ckpt_lib.save_sharded(ckpt_lib.step_dir(base, step), step,
                                  tree)

        t0 = time.perf_counter()
        step, _, _ = restore_with_fallback(base, tree)
        clean_restore_s = time.perf_counter() - t0
        clean_ok = step == 20

        # flip payload bytes of one shard of the newest commit
        sdir = ckpt_lib.step_dir(base, 20)
        shard = sorted(f for f in os.listdir(sdir)
                       if f.endswith(".npy"))[0]
        with open(os.path.join(sdir, shard), "r+b") as f:
            f.seek(-8, os.SEEK_END)
            tail = f.read(8)
            f.seek(-8, os.SEEK_END)
            f.write(bytes(b ^ 0xFF for b in tail))

        t0 = time.perf_counter()
        step, restored, report = restore_with_fallback(base, tree)
        fallback_s = time.perf_counter() - t0
        fallback_ok = (
            step == 10 and report.fell_back
            and [q.step for q in report.quarantined] == [20]
            and report.quarantined[0].quarantined_to is not None
            and not os.path.isdir(sdir)
            and all(np.array_equal(restored[k], tree[k]) for k in tree))

        # transient EIO on the first read, absorbed by one retry
        plan = FaultPlan([FaultSpec("sharded.read", "eio", hit=1)])
        t0 = time.perf_counter()
        with install(plan):
            step, _, rep = restore_with_fallback(
                base, tree,
                retry=RetryPolicy(max_retries=1, base_delay_s=0.001))
        retry_restore_s = time.perf_counter() - t0
        retry_ok = (step == 10 and bool(plan.fired)
                    and not rep.quarantined)
    finally:
        shutil.rmtree(base, ignore_errors=True)

    state_bytes = sum(a.nbytes for a in tree.values())
    return {
        "n_leaves": n_leaves,
        "state_bytes": state_bytes,
        "clean_restore_s": clean_restore_s,
        "corrupt_fallback_s": fallback_s,
        "retry_restore_s": retry_restore_s,
        "clean_ok": bool(clean_ok),
        "fallback_ok": bool(fallback_ok),
        "retry_ok": bool(retry_ok),
    }


def _one_replay(label, src, size_dist, mix, policy, mode, seed):
    from repro.core.jct_model import ReconfigCostModel
    from repro.core.simulator import FailureModel, simulate
    from repro.core.traces import TraceCategory, generate_trace

    jobs = generate_trace(TraceCategory(src, size_dist, mix),
                          seed=seed, double=False, max_size=4)
    r = simulate(jobs, "DM", policy=policy, seed=seed,
                 reconfig_cost=ReconfigCostModel(mode=mode),
                 failure_model=FailureModel(
                     mtbf_s=MTBF_S, ckpt_interval_s=CKPT_INTERVAL_S))
    return len(jobs), r


def _failure_replay(quick: bool) -> dict:
    """Stage 2: drain vs handoff recovery pricing under seeded MTBF."""
    seeds = (0,) if quick else (0, 1, 2)
    out = {"mtbf_s": MTBF_S, "ckpt_interval_s": CKPT_INTERVAL_S}
    per_trace = {}
    totals = {"drain": 0.0, "handoff": 0.0}
    recoveries = {"drain": 0, "handoff": 0}
    n_failures_total = 0
    all_finished = True
    same_failure_seq = True
    goodput_degrades = True
    for label, src, size_dist, mix, policy in FAILURE_TRACES:
        rows = []
        for seed in seeds:
            n_jobs, drain = _one_replay(label, src, size_dist, mix,
                                        policy, "drain", seed)
            _, hand = _one_replay(label, src, size_dist, mix,
                                  policy, "handoff", seed)
            all_finished &= (drain.n_jobs == n_jobs
                             and hand.n_jobs == n_jobs)
            same_failure_seq &= drain.n_failures == hand.n_failures
            goodput_degrades &= (drain.n_failures == 0
                                 or drain.goodput < 1.0)
            n_failures_total += drain.n_failures
            totals["drain"] += drain.failure_restart_cost_s
            totals["handoff"] += hand.failure_restart_cost_s
            recoveries["drain"] += drain.n_recoveries
            recoveries["handoff"] += hand.n_recoveries
            rows.append({
                "seed": seed,
                "n_jobs": n_jobs,
                "n_failures": drain.n_failures,
                "n_recoveries": drain.n_recoveries,
                "handoff_n_recoveries": hand.n_recoveries,
                "lost_work_s": drain.failure_lost_work_s,
                "drain_restart_cost_s": drain.failure_restart_cost_s,
                "handoff_restart_cost_s": hand.failure_restart_cost_s,
                "drain_goodput": drain.goodput,
                "handoff_goodput": hand.goodput,
                "drain_makespan": drain.makespan,
                "handoff_makespan": hand.makespan,
            })
        per_trace[label] = {"runs": rows}
    out["traces"] = per_trace
    out["drain_restart_cost_s"] = totals["drain"]
    out["handoff_restart_cost_s"] = totals["handoff"]
    out["drain_n_recoveries"] = recoveries["drain"]
    out["handoff_n_recoveries"] = recoveries["handoff"]
    # per-recovery means: restart-charge magnitudes shift the schedule,
    # so the *number* of jobs a given failure strikes can differ between
    # modes — the comparable quantity is the price of one recovery, on
    # which failure_restart_s caps handoff at the drain constant
    out["drain_restart_mean_s"] = (
        totals["drain"] / max(recoveries["drain"], 1))
    out["handoff_restart_mean_s"] = (
        totals["handoff"] / max(recoveries["handoff"], 1))
    out["n_failures_total"] = n_failures_total
    out["all_jobs_finished"] = bool(all_finished)
    out["same_failure_sequence"] = bool(same_failure_seq)
    out["goodput_degrades"] = bool(goodput_degrades)
    return out


def main(quick: bool = False, out_path: str = DEFAULT_OUT) -> None:
    from benchmarks.common import emit

    recovery = _recovery_bench(quick)
    replay = _failure_replay(quick)

    # determinism is part of the contract: the replay stage re-run with
    # the same seeds must reproduce byte-identical numbers (the recovery
    # stage measures wallclock, which legitimately varies)
    replay_again = _failure_replay(quick)
    deterministic = json.dumps(replay, sort_keys=True) == \
        json.dumps(replay_again, sort_keys=True)

    acceptance = {
        "recovery_clean_ok": recovery["clean_ok"],
        "recovery_fallback_ok": recovery["fallback_ok"],
        "recovery_retry_ok": recovery["retry_ok"],
        "failures_struck": replay["n_failures_total"] > 0,
        "all_jobs_finished": replay["all_jobs_finished"],
        "same_failure_sequence": replay["same_failure_sequence"],
        "goodput_degrades": replay["goodput_degrades"],
        # the pricing claim: one unplanned handoff recovery never costs
        # more than the incumbent drain reload (failure_restart_s
        # min-caps at the drain constant); totals are not comparable —
        # see the *_restart_mean_s comment in the replay section
        "handoff_recovery_le_drain": bool(
            replay["handoff_restart_mean_s"]
            <= replay["drain_restart_mean_s"] + 1e-9),
        "deterministic_replay": bool(deterministic),
    }
    acceptance["pass"] = all(v for v in acceptance.values()
                             if isinstance(v, bool))

    out = {
        "quick": quick,
        "recovery": recovery,
        "replay": replay,
        "acceptance": acceptance,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)

    emit("fault_recovery_clean_restore",
         recovery["clean_restore_s"] * 1e6,
         f"state={recovery['state_bytes']};ok={recovery['clean_ok']}")
    emit("fault_recovery_corrupt_fallback",
         recovery["corrupt_fallback_s"] * 1e6,
         f"quarantine+fallback;ok={recovery['fallback_ok']}")
    emit("fault_recovery_transient_retry",
         recovery["retry_restore_s"] * 1e6,
         f"eio_retried;ok={recovery['retry_ok']}")
    emit("fault_replay", 0.0,
         f"n_failures={replay['n_failures_total']};"
         f"drain_restart_mean={replay['drain_restart_mean_s']:.2f}s;"
         f"handoff_restart_mean={replay['handoff_restart_mean_s']:.2f}s;"
         f"pass={acceptance['pass']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    main(args.quick, args.out)
