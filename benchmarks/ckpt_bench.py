"""Checkpoint benchmark: per-rank bytes + save/restore wallclock.

Two measurements:

1. **Byte accounting** (exact, full ``llama3.2-1b``): per-rank bytes a
   sharded ZeRO-1 checkpoint writes vs. the gathered-full legacy
   baseline.  The flat f32 state (masters + both moments) shards 1/F
   over the fast axis, so per-rank sharded bytes for the optimizer state
   are expected at ~1/F of the gathered write — the restart-at-scale
   win: checkpoint time stops growing with model size per rank.

2. **Wallclock** (reduced config, 8 fake host devices, subprocess): real
   ``save_sharded`` / ``restore_sharded`` round trips for a sharded
   zero1 state on a (2, 4) pod x data mesh, including a reshard-restore
   onto the (4, 2) re-factorization (the elastic repack path), against
   the legacy gathered save/restore.

Writes ``BENCH_ckpt.json`` (CI uploads ``BENCH_*.json``) and emits the
usual ``name,us,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(REPO, "BENCH_ckpt.json")
ARCH = "llama3.2-1b"
MESH_SHAPE = (2, 4)                    # (pod, data) over 8 fake devices
RESHARD_SHAPE = (4, 2)                 # elastic repack target


def _accounting() -> dict:
    """Exact per-rank byte math for the full arch (no training)."""
    import jax

    from repro.collectives import bucketing as BK
    from repro.collectives.deterministic import det_align
    from repro.models.registry import build_model, get_config

    n_pod, n_data = MESH_SHAPE
    cfg = get_config(ARCH)
    shapes = jax.eval_shape(build_model(cfg).init, jax.random.key(0))
    param_bytes = sum(l.dtype.itemsize * math.prod(l.shape)
                      for l in jax.tree.leaves(shapes))
    layout = BK.plan_buckets(shapes, align=det_align(n_data))
    flat_elems = layout.n_padded_elements()
    opt_f32 = 3 * 4 * flat_elems           # masters + mu + nu, f32
    return {
        "arch": ARCH,
        "mesh": {"pod": n_pod, "data": n_data},
        "n_buckets": layout.n_buckets,
        "param_bytes": param_bytes,
        "opt_state_bytes_full": opt_f32,
        # legacy gathered format: the saving host writes everything
        "legacy_rank_bytes": param_bytes + opt_f32,
        # sharded: every rank writes its 1/F opt shards; rank 0 also
        # writes the replicated leaves (params + step) + manifest
        "sharded_rank_bytes": opt_f32 // n_data,
        "sharded_rank0_bytes": param_bytes + opt_f32 // n_data,
        "opt_shard_frac": (opt_f32 // n_data) / opt_f32,
        "expected_frac": 1.0 / n_data,
    }


def _inner(out_path: str, quick: bool) -> None:
    import time

    import jax

    from repro import ckpt
    from repro import checkpoint as legacy
    from repro import optim
    from repro.models.registry import build_model, get_config, \
        reduced_config
    from repro.train import init_sharded_zero1, make_bucket_layout
    import shutil
    import tempfile

    acct = _accounting()

    rcfg = reduced_config(get_config(ARCH))
    model = build_model(rcfg, remat=False)
    mesh = jax.make_mesh(MESH_SHAPE, ("pod", "data"))
    params = model.init(jax.random.key(0))
    layout = make_bucket_layout(params, mesh, deterministic=True)
    state, opt_sh = init_sharded_zero1(optim.AdamWConfig(), params,
                                       layout, mesh)

    def timed(fn, iters=1 if quick else 3):
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    base = tempfile.mkdtemp()
    sdir = ckpt.step_dir(base, 1)
    ldir = ckpt.step_dir(base, 2)

    def save_shard():
        ckpt.save_sharded(sdir, 1, (params, state), layout=layout,
                          mesh=mesh)

    def save_legacy():
        legacy.save(ldir, 2, (params, state))

    wall = {"save_sharded_s": timed(save_shard),
            "save_legacy_s": timed(save_legacy)}

    def restore_same():
        ckpt.restore_sharded(sdir, (params, state),
                             shardings=(None, opt_sh))

    wall["restore_sharded_s"] = timed(restore_same)

    mesh2 = jax.make_mesh(RESHARD_SHAPE, ("pod", "data"))
    params2 = model.init(jax.random.key(0))
    layout2 = make_bucket_layout(params2, mesh2, deterministic=True)
    assert layout2.bucket_sizes == layout.bucket_sizes
    state2, opt_sh2 = init_sharded_zero1(optim.AdamWConfig(), params2,
                                         layout2, mesh2)

    def restore_reshard():
        ckpt.restore_sharded(sdir, (params2, state2),
                             shardings=(None, opt_sh2))

    wall["restore_resharded_s"] = timed(restore_reshard)

    def restore_legacy():
        legacy.restore(ldir, (params, state))

    wall["restore_legacy_s"] = timed(restore_legacy)

    # verify the reshard actually recovered the state before reporting
    import numpy as np
    _, (rp, rs) = ckpt.restore_sharded(sdir, (params2, state2),
                                       shardings=(None, opt_sh2))
    for a, b in zip(jax.tree.leaves((params, state)),
                    jax.tree.leaves((rp, rs))):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # measured (not analytic) shard fraction: walk the manifest the save
    # actually wrote — if save_sharded ever regressed into writing full
    # gathered buckets, this is the number that catches it
    man = ckpt.read_manifest(sdir)
    measured_frac = 0.0
    n_sharded = 0
    for e in man.leaves.values():
        if e.kind != "sharded":
            continue
        n_sharded += 1
        total = int(np.prod(e.shape))
        for s in e.shards:
            vol = 1
            for a, b in s.index:
                vol *= b - a
            measured_frac = max(measured_frac, vol / total)
    assert n_sharded > 0
    shutil.rmtree(base, ignore_errors=True)

    frac = acct["opt_shard_frac"]
    n_data = MESH_SHAPE[1]
    out = {
        "quick": quick,
        "accounting": acct,
        "wallclock": {**wall,
                      "reduced_arch": ARCH,
                      "reshard": {"from": list(MESH_SHAPE),
                                  "to": list(RESHARD_SHAPE)}},
        "acceptance": {
            "opt_shard_frac": frac,
            "measured_max_shard_frac": measured_frac,
            "n_sharded_leaves": n_sharded,
            "bound": 1.0 / n_data + 1e-9,
            "pass": bool(frac <= 1.0 / n_data + 1e-9
                         and measured_frac <= 1.0 / n_data + 1e-9),
        },
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"WROTE {out_path}")


def main(quick: bool = False, out_path: str = DEFAULT_OUT) -> None:
    """Run the measurement in a fake-device subprocess, emit CSV rows."""
    from benchmarks.common import emit

    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{MESH_SHAPE[0] * MESH_SHAPE[1]}")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, "-m", "benchmarks.ckpt_bench", "--inner",
           "--out", out_path] + (["--quick"] if quick else [])
    res = subprocess.run(cmd, capture_output=True, text=True,
                         timeout=1800, env=env, cwd=REPO)
    if res.returncode != 0:
        raise RuntimeError(f"ckpt bench inner failed:\n"
                           f"{res.stderr[-4000:]}")
    with open(out_path) as f:
        data = json.load(f)
    acct = data["accounting"]
    emit("ckpt_bytes_per_rank", 0.0,
         f"sharded={acct['sharded_rank_bytes']};"
         f"legacy={acct['legacy_rank_bytes']};"
         f"opt_frac={acct['opt_shard_frac']:.4f}"
         f"~1/F={acct['expected_frac']:.4f}")
    for k, v in data["wallclock"].items():
        if k.endswith("_s"):
            emit(f"ckpt_{k[:-2]}", v * 1e6, "reduced-config zero1 state")
    acc = data["acceptance"]
    emit("ckpt_acceptance", 0.0,
         f"opt_shard_frac={acc['opt_shard_frac']:.4f};"
         f"measured_max_shard_frac={acc['measured_max_shard_frac']:.4f}"
         f"<=bound={acc['bound']:.4f};pass={acc['pass']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.inner:
        _inner(args.out, args.quick)
    else:
        main(args.quick, args.out)
