"""Fig. 10: one-to-one vs one-to-many at size 2, SHM/NET x SAME/DIFF,
solo (a) and under concurrency (b)."""
from __future__ import annotations

from benchmarks.common import emit, time_fn
from repro.core.jct_model import PlacementView, iteration_time

CONFIGS = {
    "one2one_2g": PlacementView(("2g.10gb",), (1,), "NONE", sm_slices=2),
    "SHM-SAME": PlacementView(("1g.5gb",) * 2, (2,), "SHM"),
    "SHM-DIFF": PlacementView(("1g.5gb",) * 2, (1, 1), "SHM"),
    "NET-DIFF": PlacementView(("1g.5gb",) * 2, (1, 1), "NET"),
}


def run(model: str, batch: int, *, net_jobs: int = 1) -> dict:
    out = {}
    for name, view in CONFIGS.items():
        if view.transport == "NET":
            view = PlacementView(view.instance_types,
                                 view.leaves_per_gpu, "NET",
                                 concurrent_net_jobs=net_jobs)
        out[name] = iteration_time(model, batch, view, train=True)
    base = out["one2one_2g"]
    return {k: v / base for k, v in out.items()}


def main() -> None:
    us = time_fn(lambda: run("bert-base", 32), warmup=0, iters=3)
    for model, batch in (("mobilenetv3-large", 128),
                         ("efficientnet-b2", 64),
                         ("distilbert", 32), ("bert-base", 16)):
        solo = run(model, batch, net_jobs=1)
        busy = run(model, batch, net_jobs=6)
        emit(f"fig10a_{model}", us,
             ";".join(f"{k}={v:.3f}" for k, v in solo.items()))
        emit(f"fig10b_{model}", us,
             f"SHM-SAME={busy['SHM-SAME']:.3f};"
             f"NET-DIFF_busy={busy['NET-DIFF']:.3f}")


if __name__ == "__main__":
    main()
