"""Fig. 11: collective bandwidth, SHM vs NET, 2/4/6/8 MIG instances —
plus the TPU-adapted equivalent: hierarchical vs flat all-reduce measured
in lowered-HLO collective bytes (run in a fake-device subprocess)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit, time_fn
from repro.core.jct_model import WORKLOADS
from repro.collectives.transport import gpu_collective, \
    hierarchical_vs_flat_bytes


def run_gpu_model() -> dict:
    out = {}
    for n in (2, 4, 6, 8):
        per_gpu = (n // 2, n - n // 2) if n > 1 else (1,)
        for op in ("all_reduce", "all_gather"):
            shm = gpu_collective(op, 128e6, transport="SHM",
                                 leaves_per_gpu=(n,) if n <= 7
                                 else (4, 4))
            net = gpu_collective(op, 128e6, transport="NET",
                                 leaves_per_gpu=per_gpu,
                                 concurrent_net_jobs=1)
            out[f"{op}_{n}"] = (shm.bus_bandwidth_gbps,
                                net.bus_bandwidth_gbps)
    return out


def run_tpu_hlo() -> str:
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.collectives.hierarchical import make_hier_all_reduce
        from repro.analysis.hlo import analyze
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        x = jax.ShapeDtypeStruct((8, 1 << 20), jnp.float32)
        rows = []
        for name, kw in (("flat", dict(flat=True)), ("hier", dict()),
                         ("hier_int8", dict(compress_bits=8))):
            fn = make_hier_all_reduce(mesh, fast_axis="data",
                                      slow_axis="pod", **kw)
            txt = fn.lower(x).compile().as_text()
            st = analyze(txt, chips_per_pod=4)
            rows.append(f"{name}_crosspod={st.cross_pod_bytes/1e6:.1f}MB")
        print("|".join(rows))
        """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    res = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=560,
                         env=env)
    if res.returncode != 0:
        return f"hlo_measure_failed({res.stderr.strip()[-120:]})"
    return res.stdout.strip().splitlines()[-1]


def main() -> None:
    us = time_fn(run_gpu_model, warmup=0, iters=3)
    out = run_gpu_model()
    for key, (shm, net) in out.items():
        emit(f"fig11_{key}", us,
             f"shm_busbw={shm:.2f}GBps;net_busbw={net:.2f}GBps")
    hb = hierarchical_vs_flat_bytes(1e9, fast=16, slow=2)
    emit("fig11_tpu_analytic", us,
         f"slow_bytes_reduction={hb['reduction']:.1f}x")
    emit("fig11_tpu_hlo", 0.0, run_tpu_hlo())


if __name__ == "__main__":
    main()
