"""End-to-end behaviour tests for the paper's system: schedule -> execute ->
communicate, plus SSM/mLSTM math properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no-network env: deterministic example-based shim
    from tests._hypothesis_stub import given, settings, st

from repro.core.executor import JobExecutor
from repro.core.job import Job
from repro.core.leaves import Cluster
from repro.core.modes import FlexMIG
from repro.core.registry import DuplicateGpuError, TopologyMismatchError
from repro.models import ssm as S
from repro.models import xlstm as X


def test_end_to_end_schedule_launch_communicate():
    """Fig. 4/5 wiring: FM places a size-4 job across both GPUs; the
    executor builds the pod env; the MIG-aware communicator forms with SHM
    transports; the stock path fails."""
    cluster = Cluster(n_hosts=1, gpus_per_host=2)
    fm = FlexMIG()
    fm.setup(cluster)
    job = Job("job-1", "bert-base", "train", 4, 32, 1200.0)
    placement = fm.try_place(job, cluster)
    assert placement is not None
    assert len({i.gpu_id for i in placement.instances}) == 2  # round-robin

    ex = JobExecutor()
    launched = ex.launch(job, placement, mig_aware=True)
    assert launched.pod.n_workers == 4
    assert set(launched.transports.values()) == {"SHM"}
    uuids = launched.pod.env["NVIDIA_VISIBLE_DEVICES"].split(",")
    assert len(set(uuids)) == 4

    with pytest.raises((DuplicateGpuError, TopologyMismatchError)):
        ex.launch(job, placement, mig_aware=False)   # stock NCCL fails


def test_one_to_many_spans_gpus_c3_lifted():
    """C3 (no cross-GPU aggregation) is exactly what one-to-many lifts."""
    cluster = Cluster(n_hosts=1, gpus_per_host=2)
    fm = FlexMIG()
    fm.setup(cluster)
    job = Job("big", "resnet101", "train", 8, 256, 2000.0)
    placement = fm.try_place(job, cluster)
    assert placement is not None
    assert sorted(placement.leaves_per_gpu()) == [4, 4]


@settings(max_examples=8, deadline=None)
@given(T=st.sampled_from([32, 64, 96]),
       chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 100))
def test_ssd_chunk_invariance_property(T, chunk, seed):
    """Property: SSD output is independent of chunk size (the kernel's
    core contract)."""
    B, H, P, G, N = 1, 2, 8, 1, 4
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, G, N))
    Cm = jax.random.normal(ks[4], (B, T, G, N))
    y1, s1 = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y2, s2 = S.ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(T=st.sampled_from([32, 64]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 100))
def test_mlstm_chunk_invariance_property(T, chunk, seed):
    B, H, D = 1, 2, 8
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    ir = jax.random.normal(ks[3], (B, T, H)) * 2
    fr = jax.random.normal(ks[4], (B, T, H)) * 2 + 2
    h1, _ = X.mlstm_chunked(q, k, v, ir, fr, chunk=chunk)
    h2, _ = X.mlstm_sequential_ref(q, k, v, ir, fr)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=5e-3, atol=5e-3)


def test_decode_state_matches_chunked_ssm():
    """Mamba decode recurrence continues exactly where prefill stopped."""
    B, T, H, P, G, N = 1, 32, 2, 8, 1, 4
    ks = jax.random.split(jax.random.key(9), 5)
    x = jax.random.normal(ks[0], (B, T + 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T + 1, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T + 1, G, N))
    Cm = jax.random.normal(ks[4], (B, T + 1, G, N))
    y_all, _ = S.ssd_sequential_ref(x, dt, A, Bm, Cm)
    y_pre, state = S.ssd_chunked(x[:, :T], dt[:, :T], A, Bm[:, :T],
                                 Cm[:, :T], chunk=8)
    y_t, _ = S.ssd_step(state, x[:, T], dt[:, T], A, Bm[:, T], Cm[:, T])
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_all[:, T]),
                               rtol=1e-3, atol=1e-4)
