"""Deterministic fallback for ``hypothesis`` in no-network environments.

The property tests in this suite use a small slice of the hypothesis API:
``@settings(max_examples=N, deadline=None)``, ``@given(kw=st...)`` and the
``floats`` / ``integers`` / ``sampled_from`` strategies.  When the real
package is unavailable, this shim runs each property as a deterministic
example-based test: every strategy draws from a seeded PRNG keyed on the
test name, the example index and the argument name, so all modules always
collect and the drawn examples are stable across runs.

This is NOT a property-testing engine (no shrinking, no coverage-guided
generation); install ``hypothesis`` (the ``test`` extra in pyproject.toml)
for the real thing.
"""
from __future__ import annotations

import functools
import inspect
import math
import random
import zlib
from typing import Any, Callable, Dict, Sequence

DEFAULT_MAX_EXAMPLES = 10
_SETTINGS_ATTR = "_stub_max_examples"


class SearchStrategy:
    """A deterministic value source: draw(rng) -> value."""

    def __init__(self, draw: Callable[[random.Random], Any], label: str):
        self._draw = draw
        self.label = label

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self):
        return f"SearchStrategy({self.label})"


class strategies:
    """Stand-in for ``hypothesis.strategies`` (used as ``st``)."""

    @staticmethod
    def floats(min_value: float, max_value: float, **_: Any
               ) -> SearchStrategy:
        lo, hi = float(min_value), float(max_value)

        def draw(rng: random.Random) -> float:
            if lo > 0 and hi / lo > 1e3:      # wide positive range: log scale
                return math.exp(rng.uniform(math.log(lo), math.log(hi)))
            return rng.uniform(lo, hi)
        return SearchStrategy(draw, f"floats({lo}, {hi})")

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: rng.randint(int(min_value), int(max_value)),
            f"integers({min_value}, {max_value})")

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
        elems = list(elements)
        return SearchStrategy(lambda rng: elems[rng.randrange(len(elems))],
                              f"sampled_from({elems!r})")

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.randrange(2)),
                              "booleans()")


st = strategies


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_: Any):
    """Decorator recording how many deterministic examples to run.

    Unknown keywords (deadline=..., suppress_health_check=...) are
    accepted and ignored — they configure engine behavior the stub
    doesn't have.
    """
    def deco(fn):
        setattr(fn, _SETTINGS_ATTR, max_examples)
        return fn
    return deco


def given(**param_strategies: SearchStrategy):
    """Run the test once per deterministic example.

    Examples are seeded from (test name, example index, parameter name),
    so runs are reproducible and order-independent.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, _SETTINGS_ATTR,
                        getattr(fn, _SETTINGS_ATTR, DEFAULT_MAX_EXAMPLES))
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                drawn: Dict[str, Any] = {}
                for name, strat in param_strategies.items():
                    seed = zlib.crc32(name.encode()) ^ (base + i)
                    drawn[name] = strat.draw(random.Random(seed))
                try:
                    fn(*args, **{**drawn, **kwargs})
                except _AssumptionNotMet:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"stub-hypothesis example {i}/{n} failed with "
                        f"{drawn!r}: {e}") from e

        # pytest must not mistake the strategy-supplied parameters for
        # fixtures: expose only the remaining (fixture) parameters and
        # drop functools' __wrapped__ so introspection stops here
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items()
                     if name not in param_strategies]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        del wrapper.__wrapped__
        return wrapper
    return deco


class HealthCheck:
    """Placeholder namespace so ``suppress_health_check=[...]`` parses."""
    too_slow = data_too_large = filter_too_much = None


def assume(condition: bool) -> None:
    """Weak stand-in: examples violating an assumption just pass."""
    if not condition:
        raise _AssumptionNotMet()


class _AssumptionNotMet(Exception):
    pass
