"""§4.2 runtime layer: peer discovery, synthetic bus-ID labeling."""
import pytest

from repro.core.registry import (DuplicateGpuError, InvalidBusIdError,
                                 PeerInfo, TopologyMismatchError,
                                 build_topology, driver_call_guard,
                                 env_to_peer, form_communicator,
                                 is_synthetic, peer_discovery,
                                 restore_bus_id, select_transport)


def _peers():
    return [
        PeerInfo(0, 0, 111, 1, "00:4B:00.0", "MIG-aaa"),
        PeerInfo(1, 0, 111, 2, "00:4B:00.0", "MIG-bbb"),
        PeerInfo(2, 0, 111, 3, "00:4C:00.0", "MIG-ccc"),
    ]


def test_stock_nccl_aborts_on_same_busid():
    """Failure point 1 (§2.5): false duplicate-GPU detection."""
    with pytest.raises(DuplicateGpuError):
        peer_discovery(_peers(), mig_aware=False)


def test_mig_aware_discovery_passes():
    peer_discovery(_peers(), mig_aware=True)  # no raise


def test_same_instance_double_bind_still_detected():
    peers = _peers() + [PeerInfo(3, 0, 111, 4, "00:4B:00.0", "MIG-aaa")]
    with pytest.raises(DuplicateGpuError):
        peer_discovery(peers, mig_aware=True)


def test_missing_mig_id_detected():
    peers = [PeerInfo(0, 0, 1, 1, "00:4B:00.0", None),
             PeerInfo(1, 0, 1, 2, "00:4B:00.0", None)]
    with pytest.raises(DuplicateGpuError):
        peer_discovery(peers, mig_aware=True)


def test_stock_topology_collapses_instances():
    """Failure point 2: dedup collapses nodes -> fewer devices than
    ranks."""
    nodes = build_topology(_peers(), synthetic_labeling=False)
    assert len(nodes) == 2
    with pytest.raises(TopologyMismatchError):
        form_communicator(_peers(), mig_aware=True,
                          synthetic_labeling=False)


def test_synthetic_labeling_makes_unique_nodes():
    nodes = build_topology(_peers(), synthetic_labeling=True)
    assert len(nodes) == 3
    labels = [n.label for n in nodes]
    assert labels == ["00:4B:00.0", "00:4B:00.1", "00:4C:00.0"]
    assert len(set(labels)) == 3


def test_restoration_routine():
    """The paper's example: 00:4B:00.0 -> 00:4B:00.1 and back."""
    assert restore_bus_id("00:4B:00.1") == "00:4B:00.0"
    assert restore_bus_id("00:4B:00.0") == "00:4B:00.0"
    assert is_synthetic("00:4B:00.3")
    assert not is_synthetic("00:4B:00.0")
    assert driver_call_guard("00:4B:00.2") == "00:4B:00.0"


def test_full_bootstrap():
    nodes = form_communicator(_peers(), mig_aware=True,
                              synthetic_labeling=True)
    assert len(nodes) == 3


def test_same_host_different_gpus_ok_without_mig():
    peers = [PeerInfo(0, 0, 1, 1, "00:4B:00.0"),
             PeerInfo(1, 0, 1, 2, "00:4C:00.0")]
    peer_discovery(peers, mig_aware=False)    # distinct bus ids: fine


def test_transport_selection():
    a = PeerInfo(0, 0, 1, 1, "00:4B:00.0", "MIG-a")
    b = PeerInfo(1, 0, 1, 2, "00:4B:00.0", "MIG-b")
    c = PeerInfo(2, 0, 2, 3, "00:4B:00.0", "MIG-c")
    assert select_transport(a, b) == "SHM"    # same host
    assert select_transport(a, c) == "NET"    # cross host


def test_env_plumbing():
    p = env_to_peer(0, {"NVIDIA_VISIBLE_DEVICES": "MIG-xyz"},
                    host_hash=7, pid_hash=1, pcie_bus_id="00:4B:00.0")
    assert p.mig_id == "MIG-xyz"
