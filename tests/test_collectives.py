"""Hierarchical collectives + compression (multi-device via subprocess)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no-network env: deterministic example-based shim
    from tests._hypothesis_stub import given, settings, st

from repro.collectives.compression import (apply_error_feedback,
                                           dequantize_int8, quantize_int8)
from repro.collectives.transport import (gpu_collective,
                                         hierarchical_vs_flat_bytes,
                                         tpu_collective_time)
from tests.conftest import run_multidevice


def test_int8_quantization_roundtrip():
    x = jnp.linspace(-3.0, 3.0, 128)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.51


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3))
def test_quantization_error_bounded_property(scale):
    x = jax.random.normal(jax.random.key(0), (256,)) * scale
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.51


def test_error_feedback_reduces_bias():
    """Residual carrying: the average of compressed grads converges to the
    true mean over steps."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)) * 1e-4)
    resid = None
    acc = jnp.zeros_like(g_true)
    n = 50
    for _ in range(n):
        gq, resid = apply_error_feedback(g_true, resid)
        acc = acc + gq
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g_true),
                               rtol=0.05, atol=1e-7)


def test_hier_vs_flat_slow_boundary_bytes():
    out = hierarchical_vs_flat_bytes(1e9, fast=16, slow=2)
    assert out["reduction"] == pytest.approx(16.0)


def test_gpu_collective_model_shm_beats_net_under_contention():
    shm = gpu_collective("all_reduce", 200e6, transport="SHM",
                         leaves_per_gpu=(2, 2))
    net = gpu_collective("all_reduce", 200e6, transport="NET",
                         leaves_per_gpu=(2, 2), concurrent_net_jobs=4)
    assert shm.time_s < net.time_s


def test_tpu_collective_two_tier():
    ici = tpu_collective_time("all_reduce", 1e8, n_chips=16, axis="ici")
    dcn = tpu_collective_time("all_reduce", 1e8, n_chips=2, axis="dcn")
    assert dcn > ici


def test_hierarchical_allreduce_correct_multidevice():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.collectives.hierarchical import make_hier_all_reduce
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        x = jnp.arange(8 * 33, dtype=jnp.float32).reshape(8, 33)
        xs = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
        want = np.broadcast_to(np.asarray(x).reshape(8, 33).mean(0), (33,))
        for kw in (dict(), dict(flat=True), dict(compress_bits=16)):
            fn = make_hier_all_reduce(mesh, fast_axis="data",
                                      slow_axis="pod", **kw)
            got = np.asarray(fn(xs))
            # every shard now holds the mean of its pod... full mean:
            assert got.shape == (8, 33)
            np.testing.assert_allclose(got, np.tile(want, (8, 1)),
                                       rtol=2e-2, atol=2e-2)
        # int8 path: looser tolerance
        fn8 = make_hier_all_reduce(mesh, fast_axis="data",
                                   slow_axis="pod", compress_bits=8)
        got = np.asarray(fn8(xs))
        np.testing.assert_allclose(got, np.tile(want, (8, 1)),
                                   rtol=0.05, atol=1.5)
        print("HIER_OK")
        """)
    assert "HIER_OK" in out


def test_moe_sharded_matches_single_device():
    """EP shard_map MoE == single-shard MoE on identical inputs."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.registry import get_config, reduced_config
        from repro.models import ffn as F
        from repro.sharding import make_rules, use_rules
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = reduced_config(get_config("qwen2-moe-a2.7b"))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        key = jax.random.key(0)
        p = F.moe_init(key, cfg)
        x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)

        ref, aux_ref = F.moe_apply(x, p, cfg)          # no rules: 1 shard

        with mesh:
            with use_rules(rules):
                xs = jax.device_put(x, NamedSharding(
                    mesh, P("data", None, None)))
                out, aux = jax.jit(
                    lambda x, p: F.moe_apply(x, p, cfg))(xs, p)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=3e-2, atol=3e-2)
        np.testing.assert_allclose(float(aux), float(aux_ref),
                                   rtol=1e-2, atol=1e-4)
        print("MOE_OK")
        """)
    assert "MOE_OK" in out
