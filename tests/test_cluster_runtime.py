"""Multi-tenant cluster runtime (repro.cluster.runtime).

Two layers:

- **FakeManager units**: the scheduling/repack/crash state machine
  driven by an instant in-process segment manager — admission order,
  quota serialization, `after` arrival gating, defrag + rebalance
  repacks, crash bookkeeping, deadlock detection;
- **real co-scheduled smokes**: subprocess workers over a shared
  fake-device pool — the 3-job/2-tenant contention scenario with both
  repack kinds and cross-job bitwise invariance, and a namespaced
  crash fault that restarts exactly the job it targets.
"""
import pytest

from repro.cluster import (ClusterError, ClusterJobSpec, ClusterRuntime,
                           DevicePool, SegmentResult)
from repro.core.job import TIER_HIGH
from repro.core.scheduler import Scheduler
from repro.faults.plan import FaultPlan, FaultSpec


class FakeManager:
    """Instant JobManager stand-in: every poll after a launch completes
    the segment (or crashes it, per ``crash_at``) with deterministic
    synthetic losses — the runtime's control flow runs at unit-test
    speed with zero subprocesses."""

    def __init__(self, spec, work_dir, *, crash_at=()):
        self.spec = spec
        self.crash_at = set(crash_at)         # {(segment, attempt)}
        self.segment = 0
        self.attempt = 0
        self.restarts = 0
        self.done_step = 0
        self.results = []
        self.launches = []                    # [(shape, fault_env)]
        self._pending = None

    @property
    def finished(self):
        return self.done_step >= self.spec.n_steps

    def next_run_to(self):
        return min(self.done_step + self.spec.segment_steps,
                   self.spec.n_steps)

    def launch(self, shape, *, fault_env=None):
        assert shape[0] * shape[1] == self.spec.size
        self.launches.append((shape, fault_env))
        self._pending = shape

    def poll(self):
        if self._pending is None:
            return None
        shape = self._pending
        self._pending = None
        if (self.segment, self.attempt) in self.crash_at:
            return ("crash", -9)
        start, end = self.done_step, self.next_run_to()
        res = SegmentResult(
            job_id=self.spec.job_id, segment=self.segment,
            attempt=self.attempt, start_step=start, end_step=end,
            shape=shape,
            losses=[1000.0 * self.spec.seed + s
                    for s in range(start, end)],
            steady_step_s=0.01, first_step_s=0.05,
            state_bytes=1000 * self.spec.size, final_save_s=0.02,
            final_save_bytes=500, resume_restore_s=0.01,
            resume_restore_bytes=500, resume_setup_s=0.005,
            recovered_step=None)
        self.results.append(res)
        self.done_step = end
        self.segment += 1
        self.attempt = 0
        return ("ok", res)

    def note_crash(self):
        self.attempt += 1
        self.restarts += 1

    def tail_log(self, n=2000):
        return "<fake>"


def _run(specs, tmp_path, **kw):
    kw.setdefault("pool", DevicePool(2, 4))
    kw.setdefault("manager_factory", FakeManager)
    rt = ClusterRuntime(specs, base_dir=str(tmp_path), **kw)
    return rt, rt.run()


# -------------------------------------------------------- fake units

def test_two_jobs_run_side_by_side(tmp_path):
    specs = [ClusterJobSpec("a", size=4, n_steps=4, segment_steps=2),
             ClusterJobSpec("b", size=4, n_steps=4, segment_steps=2)]
    _, res = _run(specs, tmp_path)
    assert set(res.jobs) == {"a", "b"}
    assert res.jobs["a"].losses == [0.0, 1.0, 2.0, 3.0]
    assert res.repacks == []
    # one stitched boundary measurement per job
    assert [m["job_id"] for m in res.measurements] == ["a", "b"]
    assert all(not m["repack"] for m in res.measurements)


def test_contention_scenario_defrag_then_rebalance(tmp_path):
    specs = [
        ClusterJobSpec("j0", size=4, n_steps=15, segment_steps=3,
                       tenant="acme"),
        ClusterJobSpec("j1", size=2, n_steps=2, segment_steps=2,
                       tenant="beta"),
        ClusterJobSpec("j2", size=4, n_steps=2, segment_steps=2,
                       tenant="beta", priority_tier=TIER_HIGH,
                       after="j1"),
    ]
    _, res = _run(specs, tmp_path,
                  scheduler=Scheduler("backfill", depth=8,
                                      quotas={"beta": 6}))
    reasons = [r.reason for r in res.repacks]
    assert "defrag" in reasons and "rebalance" in reasons
    defrag = res.repacks[reasons.index("defrag")]
    assert defrag.job_id == "j0" and defrag.requested_by == "j2"
    assert defrag.to_shape == (1, 4)          # consolidated to one host
    # j0 went wide -> packed -> back wide; every step executed exactly
    # once across the repacks
    shapes = res.jobs["j0"].shapes
    assert shapes[0] == (2, 2) and (1, 4) in shapes
    assert shapes[-1] == (2, 2)
    assert res.jobs["j0"].losses == [float(s) for s in range(15)]
    # the tier-0 job landed single-host
    assert res.jobs["j2"].shapes == [(1, 4)]
    # repack boundaries are visible in the stitched measurements
    assert any(m["repack"] for m in res.measurements)


def test_quota_serializes_tenant(tmp_path):
    seen = []

    class Recording(Scheduler):
        def candidates(self, queue, usage=None):
            seen.append(dict(usage or {}))
            return super().candidates(queue, usage=usage)

    specs = [ClusterJobSpec("b1", size=2, n_steps=2, tenant="beta"),
             ClusterJobSpec("b2", size=2, n_steps=2, tenant="beta")]
    _, res = _run(specs, tmp_path, pool=DevicePool(1, 4),
                  scheduler=Recording("backfill", depth=8,
                                      quotas={"beta": 2}))
    assert len(res.jobs) == 2
    assert max(u.get("beta", 0) for u in seen) <= 2


def test_after_gates_arrival(tmp_path):
    specs = [ClusterJobSpec("first", size=2, n_steps=2),
             ClusterJobSpec("second", size=2, n_steps=2,
                            after="first")]
    rt, res = _run(specs, tmp_path, pool=DevicePool(1, 2))
    assert set(res.jobs) == {"first", "second"}
    assert ClusterJobSpec("x", size=1, n_steps=1).after is None


def test_crash_relaunches_then_succeeds(tmp_path):
    def factory(spec, wd):
        return FakeManager(spec, wd, crash_at={(1, 0)}
                           if spec.job_id == "a" else ())

    specs = [ClusterJobSpec("a", size=2, n_steps=4, segment_steps=2),
             ClusterJobSpec("b", size=2, n_steps=4, segment_steps=2)]
    _, res = _run(specs, tmp_path, manager_factory=factory)
    assert res.jobs["a"].restarts == 1
    assert res.jobs["b"].restarts == 0
    assert res.jobs["a"].losses == [0.0, 1.0, 2.0, 3.0]


def test_crash_beyond_max_restarts_raises(tmp_path):
    def factory(spec, wd):
        return FakeManager(spec, wd,
                           crash_at={(0, 0), (0, 1), (0, 2)})

    specs = [ClusterJobSpec("a", size=2, n_steps=2)]
    with pytest.raises(ClusterError, match="giving up"):
        _run(specs, tmp_path, manager_factory=factory, max_restarts=2)


def test_quota_smaller_than_job_is_a_deadlock(tmp_path):
    specs = [ClusterJobSpec("a", size=4, n_steps=2, tenant="beta")]
    with pytest.raises(ClusterError, match="deadlock"):
        _run(specs, tmp_path,
             scheduler=Scheduler("fifo", quotas={"beta": 2}))


def test_frag_aware_runtime_packs_exact_fits(tmp_path):
    """frag_aware=True routes placement through the pool's frag-aware
    strategy: a size-4 arrival onto a half-loaded pool takes the
    exact-fit host instead of the round-robin wide split — and the run
    still completes every job."""
    specs = [ClusterJobSpec("a", size=4, n_steps=4, segment_steps=4),
             ClusterJobSpec("b", size=4, n_steps=2, segment_steps=2,
                            after="a")]
    rt, res = _run(specs, tmp_path, frag_aware=True,
                   rebalance=False)
    assert set(res.jobs) == {"a", "b"}
    # every placement was single-host (exact fits: 4 onto 4-device
    # hosts); default round_robin would have split (2, 2)
    assert res.jobs["a"].shapes == [(1, 4)]
    assert res.jobs["b"].shapes == [(1, 4)]


def test_frag_aware_default_off_is_unchanged(tmp_path):
    specs = [ClusterJobSpec("a", size=4, n_steps=2, segment_steps=2)]
    rt, res = _run(specs, tmp_path)
    assert rt.frag_aware is False
    assert res.jobs["a"].shapes == [(2, 2)]     # round-robin wide split


def test_spec_validation():
    with pytest.raises(ClusterError, match="duplicate"):
        ClusterRuntime([ClusterJobSpec("a", size=2, n_steps=2),
                        ClusterJobSpec("a", size=2, n_steps=2)],
                       pool=DevicePool(2, 4), base_dir="/tmp/x")
    with pytest.raises(ClusterError, match="exceeds the pool"):
        ClusterRuntime([ClusterJobSpec("a", size=16, n_steps=2)],
                       pool=DevicePool(2, 4), base_dir="/tmp/x")
    with pytest.raises(ClusterError, match="names no submitted"):
        ClusterRuntime([ClusterJobSpec("a", size=2, n_steps=2,
                                       after="ghost")],
                       pool=DevicePool(2, 4), base_dir="/tmp/x")
    with pytest.raises(ValueError):
        ClusterJobSpec("bad", size=0, n_steps=2)


# ---------------------------------------------------- real subprocess

def test_cluster_smoke_multidevice(tmp_path):
    """The contention scenario end-to-end with real workers: 3 jobs,
    2 tenants, both repack kinds, per-tenant quota, and the bitwise
    invariant crossing jobs — j2 (tier-0, admitted by the defrag) runs
    the same width/config/seed as j0, so its 2 losses must equal j0's
    first 2 exactly, repacks and all."""
    specs = [
        ClusterJobSpec("j0", size=4, n_steps=15, segment_steps=3,
                       tenant="acme"),
        ClusterJobSpec("j1", size=2, n_steps=2, segment_steps=2,
                       tenant="beta"),
        ClusterJobSpec("j2", size=4, n_steps=2, segment_steps=2,
                       tenant="beta", priority_tier=TIER_HIGH,
                       after="j1"),
    ]
    rt = ClusterRuntime(
        specs, pool=DevicePool(2, 4), base_dir=str(tmp_path),
        scheduler=Scheduler("backfill", depth=8, quotas={"beta": 6}),
        timeout_s=500.0)
    res = rt.run()

    reasons = [r.reason for r in res.repacks]
    assert len(res.repacks) >= 2
    assert "defrag" in reasons
    defrag = res.repacks[reasons.index("defrag")]
    assert defrag.job_id == "j0" and defrag.requested_by == "j2"
    for jid, spec in (("j0", specs[0]), ("j1", specs[1]),
                      ("j2", specs[2])):
        assert len(res.jobs[jid].losses) == spec.n_steps
    assert res.jobs["j2"].losses == res.jobs["j0"].losses[:2]
    # measured handoffs exist and carry the stitched fields
    assert res.measurements
    m = res.measurements[0]
    assert m["save_s"] > 0 and m["restore_s"] > 0
    assert m["state_bytes"] > 0 and m["save_bytes"] > 0


def test_cluster_fault_restarts_only_target_multidevice(tmp_path):
    """A namespaced crash plan SIGKILLs j_a's first step; the runtime
    relaunches it (fresh start — nothing was committed) while j_b runs
    on untouched, and both finish with identical losses (same seed and
    width, so the restarted job must converge bitwise)."""
    specs = [ClusterJobSpec("j_a", size=2, n_steps=2),
             ClusterJobSpec("j_b", size=2, n_steps=2)]
    rt = ClusterRuntime(
        specs, pool=DevicePool(1, 4), base_dir=str(tmp_path),
        fault_plans={"j_a": FaultPlan(
            [FaultSpec("driver.first_step", "crash", hit=1)])},
        timeout_s=400.0)
    res = rt.run()
    assert res.jobs["j_a"].restarts == 1
    assert res.jobs["j_b"].restarts == 0
    assert res.jobs["j_a"].losses == res.jobs["j_b"].losses
    assert len(res.jobs["j_a"].losses) == 2
