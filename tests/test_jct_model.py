"""Job-level performance model: the §5.4 measured effects."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no-network env: deterministic example-based shim
    from tests._hypothesis_stub import given, settings, st

from repro.core.jct_model import (WORKLOADS, PlacementView,
                                  ReconfigCostModel, bucket_sync_times,
                                  ckpt_state_bytes,
                                  exposed_slow_fraction,
                                  hier_sync_makespan, iteration_time,
                                  jct_scale)


def _view(types, per_gpu, transport="SHM", net_jobs=1):
    return PlacementView(tuple(types), tuple(per_gpu), transport,
                         concurrent_net_jobs=net_jobs)


def test_1g10gb_single_instance_boost_10_to_30pct():
    for name, w in WORKLOADS.items():
        t5 = iteration_time(name, 64, _view(["1g.5gb"], [1], "NONE"),
                            train=True)
        t10 = iteration_time(name, 64, _view(["1g.10gb"], [1], "NONE"),
                             train=True)
        assert 1.08 <= t5 / t10 <= 1.32, name     # the paper's band


def test_mixing_1g10_with_1g5_gives_no_benefit():
    """size>=2: sync caps at the slowest leaf (§3.2)."""
    pure = iteration_time("bert-base", 32,
                          _view(["1g.5gb"] * 2, [1, 1]), train=True)
    mixed = iteration_time("bert-base", 32,
                           _view(["1g.5gb", "1g.10gb"], [1, 1]),
                           train=True)
    assert mixed >= pure * 0.999


def test_placement_skew_degrades_fig9():
    """6-0 worse than 5-1 worse than ... 3-3 (PCIe saturation)."""
    times = []
    for split in [(3, 3), (4, 2), (5, 1), (6, 0)]:
        per = [s for s in split if s > 0]
        times.append(iteration_time(
            "bert-base", 32, _view(["1g.5gb"] * 6, per), train=True))
    assert times == sorted(times)
    assert times[-1] > times[0]                   # visible degradation


def test_one_to_many_penalty_modest_fig10a():
    """one-to-many vs one-to-one: <= ~10% at size 2 (paper Fig. 10a)."""
    for name in WORKLOADS:
        one = iteration_time(name, 32, PlacementView(
            ("2g.10gb",), (1,), "NONE", sm_slices=2), train=True)
        many = iteration_time(name, 32, _view(["1g.5gb"] * 2, [1, 1]),
                              train=True)
        assert many / one <= 1.12, name
        assert many / one >= 0.99, name


def test_net_contention_fig10b():
    """Single NET stream can match SHM, but concurrency kills NET."""
    shm = iteration_time("bert-base", 32,
                         _view(["1g.5gb"] * 2, [2], "SHM"), train=True)
    net1 = iteration_time("bert-base", 32,
                          _view(["1g.5gb"] * 2, [1, 1], "NET",
                                net_jobs=1), train=True)
    net8 = iteration_time("bert-base", 32,
                          _view(["1g.5gb"] * 2, [1, 1], "NET",
                                net_jobs=8), train=True)
    assert net1 <= shm * 1.05                     # NET-DIFF can win alone
    assert net8 > net1                            # contention hurts NET


def test_jct_scale_reference_is_unity():
    for name in ("resnet50", "bert-base", "t5-small"):
        assert jct_scale(name, 64, 4, _view(["1g.5gb"] * 4, [2, 2]),
                         train=True) == pytest.approx(1.0, rel=1e-6)


# ------------------------------------------------- bucket sync schedule

def test_hier_sync_makespan_serial_is_stage_sum():
    f, s, d = [1.0, 2.0], [10.0, 5.0], [1.5, 0.5]
    assert hier_sync_makespan(f, s, d, overlap=False) == \
        pytest.approx(sum(f) + sum(s) + sum(d))


def test_hier_sync_makespan_overlap_hides_slow_dominated():
    # 4 equal buckets, slow >> fast: the pipeline leaves only the first
    # reduce-scatter, the slow chain, and the last drain exposed
    f, s, d = [1.0] * 4, [10.0] * 4, [1.0] * 4
    assert hier_sync_makespan(f, s, d, overlap=False) == pytest.approx(48)
    assert hier_sync_makespan(f, s, d, overlap=True) == pytest.approx(42)


def test_hier_sync_makespan_overlap_fast_dominated():
    # fast >> slow: the fast channel is the bottleneck; the slow hops
    # (2 units total) hide entirely under it
    f, s, d = [10.0, 10.0], [1.0, 1.0], [10.0, 10.0]
    assert hier_sync_makespan(f, s, d, overlap=False) == pytest.approx(42)
    assert hier_sync_makespan(f, s, d, overlap=True) == pytest.approx(40)


def test_hier_sync_makespan_overlap_never_slower():
    for k in (1, 2, 3, 7):
        f = [0.5 + 0.1 * i for i in range(k)]
        s = [2.0 - 0.2 * i for i in range(k)]
        d = [0.4] * k
        serial = hier_sync_makespan(f, s, d, overlap=False)
        piped = hier_sync_makespan(f, s, d, overlap=True)
        assert piped <= serial + 1e-12
        # and never better than the slow-chain + pipeline-fill bound
        assert piped >= max(sum(s), f[0] + s[-1] + d[-1]) - 1e-12


def test_exposed_slow_fraction_bounds():
    f, s, d = [1.0] * 4, [10.0] * 4, [1.0] * 4
    assert exposed_slow_fraction(f, s, d, overlap=False) == \
        pytest.approx(1.0)
    frac = exposed_slow_fraction(f, s, d, overlap=True)
    assert 0.0 < frac < 1.0
    assert exposed_slow_fraction([1.0], [0.0], [1.0], overlap=True) == 0.0


def test_bucket_sync_times_degenerate_axes_and_compression():
    numels = (64, 128)
    f1, s1, d1 = bucket_sync_times(numels, nf=1, ns=4, fast_bps=1e9,
                                   slow_bps=1e9)
    assert f1 == [0.0, 0.0] and d1 == [0.0, 0.0]     # no fast tier
    f2, s2, d2 = bucket_sync_times(numels, nf=4, ns=1, fast_bps=1e9,
                                   slow_bps=1e9)
    assert s2 == [0.0, 0.0]                          # no slow tier
    assert all(x > 0 for x in f2) and f2 == d2
    # int8 slow hop: 1 byte/elem -> 4x fewer slow seconds than f32
    _, s32, _ = bucket_sync_times(numels, nf=4, ns=2, fast_bps=1e9,
                                  slow_bps=1e9)
    _, s8, _ = bucket_sync_times(numels, nf=4, ns=2, fast_bps=1e9,
                                 slow_bps=1e9, slow_bytes_per_elem=1.0)
    for a, b in zip(s8, s32):
        assert a == pytest.approx(b / 4.0)


# ------------------------------------------- reconfiguration cost model

def test_reconfig_cost_model_validation():
    with pytest.raises(ValueError, match="mode"):
        ReconfigCostModel(mode="magic")
    with pytest.raises(ValueError, match="throughput"):
        ReconfigCostModel(save_bps=0.0)


def test_drain_mode_charges_exactly_the_drain():
    cm = ReconfigCostModel()                    # mode="drain"
    assert cm.job_suspension_s(1e12, drain_s=123.0) == 123.0
    assert cm.geometry_s(base_s=110.0, drain_s=130.0) == 130.0


def test_handoff_geometry_is_the_reconfigure_cycle_alone():
    cm = ReconfigCostModel(mode="handoff")
    assert cm.geometry_s(base_s=110.0, drain_s=130.0) == 110.0


@settings(max_examples=40, deadline=None)
@given(b1=st.floats(min_value=0.0, max_value=1e12),
       b2=st.floats(min_value=0.0, max_value=1e12),
       ranks=st.integers(min_value=1, max_value=64),
       drain_s=st.floats(min_value=1.0, max_value=1e4))
def test_property_handoff_monotone_in_state_bytes(b1, b2, ranks,
                                                  drain_s):
    """Calibrated handoff cost is monotone in state bytes..."""
    cm = ReconfigCostModel(mode="handoff")
    lo, hi = sorted((b1, b2))
    assert cm.handoff_s(lo, n_ranks_old=ranks, n_ranks_new=ranks) <= \
        cm.handoff_s(hi, n_ranks_old=ranks, n_ranks_new=ranks)
    assert cm.job_suspension_s(lo, drain_s=drain_s, n_ranks_old=ranks,
                               n_ranks_new=ranks) <= \
        cm.job_suspension_s(hi, drain_s=drain_s, n_ranks_old=ranks,
                            n_ranks_new=ranks)


@settings(max_examples=40, deadline=None)
@given(bytes_=st.floats(min_value=0.0, max_value=1e13),
       ranks_old=st.integers(min_value=1, max_value=64),
       ranks_new=st.integers(min_value=1, max_value=64),
       drain_s=st.floats(min_value=0.0, max_value=1e5))
def test_property_handoff_never_exceeds_drain(bytes_, ranks_old,
                                              ranks_new, drain_s):
    """...and never exceeds the drain cost it replaces."""
    cm = ReconfigCostModel(mode="handoff")
    charged = cm.job_suspension_s(bytes_, drain_s=drain_s,
                                  n_ranks_old=ranks_old,
                                  n_ranks_new=ranks_new)
    assert charged <= drain_s + 1e-12
    assert charged >= 0.0


@settings(max_examples=20, deadline=None)
@given(ranks=st.integers(min_value=1, max_value=64))
def test_property_more_ranks_never_slower(ranks):
    """Sharded 1/F I/O: adding ranks never makes the handoff slower."""
    cm = ReconfigCostModel(mode="handoff")
    b = 4e9
    assert cm.handoff_s(b, n_ranks_old=ranks + 1, n_ranks_new=ranks) <= \
        cm.handoff_s(b, n_ranks_old=ranks, n_ranks_new=ranks)


def test_from_measurements_calibration():
    ms = [{"save_s": 2.0, "restore_s": 1.0, "compile_s": 0.5,
           "save_bytes": 2e9, "restore_bytes": 3e9},
          {"save_s": 4.0, "restore_s": 2.0, "compile_s": 1.5,
           "save_bytes": 4e9, "restore_bytes": 6e9}]
    cm = ReconfigCostModel.from_measurements(ms)
    assert cm.mode == "handoff"
    assert cm.save_bps == pytest.approx(1e9)
    assert cm.restore_bps == pytest.approx(3e9)
    assert cm.recompile_s == pytest.approx(1.0)
    # bytes/ranks/bps arithmetic round-trips through the calibration
    assert cm.handoff_s(8e9, n_ranks_old=2, n_ranks_new=4) == \
        pytest.approx(8e9 / 2 / 1e9 + 8e9 / 4 / 3e9 + 1.0)
    with pytest.raises(ValueError, match="zero measurements"):
        ReconfigCostModel.from_measurements([])


def test_ckpt_state_bytes_tracks_params():
    """fp16 params + f32 master/mu/nu = 14 B/param, model-ordered."""
    for name, w in WORKLOADS.items():
        assert ckpt_state_bytes(name) == pytest.approx(
            w.params_m * 1e6 * 14)
    assert ckpt_state_bytes("bert-base") > ckpt_state_bytes("distilbert")
