"""Job-level performance model: the §5.4 measured effects."""
import pytest

from repro.core.jct_model import (WORKLOADS, PlacementView, iteration_time,
                                  jct_scale)


def _view(types, per_gpu, transport="SHM", net_jobs=1):
    return PlacementView(tuple(types), tuple(per_gpu), transport,
                         concurrent_net_jobs=net_jobs)


def test_1g10gb_single_instance_boost_10_to_30pct():
    for name, w in WORKLOADS.items():
        t5 = iteration_time(name, 64, _view(["1g.5gb"], [1], "NONE"),
                            train=True)
        t10 = iteration_time(name, 64, _view(["1g.10gb"], [1], "NONE"),
                             train=True)
        assert 1.08 <= t5 / t10 <= 1.32, name     # the paper's band


def test_mixing_1g10_with_1g5_gives_no_benefit():
    """size>=2: sync caps at the slowest leaf (§3.2)."""
    pure = iteration_time("bert-base", 32,
                          _view(["1g.5gb"] * 2, [1, 1]), train=True)
    mixed = iteration_time("bert-base", 32,
                           _view(["1g.5gb", "1g.10gb"], [1, 1]),
                           train=True)
    assert mixed >= pure * 0.999


def test_placement_skew_degrades_fig9():
    """6-0 worse than 5-1 worse than ... 3-3 (PCIe saturation)."""
    times = []
    for split in [(3, 3), (4, 2), (5, 1), (6, 0)]:
        per = [s for s in split if s > 0]
        times.append(iteration_time(
            "bert-base", 32, _view(["1g.5gb"] * 6, per), train=True))
    assert times == sorted(times)
    assert times[-1] > times[0]                   # visible degradation


def test_one_to_many_penalty_modest_fig10a():
    """one-to-many vs one-to-one: <= ~10% at size 2 (paper Fig. 10a)."""
    for name in WORKLOADS:
        one = iteration_time(name, 32, PlacementView(
            ("2g.10gb",), (1,), "NONE", sm_slices=2), train=True)
        many = iteration_time(name, 32, _view(["1g.5gb"] * 2, [1, 1]),
                              train=True)
        assert many / one <= 1.12, name
        assert many / one >= 0.99, name


def test_net_contention_fig10b():
    """Single NET stream can match SHM, but concurrency kills NET."""
    shm = iteration_time("bert-base", 32,
                         _view(["1g.5gb"] * 2, [2], "SHM"), train=True)
    net1 = iteration_time("bert-base", 32,
                          _view(["1g.5gb"] * 2, [1, 1], "NET",
                                net_jobs=1), train=True)
    net8 = iteration_time("bert-base", 32,
                          _view(["1g.5gb"] * 2, [1, 1], "NET",
                                net_jobs=8), train=True)
    assert net1 <= shm * 1.05                     # NET-DIFF can win alone
    assert net8 > net1                            # contention hurts NET


def test_jct_scale_reference_is_unity():
    for name in ("resnet50", "bert-base", "t5-small"):
        assert jct_scale(name, 64, 4, _view(["1g.5gb"] * 4, [2, 2]),
                         train=True) == pytest.approx(1.0, rel=1e-6)
