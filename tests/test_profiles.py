"""MIG profile table, tree constraints (C1/C2), over-provisioning (I1)."""
import pytest

from repro.core import profiles as P
from repro.core.leaves import Cluster, GPUState, _layout


def test_profile_table_matches_paper_table3():
    assert P.PROFILES["1g.5gb"].max_per_gpu == 7
    assert P.PROFILES["1g.10gb"].max_per_gpu == 4
    assert P.PROFILES["2g.10gb"].max_per_gpu == 3
    assert P.PROFILES["3g.20gb"].max_per_gpu == 2
    assert P.PROFILES["4g.20gb"].max_per_gpu == 1
    assert P.PROFILES["7g.40gb"].max_per_gpu == 1
    for name, prof in P.PROFILES.items():
        i, g = name.split("g.")
        assert prof.sm_slices == int(i)
        assert prof.mem_gb == int(g.rstrip("gb"))


def test_fixed_profiles_c1():
    with pytest.raises(ValueError):
        P.round_up_profile(9)
    # 3g.15gb / 5g.25gb do not exist -> rounded up (paper Fig. 2)
    assert P.round_up_profile(3) == "4g.20gb"
    assert P.round_up_profile(5) == "7g.40gb"
    assert P.overprovision_slices(3) == 1
    assert P.overprovision_slices(5) == 2
    assert P.overprovision_slices(6) == 1
    assert P.overprovision_slices(4) == 0


def test_tree_constrained_merging_c2():
    # Fig 3a: slices (0,1) share a parent -> mergeable; (1,2) do not
    assert P.mergeable(0, 1)
    assert P.mergeable(2, 3)
    assert not P.mergeable(1, 2)
    assert not P.mergeable(3, 4)


def test_gpu_placement_respects_tree():
    gpu = GPUState(0, 0)
    gpu.create_instance("2g.10gb", "a")      # takes {0,1}
    gpu.create_instance("2g.10gb", "b")      # takes {2,3}
    # 3g.20gb placements are {0,1,2} and {4,5,6}: only the latter is free
    place = gpu.valid_placement("3g.20gb")
    assert place == frozenset({4, 5, 6})
    gpu.create_instance("3g.20gb", "c")
    assert gpu.valid_placement("1g.5gb") is None  # memory exhausted? no:
    # 2+2+4 mem slices used = 8 -> full


def test_flexmig_partition_fills_gpu():
    cluster = Cluster(n_hosts=1, gpus_per_host=1)
    cluster.partition_all(P.FLEXMIG_PARTITION)
    gpu = cluster.gpus[(0, 0)]
    assert len(gpu.instances) == 7
    mem = sum(P.PROFILES[i.profile].mem_gb for i in gpu.instances)
    assert mem == 40                          # 6x5 + 10: no stranded memory


def test_static_partition_valid():
    cluster = Cluster(n_hosts=1, gpus_per_host=1)
    cluster.partition_all(P.STATIC_PARTITION)
    profs = sorted(i.profile for i in cluster.gpus[(0, 0)].instances)
    assert profs == ["1g.10gb", "2g.10gb", "4g.20gb"]


def test_layout_backtracking():
    assert _layout(["4g.20gb", "2g.10gb", "1g.10gb"]) is not None
    assert _layout(["4g.20gb", "4g.20gb"]) is None
    assert _layout(["7g.40gb"]) is not None
    assert _layout(["3g.20gb", "3g.20gb"]) is not None
    # two 3g.20gb exhaust all 8 memory slices: nothing else fits
    assert _layout(["3g.20gb", "3g.20gb", "1g.5gb"]) is None


def test_repartition_preserves_running():
    gpu = GPUState(0, 0)
    a = gpu.create_instance("1g.5gb", "a")
    a.job_id = "j1"
    gpu.create_instance("1g.5gb", "idle")
    assert gpu.could_fit_after_repartition("4g.20gb")
    inst = gpu.repartition_for("4g.20gb", "new")
    assert inst.profile == "4g.20gb"
    live = {i.uuid for i in gpu.instances}
    assert live == {"a", "new"}               # idle destroyed, running kept
