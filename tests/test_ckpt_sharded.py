"""Unit tests for the sharded checkpoint subsystem (repro.ckpt).

Multidevice behavior (per-rank shard files, reshard restore) lives in
``tests/test_ckpt_reshard.py``; these cover the host-side machinery:
round-trips, the atomic commit protocol, restore policies, corruption
detection, legacy-format dispatch, crash-safe ``latest_step`` and the
elastic checkpoint handoff.
"""
import glob
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as legacy
from repro import ckpt, optim
from repro.core.leaves import TpuSliceTopology
from repro.elastic import plan_elastic_remesh


def _tree():
    return {"a": jnp.arange(8, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 3), jnp.bfloat16)},
            "none": None,
            "opt": optim.OptState(step=jnp.int32(3),
                                  mu={"a": jnp.zeros(4)},
                                  nu={"a": jnp.ones(4)}, master=None)}


def _assert_trees_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_replicated_roundtrip_and_structure(tmp_path):
    tree = _tree()
    sdir = ckpt.step_dir(str(tmp_path), 5)
    assert ckpt.save_sharded(sdir, 5, tree) is None     # blocking
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert ckpt.is_sharded_dir(sdir)
    step, restored = ckpt.restore_sharded(sdir, tree)
    assert step == 5
    _assert_trees_equal(tree, restored)
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert isinstance(restored["opt"], optim.OptState)
    assert restored["none"] is None


def test_async_save_commits_on_join(tmp_path):
    sdir = ckpt.step_dir(str(tmp_path), 2)
    t = ckpt.save_sharded(sdir, 2, {"x": jnp.arange(6.0)},
                          blocking=False)
    assert isinstance(t, threading.Thread)
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 2
    _, r = ckpt.restore_sharded(sdir, {"x": jnp.zeros(6)})
    np.testing.assert_array_equal(np.asarray(r["x"]), np.arange(6.0))


def test_restore_auto_dispatches_legacy(tmp_path):
    sdir = ckpt.step_dir(str(tmp_path), 7)
    legacy.save(sdir, 7, {"x": jnp.arange(4.0)})
    assert not ckpt.is_sharded_dir(sdir)
    step, r = ckpt.restore_auto(sdir, {"x": jnp.zeros(4)})
    assert step == 7
    np.testing.assert_array_equal(np.asarray(r["x"]), np.arange(4.0))


def test_pad_flat_and_zero_policies(tmp_path):
    # live prefix 100, saved padded to 128
    saved = {"m": jnp.concatenate([jnp.arange(100.0), jnp.zeros(28)])}
    sdir = ckpt.step_dir(str(tmp_path), 1)
    ckpt.save_sharded(sdir, 1, saved)
    # grow: align went 128 -> 160 (e.g. fast axis 2 -> deterministic 64)
    _, r = ckpt.restore_sharded(sdir, {"m": jnp.zeros(160)},
                                policy={"m": ckpt.PAD_FLAT})
    np.testing.assert_array_equal(
        np.asarray(r["m"]),
        np.concatenate([np.arange(100.0), np.zeros(60)]).astype(
            np.float32))
    # shrink: still past the live prefix, so nothing real is dropped
    _, r2 = ckpt.restore_sharded(sdir, {"m": jnp.zeros(104)},
                                 policy={"m": ckpt.PAD_FLAT})
    np.testing.assert_array_equal(np.asarray(r2["m"])[:100],
                                  np.arange(100.0, dtype=np.float32))
    # zero policy re-initializes on mismatch (hierarchical EF residuals)
    _, r3 = ckpt.restore_sharded(sdir, {"m": jnp.zeros(64)},
                                 policy={"m": ckpt.ZERO})
    assert not np.asarray(r3["m"]).any()
    # zero policy still restores real data when shapes match
    _, r4 = ckpt.restore_sharded(sdir, {"m": jnp.zeros(128)},
                                 policy={"m": ckpt.ZERO})
    np.testing.assert_array_equal(np.asarray(r4["m"])[:100],
                                  np.arange(100.0, dtype=np.float32))
    # default policy is exact: mismatch raises
    with pytest.raises(ckpt.CorruptCheckpointError, match="shape"):
        ckpt.restore_sharded(sdir, {"m": jnp.zeros(64)})
    with pytest.raises(ckpt.CorruptCheckpointError, match="missing"):
        ckpt.restore_sharded(sdir, {"other": jnp.zeros(4)})
    # pad_flat refuses to shrink through live data (live prefix is 100)
    with pytest.raises(ckpt.CorruptCheckpointError, match="truncate"):
        ckpt.restore_sharded(sdir, {"m": jnp.zeros(64)},
                             policy={"m": ckpt.PAD_FLAT})


def test_lost_shard_entries_detected(tmp_path):
    """A manifest that parses but lost shard entries (torn hand-edit,
    multi-host save missing one host) must refuse, not zero-fill."""
    import json
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    arr = jax.device_put(jnp.arange(16.0), NamedSharding(mesh, P("data")))
    # force a 2-shard manifest by hand-splitting a replicated save
    sdir = ckpt.step_dir(str(tmp_path), 1)
    ckpt.save_sharded(sdir, 1, {"m": jnp.arange(16.0)})
    man_path = os.path.join(sdir, ckpt.MANIFEST)
    with open(man_path) as f:
        man = json.load(f)
    entry = man["leaves"]["m"]
    # rewrite as a sharded entry covering only half the array
    man["leaves"]["m"] = {
        "kind": "sharded", "shape": entry["shape"],
        "dtype": entry["dtype"], "spec": [],
        "shards": [{"file": entry["file"], "index": [[0, 8]],
                    "crc32": entry["crc32"]}]}
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ckpt.CorruptCheckpointError,
                       match="lost shard entries"):
        ckpt.restore_sharded(sdir, {"m": jnp.zeros(16)}, verify=False)


def test_corruption_detected(tmp_path):
    sdir = ckpt.step_dir(str(tmp_path), 1)
    ckpt.save_sharded(sdir, 1, {"m": jnp.arange(32.0)})
    fn = sorted(glob.glob(os.path.join(sdir, "m*.npy")))[0]
    arr = np.load(fn)
    arr[3] = 123.0
    np.save(fn, arr)
    with pytest.raises(ckpt.CorruptCheckpointError, match="checksum"):
        ckpt.restore_sharded(sdir, {"m": jnp.zeros(32)})


def test_latest_step_skips_torn_dirs(tmp_path):
    """Regression (PR-4 satellite): a crash mid-save must not break
    resume — neither a shard dir without a manifest, nor a torn temp dir
    awaiting its atomic rename, nor junk names may crash latest_step or
    win over the last committed step."""
    base = str(tmp_path)
    good = ckpt.step_dir(base, 10)
    ckpt.save_sharded(good, 10, {"x": jnp.arange(4.0)})
    # partially-written: files but no manifest (legacy-style crash)
    os.makedirs(os.path.join(base, "step_00000020"))
    np.save(os.path.join(base, "step_00000020", "x.npy"), np.zeros(4))
    # torn temp dir from the rename protocol — even WITH a manifest
    torn = os.path.join(base, "step_00000030.tmp-4242")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        f.write("{}")
    # junk that used to crash int(d.split('_')[1])
    os.makedirs(os.path.join(base, "step_final"))
    assert ckpt.latest_step(base) == 10
    assert legacy.latest_step(base) == 10       # same (shared) fix


def test_save_overwrites_same_step(tmp_path):
    sdir = ckpt.step_dir(str(tmp_path), 4)
    ckpt.save_sharded(sdir, 4, {"x": jnp.zeros(4)})
    ckpt.save_sharded(sdir, 4, {"x": jnp.arange(4.0)})
    _, r = ckpt.restore_sharded(sdir, {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(r["x"]), np.arange(4.0))
    # the aside-rename protocol must not leave .old-* residue behind
    assert not [d for d in os.listdir(str(tmp_path)) if ".old-" in d]


def test_async_save_failure_surfaces_on_join(tmp_path):
    """A failed async write must raise at join, never pass silently —
    a swallowed ENOSPC would make a failed checkpoint look committed.
    Both formats share the re-raising writer."""
    blocker = tmp_path / "base"
    blocker.write_text("not a directory")
    t = ckpt.save_sharded(str(blocker / "step_00000001"), 1,
                          {"x": jnp.zeros(2)}, blocking=False)
    with pytest.raises(OSError):
        t.join()
    # the legacy format rides the same re-raising writer
    from repro.checkpoint import _WriterThread
    t2 = legacy.save(str(tmp_path / "ok"), 2, {"x": jnp.zeros(2)},
                     blocking=False)
    assert isinstance(t2, _WriterThread)
    t2.join()
    assert legacy.latest_step(str(tmp_path)) is None   # not a step_* dir
    _, r = legacy.restore(str(tmp_path / "ok"), {"x": jnp.zeros(2)})
    np.testing.assert_array_equal(np.asarray(r["x"]), np.zeros(2))


def test_python_scalar_leaves_roundtrip(tmp_path):
    """Templates may hold raw Python scalars (np.asarray-coerced on
    save); restore must handle leaves without .shape/.dtype."""
    tree = {"n": 3, "f": 2.5, "arr": jnp.arange(4.0)}
    sdir = ckpt.step_dir(str(tmp_path), 1)
    ckpt.save_sharded(sdir, 1, tree)
    step, r = ckpt.restore_sharded(sdir, tree)
    assert step == 1
    assert int(np.asarray(r["n"])) == 3
    assert float(np.asarray(r["f"])) == 2.5
    np.testing.assert_array_equal(np.asarray(r["arr"]), np.arange(4.0))


def test_legacy_restore_validates_shapes(tmp_path):
    """The gathered format cannot reshard: a template whose shapes moved
    must fail loudly, not return wrong-shaped arrays into the step."""
    sdir = ckpt.step_dir(str(tmp_path), 1)
    legacy.save(sdir, 1, {"m": jnp.arange(8.0)})
    with pytest.raises(ckpt.CorruptCheckpointError, match="reshard"):
        legacy.restore(sdir, {"m": jnp.zeros(12)})
    with pytest.raises(ckpt.CorruptCheckpointError, match="reshard"):
        ckpt.restore_auto(sdir, {"m": jnp.zeros(12)},
                          policy={"m": ckpt.PAD_FLAT})


def test_restore_rejects_changed_bucket_layout(tmp_path):
    """PAD_FLAT's copy-prefix rule is only exact under an unchanged
    leaf->bucket placement: restoring with a different bucket_bytes must
    refuse loudly, not scramble masters across bucket boundaries."""
    from repro.collectives import bucketing as BK
    leaves = {"w": jnp.arange(100.0), "b": jnp.arange(60.0)}
    lay_save = BK.plan_buckets(leaves, bucket_bytes=256, align=1)
    assert lay_save.n_buckets == 2
    sdir = ckpt.step_dir(str(tmp_path), 1)
    ckpt.save_sharded(sdir, 1, leaves, layout=lay_save)
    lay_big = BK.plan_buckets(leaves, bucket_bytes=4096, align=1)
    assert lay_big.n_buckets == 1
    with pytest.raises(ckpt.CorruptCheckpointError, match="bucket_bytes"):
        ckpt.restore_sharded(sdir, leaves, layout=lay_big)
    # the same layout passes validation and restores
    _, r = ckpt.restore_sharded(sdir, leaves, layout=lay_save)
    _assert_trees_equal(leaves, r)
    # requesting validation against a manifest with no recorded layout
    # must refuse, not silently skip the check
    sdir2 = ckpt.step_dir(str(tmp_path), 2)
    ckpt.save_sharded(sdir2, 2, leaves)            # layout=None
    with pytest.raises(ckpt.CorruptCheckpointError,
                       match="records no bucket layout"):
        ckpt.restore_sharded(sdir2, leaves, layout=lay_save)


def test_restore_rejects_dtype_mismatch(tmp_path):
    sdir = ckpt.step_dir(str(tmp_path), 1)
    ckpt.save_sharded(sdir, 1, {"m": jnp.arange(8, dtype=jnp.float32)})
    with pytest.raises(ckpt.CorruptCheckpointError, match="dtype"):
        ckpt.restore_sharded(sdir, {"m": jnp.zeros(8, jnp.bfloat16)})
    # ZERO policy re-initializes in the template dtype instead
    _, r = ckpt.restore_sharded(sdir, {"m": jnp.zeros(8, jnp.bfloat16)},
                                policy={"m": ckpt.ZERO})
    assert r["m"].dtype == jnp.bfloat16 and not np.asarray(r["m"]).any()
    # legacy format: same guard
    ldir = ckpt.step_dir(str(tmp_path), 2)
    legacy.save(ldir, 2, {"m": jnp.arange(8, dtype=jnp.float32)})
    with pytest.raises(ckpt.CorruptCheckpointError, match="dtype"):
        legacy.restore(ldir, {"m": jnp.zeros(8, jnp.bfloat16)})


def test_manifest_records_layout_and_mesh(tmp_path):
    from repro.collectives import bucketing as BK
    leaves = {"w": jnp.arange(10.0), "b": jnp.arange(4.0)}
    layout = BK.plan_buckets(leaves, bucket_bytes=64, align=8)
    sdir = ckpt.step_dir(str(tmp_path), 1)
    ckpt.save_sharded(sdir, 1, leaves, layout=layout)
    man = ckpt.read_manifest(sdir)
    assert man.layout["align"] == 8
    assert man.layout["bucket_sizes"] == list(layout.bucket_sizes)
    assert man.layout["live_sizes"] == ckpt.bucket_live_sizes(layout)
    assert len(man.layout["slots"]) == 2


def test_elastic_plan_names_checkpoint_handoff(tmp_path):
    topo = TpuSliceTopology(n_pods=1, hosts_per_pod=4, chips_per_host=4)
    leaves = topo.leaves()
    base = str(tmp_path)
    # no committed checkpoint: the remesh must refuse the handoff
    with pytest.raises(RuntimeError, match="no committed checkpoint"):
        plan_elastic_remesh(leaves, [(0, 1)], model_parallel=4,
                            ckpt_base_dir=base)
    ckpt.save_sharded(ckpt.step_dir(base, 30), 30, {"x": jnp.zeros(2)})
    # a torn later step must not win the handoff
    os.makedirs(os.path.join(base, "step_00000040.tmp-1"))
    plan = plan_elastic_remesh(leaves, [(0, 1)], model_parallel=4,
                               ckpt_base_dir=base)
    assert plan.handoff is not None
    assert plan.handoff.step == 30
    assert plan.handoff.sharded
    assert plan.handoff.step_dir == ckpt.step_dir(base, 30)
    # without a checkpoint dir the plan still works (handoff is None)
    assert plan_elastic_remesh(leaves, [(0, 1)],
                               model_parallel=4).handoff is None
