"""End-to-end elastic preemption/repack: simulator reconfig events drive
the real sharded save -> reshard-restore -> continue cycle.

The multidevice tests are the PR-5 acceptance: a simulated trace's
reconfiguration events, mapped onto training steps by
``schedule_from_sim``, replay through ``ElasticDriver`` and the
continued loss curve is bitwise-identical to the uninterrupted run
((2,2) -> (4,1) and (2,2) -> (1,4), ``deterministic_reduce``).
"""
import pytest

from repro import optim
from repro.core.jct_model import ReconfigCostModel
from repro.core.simulator import simulate
from repro.core.traces import TraceCategory, generate_trace
from repro.data import DataConfig
from repro.elastic_driver import (ElasticDriver, ReconfigEvent,
                                  factorizations, schedule_from_sim)
from tests.conftest import run_multidevice


def _sim_with_drains():
    jobs = generate_trace(TraceCategory("philly", "balanced", "mixed"),
                          seed=7, double=False, max_size=4)
    r = simulate(jobs, "DM")
    assert r.n_drains > 0           # the golden trace reconfigures
    return r


def test_factorizations():
    assert factorizations(4) == [(1, 4), (2, 2), (4, 1)]
    assert factorizations(1) == [(1, 1)]
    assert all(p * d == 6 for p, d in factorizations(6))
    with pytest.raises(ValueError):
        factorizations(0)


def test_reconfig_event_validation():
    with pytest.raises(ValueError, match="step"):
        ReconfigEvent(step=0, mesh_shape=(2, 2))
    with pytest.raises(ValueError, match="mesh shape"):
        ReconfigEvent(step=1, mesh_shape=(2, 0))


def test_schedule_from_sim_maps_events_onto_steps():
    r = _sim_with_drains()
    n_steps = 20
    sched = schedule_from_sim(r, n_devices=4, n_steps=n_steps,
                              initial_shape=(2, 2))
    assert sched                               # drains became events
    steps = [e.step for e in sched]
    assert steps == sorted(set(steps))         # increasing, deduped
    assert all(1 <= s <= n_steps - 1 for s in steps)
    shapes = [(2, 2)] + [e.mesh_shape for e in sched]
    for prev, cur in zip(shapes, shapes[1:]):
        assert cur != prev                     # every event re-factors
        assert cur in factorizations(4)
    # sim times carried through, in order
    assert [e.sim_time for e in sched] == \
        sorted(e.sim_time for e in sched)
    # deterministic: same sim result -> same schedule
    assert schedule_from_sim(r, n_devices=4, n_steps=n_steps,
                             initial_shape=(2, 2)) == sched


def test_schedule_from_sim_degenerate_cases():
    r = _sim_with_drains()
    assert schedule_from_sim(r, n_devices=4, n_steps=1) == []
    # a single-factorization device count has nowhere to repack to
    assert schedule_from_sim(r, n_devices=1, n_steps=20) == []
    # FM never reconfigures -> empty schedule
    jobs = generate_trace(TraceCategory("philly", "balanced", "mixed"),
                          seed=7, double=False, max_size=4)
    fm = simulate(jobs, "FM")
    assert schedule_from_sim(fm, n_devices=4, n_steps=20) == []
    # max_events truncates
    one = schedule_from_sim(r, n_devices=4, n_steps=20, max_events=1)
    assert len(one) == 1


def test_run_refuses_stale_newer_checkpoint(tmp_path):
    """A leftover committed checkpoint past the first event would win
    the handoff's latest_step lookup — the driver must refuse, before
    compiling anything (so ``model`` is never touched here)."""
    stale = tmp_path / "step_00000099"
    stale.mkdir()
    # committed_steps verifies the manifest's step matches the dir name
    (stale / "manifest.json").write_text('{"step": 99}')
    drv = ElasticDriver(object(), optim.AdamWConfig(),
                        DataConfig(vocab_size=16, seq_len=4,
                                   global_batch=2),
                        base_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="stale"):
        drv.run(8, [ReconfigEvent(step=2, mesh_shape=(2, 2))])


def test_driver_rejects_bad_mode_and_duplicate_steps(tmp_path):
    dcfg = DataConfig(vocab_size=16, seq_len=4, global_batch=2)
    with pytest.raises(ValueError, match="mode"):
        ElasticDriver(object(), optim.AdamWConfig(), dcfg,
                      base_dir=str(tmp_path), mode="teleport")
    drv = ElasticDriver(object(), optim.AdamWConfig(), dcfg,
                        base_dir=str(tmp_path))
    with pytest.raises(ValueError, match="duplicate"):
        drv.run(8, [ReconfigEvent(step=2, mesh_shape=(2, 2)),
                    ReconfigEvent(step=2, mesh_shape=(4, 1))])
    with pytest.raises(ValueError, match="past the run"):
        drv.run(8, [ReconfigEvent(step=8, mesh_shape=(2, 2))])
    with pytest.raises(ValueError, match="factorization"):
        drv.run(8, [ReconfigEvent(step=2, mesh_shape=(3, 1))],
                initial_shape=(2, 2))


def test_simulate_rejects_conflicting_reconfig_args():
    """A 'drain'-labeled replay with a handoff cost model would report a
    handoff-vs-handoff delta of ~0 — refuse instead of mislabeling."""
    jobs = generate_trace(TraceCategory("philly", "small", "train"),
                          seed=0, double=False, max_size=4)
    cm = ReconfigCostModel(mode="handoff")
    with pytest.raises(ValueError, match="conflicts"):
        simulate(jobs, "DM", reconfig_mode="drain", reconfig_cost=cm)
    # a cost model alone governs the charging (no mode arg needed)
    r = simulate(jobs, "DM", reconfig_cost=cm)
    assert r.n_drains == 0


def test_elastic_driver_smoke_multidevice():
    """One save -> reshard-restore -> continue cycle, bitwise (the CI
    elastic-e2e step runs exactly this in both device-matrix legs)."""
    out = run_multidevice("""
        import tempfile
        from repro import optim
        from repro.data import DataConfig
        from repro.elastic_driver import ElasticDriver, ReconfigEvent
        from repro.models.registry import get_config, build_model, \\
            reduced_config

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                 total_steps=4)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=8)
        ref = ElasticDriver(model, ocfg, dcfg,
                            base_dir=tempfile.mkdtemp()).run(
            4, (), initial_shape=(2, 2))
        out = ElasticDriver(model, ocfg, dcfg,
                            base_dir=tempfile.mkdtemp()).run(
            4, [ReconfigEvent(step=2, mesh_shape=(4, 1))],
            initial_shape=(2, 2))
        assert out.losses == ref.losses, (out.losses, ref.losses)
        assert out.mesh_shapes[:2] == [(2, 2)] * 2
        assert out.mesh_shapes[2:] == [(4, 1)] * 2
        (m,) = out.measurements
        assert m.verified
        assert m.save_s > 0 and m.restore_s > 0
        assert m.save_bytes > 0 and m.state_bytes > 0
        print('ELASTIC_SMOKE_OK')
        """, n_devices=8)
    assert "ELASTIC_SMOKE_OK" in out


def test_preemption_replay_bitwise_multidevice():
    """The PR-5 acceptance: a *simulated trace's* reconfiguration event
    replays through the real driver; the continued loss curve is
    bitwise-identical to the uninterrupted run for (2,2) -> (4,1) and
    (2,2) -> (1,4)."""
    r = _sim_with_drains()
    sched = schedule_from_sim(r, n_devices=4, n_steps=8,
                              initial_shape=(2, 2), max_events=1)
    assert sched, "the simulated trace must provide a reconfig event"
    event_step = sched[0].step
    out = run_multidevice(f"""
        import tempfile
        from repro import optim
        from repro.data import DataConfig
        from repro.elastic_driver import ElasticDriver, ReconfigEvent
        from repro.models.registry import get_config, build_model, \\
            reduced_config

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                 total_steps=8)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=8)

        def drive(schedule):
            drv = ElasticDriver(model, ocfg, dcfg,
                                base_dir=tempfile.mkdtemp(),
                                bucket_bytes=64 << 10)
            return drv.run(8, schedule, initial_shape=(2, 2))

        ref = drive(())
        for target in ((4, 1), (1, 4)):
            out = drive([ReconfigEvent(step={event_step},
                                       mesh_shape=target)])
            assert out.losses == ref.losses, (target, out.losses,
                                              ref.losses)
            (m,) = out.measurements
            assert m.verified and m.to_shape == target
            print('REPLAY_%dx%d_OK' % target)
        print('PREEMPTION_REPLAY_BITWISE_OK')
        """, n_devices=8)
    assert "REPLAY_4x1_OK" in out
    assert "REPLAY_1x4_OK" in out
    assert "PREEMPTION_REPLAY_BITWISE_OK" in out
