"""Bucketed flat-buffer gradient collectives + shard-resident optimizer.

Layout/round-trip tests run single-device; schedule-equivalence tests run
on 1/2/4-device fake meshes in subprocesses (tests/conftest.py); the
ZeRO-1 bitwise-parity test drives 20 real train steps on a (pod, data)
mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.collectives import bucketing as BK
from tests.conftest import run_multidevice


def _mixed_tree():
    return {
        "emb": jnp.arange(7 * 5, dtype=jnp.bfloat16).reshape(7, 5),
        "blocks": {
            "w": jnp.linspace(-2, 2, 4 * 3 * 2,
                              dtype=jnp.float32).reshape(4, 3, 2),
            "b": jnp.ones((11,), jnp.float16),
        },
        "scalar": jnp.asarray(3.25, jnp.float32),
        "head": jnp.full((2, 9), -1.5, jnp.bfloat16),
    }


# ------------------------------------------------------------------ layout

def test_roundtrip_exact_mixed_shapes_dtypes():
    tree = _mixed_tree()
    for bucket_bytes, align in ((4, 1), (64, 3), (1 << 20, 4), (128, 7)):
        layout = BK.plan_buckets(tree, bucket_bytes=bucket_bytes,
                                 align=align)
        buckets = BK.flatten_to_buckets(layout, tree)
        assert all(b.dtype == jnp.float32 for b in buckets)
        assert all(b.shape[0] % align == 0 for b in buckets)
        back = BK.unflatten_from_buckets(layout, buckets)
        assert jax.tree.structure(back) == jax.tree.structure(tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype and a.shape == b.shape
            # bf16/f16 -> f32 -> back is exact: round-trip is bitwise
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_deterministic_and_first_fit():
    tree = _mixed_tree()
    l1 = BK.plan_buckets(tree, bucket_bytes=64, align=2)
    l2 = BK.plan_buckets(jax.eval_shape(lambda: tree), bucket_bytes=64,
                         align=2)
    # same layout from concrete arrays and from avals
    assert l1.slots == l2.slots and l1.bucket_sizes == l2.bucket_sizes
    # slots follow flatten order with in-bucket contiguity
    for prev, cur in zip(l1.slots, l1.slots[1:]):
        assert (cur.bucket, cur.offset) > (prev.bucket, prev.offset) or \
            cur.bucket > prev.bucket


def test_single_giant_tensor_gets_own_bucket():
    tree = {"small": jnp.ones((3,)), "giant": jnp.ones((1000,)),
            "tail": jnp.ones((2,))}
    layout = BK.plan_buckets(tree, bucket_bytes=64, align=4)  # cap=16 elems
    slots = {s.size: s for s in layout.slots}
    # dict leaves flatten alphabetically: giant | (small, tail)
    assert slots[1000].offset == 0          # giant opens its own bucket
    assert layout.bucket_sizes[slots[1000].bucket] == 1000
    assert layout.n_buckets == 2
    assert slots[3].bucket == slots[2].bucket != slots[1000].bucket
    assert layout.n_elements() == 1005
    assert layout.n_padded_elements() >= 1005


def test_bucket_count_vs_bytes_edge_cases():
    many = {f"t{i}": jnp.ones((5,)) for i in range(7)}   # 35 elems
    # capacity 2 elems: every leaf alone
    assert BK.plan_buckets(many, bucket_bytes=8).n_buckets == 7
    # huge capacity: all in one
    one = BK.plan_buckets(many, bucket_bytes=1 << 30, align=8)
    assert one.n_buckets == 1
    assert one.bucket_sizes[0] == 40        # 35 padded to align=8
    # 5-elem leaves into 10-elem buckets: 7 leaves -> 4 buckets (2,2,2,1)
    paired = BK.plan_buckets(many, bucket_bytes=40)
    assert paired.n_buckets == 4
    # empty-ish tree still yields one (padded) bucket
    assert BK.plan_buckets({"x": jnp.zeros(())},
                           bucket_bytes=1024).n_buckets == 1


def test_unflatten_dtype_override():
    tree = {"w": jnp.ones((4, 2), jnp.bfloat16)}
    layout = BK.plan_buckets(tree)
    buckets = BK.flatten_to_buckets(layout, tree)
    g = BK.unflatten_from_buckets(layout, buckets, dtype=jnp.float32)
    assert g["w"].dtype == jnp.float32


# --------------------------------------------------- schedule equivalence

def test_bucketed_schedule_matches_flat_multidevice():
    """Bucketed hier reduce-scatter/psum/all-gather == plain mean, on
    1-, 2- and 4-device meshes (with and without a pod axis)."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import parallel as PX
        from repro.collectives import bucketing as BK

        tree = {"a": jnp.arange(24.0).reshape(2, 3, 4),
                "b": {"c": jnp.linspace(-1, 1, 7)},
                "d": jnp.ones((5, 5), jnp.bfloat16)}

        for shape, names in (((1,), ("data",)), ((2,), ("data",)),
                             ((2, 2), ("pod", "data")),
                             ((4,), ("data",)),
                             ((2,), ("pod",))):
            n = 1
            for s in shape:
                n *= s
            mesh = PX.make_device_mesh(shape, names,
                                       devices=jax.devices()[:n])
            fast = "data" if "data" in names else None
            slow = "pod" if "pod" in names else None
            nf = mesh.shape[fast] if fast else 1
            layout = BK.plan_buckets(tree, bucket_bytes=128, align=nf)

            def rank(t):
                t = jax.tree.map(lambda x: x[0], t)   # strip stack dim
                b = BK.flatten_to_buckets(layout, t)
                s = BK.hier_reduce_bucket_shards(
                    b, fast_axis=fast, slow_axis=slow)
                gn = BK.shard_global_norm(s, fast)
                full = BK.all_gather_buckets(s, fast_axis=fast)
                return BK.unflatten_from_buckets(
                    layout, full, dtype=jnp.float32), gn

            # rank i contributes tree * (i+1): mean = tree * (n+1)/2
            def scaled(t, i):
                return jax.tree.map(
                    lambda x: x.astype(jnp.float32) * (i + 1.0), t)
            stacked = jax.tree.map(
                lambda x: jnp.stack([np.asarray(
                    x.astype(jnp.float32)) * (i + 1.0)
                    for i in range(n)]), tree)

            got, gn = jax.jit(PX.shard_map(
                rank, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(names), stacked),),
                out_specs=(jax.tree.map(lambda _: P(), tree), P()),
                check_vma=False, axis_names=set(names)))(stacked)

            want = jax.tree.map(
                lambda x: np.asarray(x, np.float32) * (n + 1) / 2.0, tree)
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                np.testing.assert_allclose(np.asarray(a), b,
                                           rtol=1e-6, atol=1e-6)
            # the shard-computed norm is the global norm of the mean tree
            ref = np.sqrt(sum(float(np.sum(np.square(b)))
                              for b in jax.tree.leaves(want)))
            np.testing.assert_allclose(float(gn), ref, rtol=1e-5)
        print("BUCKET_SCHED_OK")
        """, n_devices=4)
    assert "BUCKET_SCHED_OK" in out


def test_train_modes_equivalent_multidevice():
    """hier / hier_bucketed / hier_bucketed_zero1 match the xla step on a
    (pod, data) mesh, and the bucketed pair is bitwise-identical."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import optim
        from repro.models.registry import get_config, build_model, \\
            reduced_config
        from repro.sharding import make_rules
        from repro.train import make_jitted_train_step, make_bucket_layout

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        rules = make_rules(mesh, fsdp=False)
        rng = jax.random.key(1)
        batch = {'tokens': jax.random.randint(rng, (8, 32), 0,
                                              cfg.vocab_size),
                 'targets': jax.random.randint(rng, (8, 32), 0,
                                               cfg.vocab_size)}
        ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                 total_steps=30)
        results = {}
        for mode in ('xla', 'hier', 'hier_bucketed',
                     'hier_bucketed_zero1'):
            p = model.init(jax.random.key(0))
            if mode == 'hier_bucketed_zero1':
                layout = make_bucket_layout(p, mesh)
                st = optim.init_bucketed(ocfg, p, layout)
            else:
                st = optim.init(ocfg, p)
            step = make_jitted_train_step(model, ocfg, accum=2,
                                          rules=rules,
                                          cross_pod_mode=mode)
            losses = []
            with mesh:
                for i in range(4):
                    p, st, m = step(p, st, batch)
                    losses.append(float(m['loss']))
            results[mode] = (losses, p)

        ref = results['xla'][0]
        for mode in ('hier', 'hier_bucketed', 'hier_bucketed_zero1'):
            np.testing.assert_allclose(results[mode][0], ref,
                                       rtol=1e-4, atol=1e-5)
        assert results['hier_bucketed'][0] == \\
            results['hier_bucketed_zero1'][0]
        for a, b in zip(jax.tree.leaves(results['hier_bucketed'][1]),
                        jax.tree.leaves(
                            results['hier_bucketed_zero1'][1])):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("MODES_OK")
        """, n_devices=4)
    assert "MODES_OK" in out


def test_zero1_bitwise_parity_20_steps_multidevice():
    """Acceptance: hier_bucketed_zero1 preserves bitwise-identical loss
    curves vs hier_bucketed over a 20-step run on a (pod, data) mesh,
    with the optimizer state sharded over the fast axis."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.data import DataConfig, SyntheticCorpus
        from repro.models.registry import get_config, build_model, \\
            reduced_config
        from repro.sharding import make_rules
        from repro.train import make_jitted_train_step, make_bucket_layout

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        rules = make_rules(mesh, fsdp=False)
        corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=16, global_batch=8))
        ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=3,
                                 total_steps=40)
        curves = {}
        for mode in ('hier_bucketed', 'hier_bucketed_zero1'):
            p = model.init(jax.random.key(0))
            if mode == 'hier_bucketed_zero1':
                layout = make_bucket_layout(p, mesh)
                st = optim.init_bucketed(ocfg, p, layout)
                shard = NamedSharding(mesh, P('data'))
                st = optim.BucketedOptState(
                    step=st.step,
                    mu=tuple(jax.device_put(b, shard) for b in st.mu),
                    nu=tuple(jax.device_put(b, shard) for b in st.nu),
                    master=tuple(jax.device_put(b, shard)
                                 for b in st.master))
            else:
                st = optim.init(ocfg, p)
            step = make_jitted_train_step(model, ocfg, accum=1,
                                          rules=rules,
                                          cross_pod_mode=mode)
            losses = []
            with mesh:
                for i in range(20):
                    b = {k: jnp.asarray(v)
                         for k, v in corpus.batch(i).items()}
                    p, st, m = step(p, st, b)
                    losses.append(float(m['loss']))
            curves[mode] = losses
        assert curves['hier_bucketed'] == curves['hier_bucketed_zero1'], (
            curves)
        assert curves['hier_bucketed'][0] != curves['hier_bucketed'][-1]
        print("ZERO1_BITWISE_OK")
        """, n_devices=4)
    assert "ZERO1_BITWISE_OK" in out


# ------------------------------------------------------ flat optim pieces

def test_apply_flat_matches_apply_elementwise():
    """apply_flat on flat buckets == apply on the tree, bit for bit."""
    params = {"w": jnp.linspace(-1, 1, 12, dtype=jnp.bfloat16
                                ).reshape(3, 4),
              "b": jnp.zeros((5,), jnp.float32)}
    grads32 = {"w": jnp.linspace(0.1, 0.5, 12).reshape(3, 4),
               "b": jnp.full((5,), -0.2)}
    cfg = optim.AdamWConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10)
    layout = BK.plan_buckets(params, bucket_bytes=40)   # multiple buckets
    tree_state = optim.init(cfg, params)
    flat_state = optim.init_bucketed(cfg, params, layout)
    gnorm = optim.global_norm(grads32)

    for _ in range(3):
        params, tree_state, m1 = optim.apply(cfg, params, grads32,
                                             tree_state, gnorm=gnorm)
        gb = BK.flatten_to_buckets(layout, grads32)
        flat_state, m2 = optim.apply_flat(cfg, gb, flat_state,
                                          gnorm=gnorm)
        rebuilt = BK.unflatten_from_buckets(layout, flat_state.master)
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(m1["lr"]) == float(m2["lr"])


def test_init_bucketed_requires_masters():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    layout = BK.plan_buckets(params)
    with pytest.raises(AssertionError):
        optim.init_bucketed(optim.AdamWConfig(use_master=False), params,
                            layout)


def test_bucketed_modes_on_size1_mesh():
    """A (1,1) (pod, data) mesh must degenerate to the local path — the
    axis names must never reach a collective outside shard_map."""
    from repro.models.registry import build_model, get_config, \
        reduced_config
    from repro.sharding import make_rules
    from repro.train import make_bucket_layout, make_jitted_train_step
    from repro import parallel as PX

    cfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(cfg, remat=False)
    mesh = PX.make_device_mesh((1, 1), ("pod", "data"),
                               devices=jax.devices()[:1])
    rules = make_rules(mesh, fsdp=False)
    rng = jax.random.key(1)
    batch = {"tokens": jax.random.randint(rng, (4, 32), 0,
                                          cfg.vocab_size),
             "targets": jax.random.randint(rng, (4, 32), 0,
                                           cfg.vocab_size)}
    ocfg = optim.AdamWConfig()
    losses = []
    for mode in ("hier", "hier_bucketed", "hier_bucketed_zero1"):
        p = model.init(jax.random.key(0))
        st = (optim.init_bucketed(ocfg, p, make_bucket_layout(p, mesh))
              if mode == "hier_bucketed_zero1" else optim.init(ocfg, p))
        step = make_jitted_train_step(model, ocfg, accum=1, rules=rules,
                                      cross_pod_mode=mode)
        with mesh:
            p, st, m = step(p, st, batch)
        losses.append(float(m["loss"]))
    assert losses[0] == losses[1] == losses[2]


def test_unknown_mode_rejected():
    from repro.train import make_train_step
    with pytest.raises(ValueError, match="cross_pod_mode"):
        make_train_step(object(), optim.AdamWConfig(),
                        cross_pod_mode="nope")
