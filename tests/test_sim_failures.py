"""Seeded MTBF host failures in the simulator + failure-time repack.

The failure plane is strictly opt-in: without a ``FailureModel`` the
simulator must stay bit-identical to the failure-free runs the golden
tests pin.  With one armed, jobs still all finish, lost work and
restart charges are accounted, and goodput drops below 1.
"""
import dataclasses

import numpy as np
import pytest

from repro import ckpt as ckpt_lib
from repro.core.jct_model import ReconfigCostModel
from repro.core.leaves import TpuLeaf
from repro.core.simulator import FailureModel, simulate
from repro.core.traces import TraceCategory, generate_trace
from repro.elastic import repack_on_failure


def _trace(seed=0, size_dist="balanced", mix="train", max_size=4):
    return generate_trace(TraceCategory("philly", size_dist, mix),
                          seed=seed, double=False, max_size=max_size)


FM = FailureModel(mtbf_s=3 * 3600.0, ckpt_interval_s=600.0)


def test_failure_model_validation():
    with pytest.raises(ValueError, match="mtbf"):
        FailureModel(mtbf_s=0.0)
    with pytest.raises(ValueError, match="ckpt_interval"):
        FailureModel(mtbf_s=1.0, ckpt_interval_s=-1.0)
    with pytest.raises(ValueError, match="max_failures"):
        FailureModel(mtbf_s=1.0, max_failures=0)


def test_opt_in_default_is_bit_identical():
    jobs = _trace()
    base = simulate(jobs, "DM")
    again = simulate(_trace(), "DM", failure_model=None)
    assert dataclasses.asdict(base) == dataclasses.asdict(again)
    assert base.n_failures == 0 and base.failure_lost_work_s == 0.0
    # reconfig suspension already counts against goodput; failures are
    # simply absent from it here
    assert 0.0 < base.goodput <= 1.0


def test_failures_occur_and_all_jobs_still_finish():
    jobs = _trace()
    r = simulate(jobs, "DM", failure_model=FM)
    assert r.n_failures > 0, "MTBF of 3h must strike this trace"
    assert r.n_jobs == len(jobs)                # conservation holds
    assert r.n_recoveries > 0
    assert r.failure_lost_work_s >= 0.0
    assert r.failure_restart_cost_s > 0.0


def test_goodput_degrades_under_failures():
    jobs = _trace()
    clean = simulate(jobs, "DM")
    faulty = simulate(_trace(), "DM", failure_model=FM)
    assert 0.0 <= faulty.goodput < clean.goodput
    assert faulty.goodput < 1.0


def test_seeded_failures_are_deterministic():
    a = simulate(_trace(), "DM", failure_model=FM, seed=0)
    b = simulate(_trace(), "DM", failure_model=FM, seed=0)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
    c = simulate(_trace(), "DM", failure_model=FM, seed=1)
    assert dataclasses.asdict(c) != dataclasses.asdict(a)


def test_handoff_restart_charge_never_exceeds_drain():
    """failure_restart_s: the handoff recovery is min-capped at the
    drain constant, so per-run restart cost under a handoff cost model
    can't exceed the drain model's for the same failure sequence."""
    jobs = _trace()
    drain = simulate(jobs, "DM", failure_model=FM,
                     reconfig_cost=ReconfigCostModel(mode="drain"))
    hand = simulate(_trace(), "DM", failure_model=FM,
                    reconfig_cost=ReconfigCostModel(mode="handoff"))
    assert drain.n_failures == hand.n_failures  # same seeded sequence
    assert hand.failure_restart_cost_s <= drain.failure_restart_cost_s \
        + 1e-9


def test_max_failures_bounds_the_plane():
    one = FailureModel(mtbf_s=600.0, max_failures=1)
    r = simulate(_trace(), "DM", failure_model=one)
    assert r.n_failures <= 1
    assert r.n_jobs == len(_trace())


def test_cost_model_failure_restart_semantics():
    cm_d = ReconfigCostModel(mode="drain")
    cm_h = ReconfigCostModel(mode="handoff")
    state = 4 << 30
    assert cm_d.failure_restart_s(state, drain_restart_s=7.0) == 7.0
    h = cm_h.failure_restart_s(state, drain_restart_s=7.0, n_ranks_new=8)
    assert 0.0 < h <= 7.0
    # more survivors -> each restores a smaller share, never slower
    h1 = cm_h.failure_restart_s(state, drain_restart_s=1e9, n_ranks_new=1)
    h8 = cm_h.failure_restart_s(state, drain_restart_s=1e9, n_ranks_new=8)
    assert h8 <= h1


# ---------------------------------------------------- repack_on_failure

def _leaves(n_hosts, chips=2):
    return [TpuLeaf(pod=0, host=h, chip=c)
            for h in range(n_hosts) for c in range(chips)]


def test_repack_on_failure_shrinks_to_survivors():
    plan = repack_on_failure(_leaves(4), [(0, 1)], model_parallel=1)
    assert plan is not None
    assert (0, 1) not in {(l.pod, l.host) for l in plan.surviving}
    assert int(np.prod(plan.mesh_shape)) == len(plan.surviving)
    assert plan.handoff is None                 # no ckpt dir given


def test_repack_on_failure_none_when_too_few_survive():
    # every host dead: not even one model shard can form
    assert repack_on_failure(_leaves(2),
                             [(0, 0), (0, 1)], model_parallel=1) is None


def test_repack_on_failure_drops_uncommitted_ckpt_dir(tmp_path):
    """A failure before the first commit restarts from scratch instead
    of refusing (contrast: planned plan_elastic_remesh raises here)."""
    plan = repack_on_failure(_leaves(4), [(0, 1)], model_parallel=1,
                             ckpt_base_dir=str(tmp_path))
    assert plan is not None and plan.handoff is None


def test_repack_on_failure_carries_committed_handoff(tmp_path):
    tree = {"w": np.arange(8, dtype=np.float32)}
    ckpt_lib.save_sharded(ckpt_lib.step_dir(str(tmp_path), 30), 30, tree)
    plan = repack_on_failure(_leaves(4), [(0, 1)], model_parallel=1,
                             ckpt_base_dir=str(tmp_path))
    assert plan is not None and plan.handoff is not None
    assert plan.handoff.step == 30
