"""Strong correctness check: token-by-token decode reproduces the parallel
forward's next-token logits (KV caches, SSM states, conv states, rotary
offsets all have to line up for this to pass)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import build_model, get_config, reduced_config

# one representative per family (full matrix is slow on 1 CPU core)
FAMILIES = ["llama3.2-1b", "deepseek-v2-lite-16b", "zamba2-1.2b",
            "xlstm-125m", "qwen2-moe-a2.7b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_parallel_forward(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:
        # capacity dropping legitimately differs between a batched forward
        # (T tokens compete) and one-token decode; test the drop-free path
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg, remat=False)
    rng = jax.random.key(3)
    params = model.init(rng)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}

    full_logits, _ = jax.jit(model.forward_logits)(params, batch)

    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    dec_logits = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        dec_logits.append(lg[:, 0])
    dec = jnp.stack(dec_logits, axis=1)

    a = np.asarray(full_logits.astype(jnp.float32))
    b = np.asarray(dec.astype(jnp.float32))
    # bf16 params + different contraction orders (e.g. MLA's absorbed
    # decode): compare in quantile + top-1 terms
    diff = np.abs(a - b)
    assert float(np.quantile(diff, 0.999)) < 0.2, (
        f"{arch}: p99.9 |diff| = {np.quantile(diff, 0.999)}")
    assert float(diff.max()) < 0.5, f"{arch}: max |diff| = {diff.max()}"
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    assert agree > 0.9, f"{arch}: argmax agreement {agree}"
