"""Simulator invariants + paper-claim checks, incl. hypothesis properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no-network env: deterministic example-based shim
    from tests._hypothesis_stub import given, settings, st

from repro.core.job import Job
from repro.core.metrics import ModeComparison
from repro.core.simulator import simulate
from repro.core.traces import (ALL_CATEGORIES, TraceCategory,
                               generate_trace, models_for)


def _trace(seed=0, size_dist="balanced", mix="train", max_size=4):
    return generate_trace(TraceCategory("philly", size_dist, mix),
                          seed=seed, double=False, max_size=max_size)


def test_all_jobs_complete_every_mode():
    jobs = _trace()
    for mode in ("FM", "DM", "SM"):
        r = simulate(jobs, mode)
        assert r.n_jobs == len(jobs), mode


def test_fm_never_reconfigures():
    r = simulate(_trace(), "FM")
    assert r.n_reconfigs == 0


def test_fm_no_external_fragmentation():
    r = simulate(_trace(), "FM")
    assert r.avg_ext_frag_delay == pytest.approx(0.0, abs=1.0)


def test_dm_reconfigures_under_churn():
    r = simulate(_trace(size_dist="small"), "DM")
    assert r.n_reconfigs > 0


def test_utilization_bounded():
    for mode in ("FM", "DM", "SM"):
        r = simulate(_trace(), mode)
        assert 0.0 < r.utilization <= 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       size_dist=st.sampled_from(["small", "balanced", "large"]),
       mode=st.sampled_from(["FM", "DM", "SM"]),
       policy=st.sampled_from(["fifo", "backfill"]))
def test_property_invariants(seed, size_dist, mode, policy):
    jobs = _trace(seed=seed, size_dist=size_dist)
    r = simulate(jobs, mode, policy=policy)
    # conservation: every job finishes exactly once
    assert r.n_jobs == len(jobs)
    # causality: waits and JCTs non-negative
    assert all(w >= -1e-9 for w in r.wait_by_job.values())
    assert all(j > 0 for j in r.jct_by_job.values())
    # makespan dominates the longest single execution
    assert r.makespan >= max(r.jct_by_job.values()) - 1e-6
    assert 0.0 < r.utilization <= 1.0 + 1e-9


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_fm_beats_dm_makespan_mostly(seed):
    """The paper's headline direction (not magnitude): FM makespan <= DM
    within tolerance on FIFO train traces."""
    jobs = _trace(seed=seed, size_dist="large")
    fm = simulate(jobs, "FM")
    dm = simulate(jobs, "DM")
    assert fm.makespan <= dm.makespan * 1.10


def test_paper_claims_fm_vs_dm():
    """§5.3: FM lowers waiting (~11% vs DM), JCT within +10%, shorter
    makespan; averaged over categories."""
    ratios = []
    for seed in range(5):
        jobs = generate_trace(
            TraceCategory("helios_earth", "large", "train"),
            seed=seed, double=True, max_size=4)
        fm = simulate(jobs, "FM")
        dm = simulate(jobs, "DM")
        ratios.append(ModeComparison.of(fm, dm))
    mk = np.mean([r.makespan_ratio for r in ratios])
    wait = np.mean([r.wait_ratio for r in ratios])
    jct = np.mean([r.jct_ratio for r in ratios])
    assert mk < 1.0                               # shorter makespan
    assert wait < 0.95                            # visibly lower waiting
    assert jct < 1.15                             # modest per-job penalty


def test_backfill_helps_or_equal():
    jobs = _trace(size_dist="small", mix="mixed", max_size=None)
    f = simulate(jobs, "FM", policy="fifo")
    b = simulate(jobs, "FM", policy="backfill")
    # backfilling reliably reduces waiting; makespan can shift either way
    # slightly as jobs reorder
    assert b.avg_wait <= f.avg_wait * 1.02
    assert b.makespan <= f.makespan * 1.15


def test_calibration_factor_increases_jct():
    jobs = _trace()
    cal = simulate(jobs, "FM", calibrate=True)
    raw = simulate(jobs, "FM", calibrate=False)
    assert cal.avg_jct >= raw.avg_jct


def test_trace_generator_categories():
    assert len(ALL_CATEGORIES) == 36              # 4 x 3 x 3
    jobs = generate_trace(ALL_CATEGORIES[0], seed=1, double=True)
    assert len(jobs) >= 60                        # ~62-64 doubled jobs
    assert all(j.base_duration >= 600 for j in jobs)
    assert all(j.base_duration <= 7200 for j in jobs)


def test_models_for_size():
    assert "resnet50" in models_for("train", 4)
    assert "resnet18" not in models_for("train", 4)
    assert "resnet101" not in models_for("inference", 1)
