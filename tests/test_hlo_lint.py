"""HLO lint rules: one seeded-bug positive + one clean negative per rule,
plus golden parse tests against real lowered train-step modules.

The positives reconstruct bugs this repo actually shipped: the PR 4
``init_bucketed`` donation alias (a donated buffer escaping unaliased)
and the PR 4 missing-``optimization_barrier`` 1-ulp drift (an unsealed
deterministic tree fold).
"""
import gzip
import os

import pytest

from repro.analysis import hlo, ir
from repro.analysis.lint import (LintContext, all_rules, budget_for,
                                 load_budgets, run_rules)
from tests.conftest import run_multidevice

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def _fixture(name):
    with gzip.open(os.path.join(FIXTURES, name), "rt") as f:
        return f.read()


# ---------------------------------------------------------------------------
# synthetic corpus helpers
# ---------------------------------------------------------------------------

_ADD_F32 = """
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""

_ADD_BF16 = """
%addb (a: bf16[], b: bf16[]) -> bf16[] {
  %a = bf16[] parameter(0)
  %b = bf16[] parameter(1)
  ROOT %s = bf16[] add(%a, %b)
}
"""

_MIN_BF16 = """
%minb (a: bf16[], b: bf16[]) -> bf16[] {
  %a = bf16[] parameter(0)
  %b = bf16[] parameter(1)
  ROOT %m = bf16[] minimum(%a, %b)
}
"""


def _mod(body, *, header="", computations=_ADD_F32):
    return f"HloModule synth{header}\n{computations}\n{body}"


def _ctx(optimized, lowered=None, budget=None, **config):
    cfg = {"chips_per_pod": 2, "n_buckets": 0, "grad_bytes": 0}
    cfg.update(config)
    return LintContext(optimized=ir.parse(optimized),
                       lowered=ir.parse(lowered) if lowered else None,
                       config=cfg, budget=budget)


def _rules_of(findings):
    return sorted({f.rule for f in findings})


# a plain (non-det, non-overlap) clean program: one intra-pod
# reduce-scatter + cross-pod all-reduce + all-gather in f32
_CLEAN_HIER = _mod("""
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %rs = f32[4] reduce-scatter(%p0), replica_groups={{0,1},{2,3}}, dimensions={0}, to_apply=%add
  %ar = f32[4] all-reduce(%rs), replica_groups={{0,2},{1,3}}, to_apply=%add
  ROOT %ag = f32[8] all-gather(%ar), replica_groups={{0,1},{2,3}}, dimensions={0}
}
""")


def test_registry_has_the_five_rules():
    assert set(all_rules()) >= {
        "collective-budget", "deterministic-reduce", "donation-aliasing",
        "precision", "overlap-independence"}


def test_run_rules_rejects_unknown_rule():
    with pytest.raises(KeyError):
        run_rules(_ctx(_CLEAN_HIER), only=["not-a-rule"])


def test_clean_program_no_findings():
    assert run_rules(_ctx(_CLEAN_HIER)) == []


# ---------------------------------------------------------------------------
# collective-budget
# ---------------------------------------------------------------------------

def test_budget_flags_count_drift():
    """An extra all-reduce (vs the declared budget) fails with a
    diff-style message naming the kind and the delta."""
    budget = {"fixed": {"all-reduce": 1, "reduce-scatter": 1,
                        "all-gather": 1}}
    f = run_rules(_ctx(_CLEAN_HIER, budget=budget),
                  only=["collective-budget"])
    assert not f
    budget2 = {"fixed": {"reduce-scatter": 1, "all-gather": 1}}
    f = run_rules(_ctx(_CLEAN_HIER, budget=budget2),
                  only=["collective-budget"])
    assert _rules_of(f) == ["collective-budget"]
    assert "all-reduce: budget 0" in f[0].message
    assert "+1" in f[0].message


def test_budget_per_bucket_scaling():
    """per_bucket x n_buckets + fixed composes the expectation (the
    hier_bucketed '3 per bucket' declaration)."""
    body = _mod("""
ENTRY %main (p0: f32[8], p1: f32[8]) -> (f32[8], f32[8]) {
  %p0 = f32[8] parameter(0)
  %p1 = f32[8] parameter(1)
  %a0 = f32[8] all-reduce(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  %a1 = f32[8] all-reduce(%p1), replica_groups={{0,2},{1,3}}, to_apply=%add
  %l = f32[8] all-reduce(%a0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (f32[8], f32[8]) tuple(%a1, %l)
}
""")
    budget = {"fixed": {"all-reduce": 1}, "per_bucket": {"all-reduce": 1}}
    assert not run_rules(_ctx(body, budget=budget, n_buckets=2),
                         only=["collective-budget"])
    f = run_rules(_ctx(body, budget=budget, n_buckets=3),
                  only=["collective-budget"])
    assert f and "budget 4 (1 + 1/bucket x 3), got 3 (-1)" in f[0].message


def test_budget_full_gather_tripwire():
    """Payload above the declared grad-bytes multiple fails — the
    accidental param/master full-gather detector."""
    budget = {"fixed": {"all-reduce": 1, "reduce-scatter": 1,
                        "all-gather": 1},
              "max_operand_bytes_factor": 1.0}
    # operand bytes: 32 (rs) + 16 (ar) + 16 (ag) = 64 > 1.0 * 48
    f = run_rules(_ctx(_CLEAN_HIER, budget=budget, grad_bytes=48),
                  only=["collective-budget"])
    assert f and "full gather" in f[0].message
    assert not run_rules(_ctx(_CLEAN_HIER, budget=budget, grad_bytes=64),
                         only=["collective-budget"])


# ---------------------------------------------------------------------------
# deterministic-reduce
# ---------------------------------------------------------------------------

# the pinned gather + fixed-tree fold, sealed behind opt-barrier (the
# shape `collectives.deterministic.det_reduce_bucket_full` lowers to)
_DET_PRE_SEALED = _mod("""
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %ag = f32[16] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %s0 = f32[8] slice(%ag), slice={[0:8]}
  %s1 = f32[8] slice(%ag), slice={[8:16]}
  %fold = f32[8] add(%s0, %s1)
  %t = (f32[8]) tuple(%fold)
  %seal = (f32[8]) opt-barrier(%t)
  ROOT %out = f32[8] get-tuple-element(%seal), index=0
}
""")

# PR 4 bug reconstruction: the same fold with no optimization_barrier —
# XLA is free to refold the tree, 1-ulp drift across factorizations
_DET_PRE_UNSEALED = _mod("""
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %ag = f32[16] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %s0 = f32[8] slice(%ag), slice={[0:8]}
  %s1 = f32[8] slice(%ag), slice={[8:16]}
  ROOT %fold = f32[8] add(%s0, %s1)
}
""")

# gather-only optimized program (what det mode must compile to)
_DET_POST_CLEAN = _mod("""
ENTRY %main (p0: f32[8]) -> f32[16] {
  %p0 = f32[8] parameter(0)
  ROOT %ag = f32[16] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
}
""")


def test_det_rule_negative_sealed_fold():
    assert not run_rules(
        _ctx(_DET_POST_CLEAN, lowered=_DET_PRE_SEALED,
             deterministic_reduce=True), only=["deterministic-reduce"])


def test_det_rule_flags_missing_barrier():
    """The PR 4 drift: no optimization_barrier in the lowered program."""
    f = run_rules(_ctx(_DET_POST_CLEAN, lowered=_DET_PRE_UNSEALED,
                       deterministic_reduce=True),
                  only=["deterministic-reduce"])
    assert len(f) == 1 and "no optimization_barrier" in f[0].message


def test_det_rule_flags_barrier_without_gather_cone():
    """A barrier sealing something other than the gathered fold does not
    satisfy the contract."""
    body = _mod("""
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %ag = f32[16] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %t = (f32[8]) tuple(%p0)
  %seal = (f32[8]) opt-barrier(%t)
  ROOT %out = f32[8] get-tuple-element(%seal), index=0
}
""")
    f = run_rules(_ctx(_DET_POST_CLEAN, lowered=body,
                       deterministic_reduce=True),
                  only=["deterministic-reduce"])
    assert len(f) == 1 and "no all-gather feeds" in f[0].message


def test_det_rule_flags_raw_all_reduce():
    """Any surviving all-reduce/reduce-scatter in a det program is a
    mesh-factorization-dependent reduction order."""
    f = run_rules(_ctx(_CLEAN_HIER, lowered=_DET_PRE_SEALED,
                       deterministic_reduce=True),
                  only=["deterministic-reduce"])
    kinds = {x.op for x in f}
    assert "ar" in kinds and "rs" in kinds


def test_det_rule_inactive_outside_det_mode():
    assert not run_rules(_ctx(_CLEAN_HIER, deterministic_reduce=False),
                         only=["deterministic-reduce"])


# ---------------------------------------------------------------------------
# donation-aliasing
# ---------------------------------------------------------------------------

_DONOR_PRE = _mod("""
ENTRY %main (p0: f32[8], p1: f32[8]) -> (f32[8], f32[8]) {
  %p0 = f32[8] parameter(0)
  %p1 = f32[8] parameter(1)
  %a = f32[8] add(%p0, %p1)
  %b = f32[8] multiply(%p0, %p1)
  ROOT %t = (f32[8], f32[8]) tuple(%a, %b)
}
""", header=", buffer_donor={ (0, {}), (1, {}) }")


def _post_aliased(alias_header):
    return _mod("""
ENTRY %main (p0: f32[8], p1: f32[8]) -> (f32[8], f32[8]) {
  %p0 = f32[8] parameter(0)
  %p1 = f32[8] parameter(1)
  %a = f32[8] add(%p0, %p1)
  %b = f32[8] multiply(%p0, %p1)
  ROOT %t = (f32[8], f32[8]) tuple(%a, %b)
}
""", header=", input_output_alias={ " + alias_header + " }")


def test_donation_negative_all_realized():
    post = _post_aliased("{0}: (0, {}, may-alias), "
                         "{1}: (1, {}, may-alias)")
    assert not run_rules(_ctx(post, lowered=_DONOR_PRE),
                         only=["donation-aliasing"])


def test_donation_flags_escaped_donor():
    """The PR 4 init_bucketed bug: a donated buffer kept alive by a
    live use never gets an input_output_alias entry — donation is
    silently dropped and peak memory grows."""
    post = _post_aliased("{0}: (0, {}, may-alias)")
    f = run_rules(_ctx(post, lowered=_DONOR_PRE),
                  only=["donation-aliasing"])
    assert len(f) == 1
    assert "parameter 1 escapes unaliased" in f[0].message


def test_donation_flags_double_alias():
    post = _post_aliased("{0}: (0, {}, may-alias), "
                         "{1}: (0, {}, may-alias)")
    f = run_rules(_ctx(post, lowered=None), only=["donation-aliasing"])
    assert len(f) == 1 and "two outputs" in f[0].message


def test_donation_silent_without_donors():
    """No donation offers (no lowered text, no declared list): nothing
    to check, no findings."""
    post = _post_aliased("{0}: (0, {}, may-alias)")
    assert not run_rules(_ctx(post), only=["donation-aliasing"])


# ---------------------------------------------------------------------------
# precision
# ---------------------------------------------------------------------------

def _bf16_reduce(groups, apply_comp="%addb"):
    return _mod(f"""
ENTRY %main (p0: bf16[8]) -> bf16[8] {{
  %p0 = bf16[8] parameter(0)
  ROOT %ar = bf16[8] all-reduce(%p0), replica_groups={groups}, to_apply={apply_comp}
}}
""", computations=_ADD_F32 + _ADD_BF16 + _MIN_BF16)


def test_precision_flags_bf16_accumulation():
    f = run_rules(_ctx(_bf16_reduce("{{0,1},{2,3}}")), only=["precision"])
    assert len(f) == 1 and "bf16" in f[0].message


def test_precision_negative_f32():
    assert not run_rules(_ctx(_CLEAN_HIER), only=["precision"])


def test_precision_allows_declared_bf16_slow_hop():
    """slow_compress_bits=16 declares the cross-pod hop bf16 — legal
    there, still illegal on intra-pod groups."""
    cross = _bf16_reduce("{{0,2},{1,3}}")
    intra = _bf16_reduce("{{0,1},{2,3}}")
    assert not run_rules(_ctx(cross, slow_compress_bits=16),
                         only=["precision"])
    assert run_rules(_ctx(intra, slow_compress_bits=16),
                     only=["precision"])


def test_precision_ignores_non_additive_reduction():
    """A bf16 min-reduction is not accumulation; only additive applies
    are gated."""
    assert not run_rules(_ctx(_bf16_reduce("{{0,1},{2,3}}", "%minb")),
                         only=["precision"])


# ---------------------------------------------------------------------------
# overlap-independence
# ---------------------------------------------------------------------------

_CHAINED_SLOW = _mod("""
ENTRY %main (p0: f32[8], p1: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %p1 = f32[8] parameter(1)
  %ar0 = f32[8] all-reduce(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  %mix = f32[8] add(%ar0, %p1)
  ROOT %ar1 = f32[8] all-reduce(%mix), replica_groups={{0,2},{1,3}}, to_apply=%add
}
""")

_INDEPENDENT_SLOW = _mod("""
ENTRY %main (p0: f32[8], p1: f32[8]) -> (f32[8], f32[8]) {
  %p0 = f32[8] parameter(0)
  %p1 = f32[8] parameter(1)
  %ar0 = f32[8] all-reduce(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  %ar1 = f32[8] all-reduce(%p1), replica_groups={{0,2},{1,3}}, to_apply=%add
  ROOT %t = (f32[8], f32[8]) tuple(%ar0, %ar1)
}
""")


def test_overlap_flags_dependent_slow_collectives():
    f = run_rules(_ctx(_CHAINED_SLOW, overlap=True),
                  only=["overlap-independence"])
    assert len(f) == 1 and "cannot pipeline" in f[0].message
    assert f[0].op.endswith("ar1")


def test_overlap_negative_independent():
    assert not run_rules(_ctx(_INDEPENDENT_SLOW, overlap=True),
                         only=["overlap-independence"])


def test_overlap_warns_when_nothing_crosses_pods():
    intra = _mod("""
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  ROOT %ar = f32[8] all-reduce(%p0), replica_groups={{0,1},{2,3}}, to_apply=%add
}
""")
    f = run_rules(_ctx(intra, overlap=True),
                  only=["overlap-independence"])
    assert len(f) == 1 and f[0].severity == "warning"


def test_overlap_rule_inactive_without_overlap():
    assert not run_rules(_ctx(_CHAINED_SLOW, overlap=False),
                         only=["overlap-independence"])


# ---------------------------------------------------------------------------
# parser hardening (satellite: async collectives, nested fusions,
# multi-line op attrs)
# ---------------------------------------------------------------------------

def test_parse_async_pairing():
    body = _mod("""
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %ars = f32[8] all-reduce-start(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  ROOT %ard = f32[8] all-reduce-done(%ars)
}
""")
    m = ir.parse(body)
    assert m.async_pairs() == {"ars": "ard"}
    starts = [o for _, o in m.ops() if o.is_async_start]
    assert starts[0].collective_kind == "all-reduce"


def test_parse_nested_fusion_call_graph():
    body = _mod("""
%inner (q: f32[8]) -> f32[8] {
  %q = f32[8] parameter(0)
  ROOT %n = f32[8] negate(%q)
}

%outer (r: f32[8]) -> f32[8] {
  %r = f32[8] parameter(0)
  ROOT %c = f32[8] fusion(%r), kind=kLoop, calls=%inner
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  ROOT %f = f32[8] fusion(%p0), kind=kLoop, calls=%outer
}
""")
    m = ir.parse(body)
    f = m.entry.op("f")
    assert m.called_computations(f) == ["outer"]
    c = m.computations["outer"].op("c")
    assert m.called_computations(c) == ["inner"]


def test_parse_multiline_wrapped_attrs():
    """The printer wraps long replica_groups/backend_config attrs; the
    logical-line joiner must reassemble them (brackets inside quoted
    metadata strings must not skew the balance)."""
    body = _mod("""
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  ROOT %ar = f32[8] all-reduce(%p0), replica_groups={{0,2},
    {1,3}}, to_apply=%add,
    metadata={op_name="jit(main)/while[body]{nested}" source_file="x.py"}
}
""")
    m = ir.parse(body)
    ar = m.entry.op("ar")
    assert ar is not None and ar.is_collective
    assert ir.parse_replica_groups(ar.attrs) == [[0, 2], [1, 3]]


def test_compressed_mode_raises_not_implemented_multipod():
    out = run_multidevice("""
        import jax
        from repro import optim
        from repro.models.registry import build_model, get_config, \\
            reduced_config
        from repro.sharding import make_rules
        from repro.train import make_train_step
        mesh = jax.make_mesh((2, 2), ("pod", "data"))
        rules = make_rules(mesh, fsdp=False)
        model = build_model(reduced_config(get_config("llama3.2-1b")),
                            remat=False)
        ocfg = optim.AdamWConfig()
        try:
            make_train_step(model, ocfg, rules=rules,
                            cross_pod_mode="compressed")
        except NotImplementedError as e:
            assert "hier_bucketed" in str(e)
            assert "slow_compress_bits=8" in str(e)
            print("COMPRESSED_RAISES_OK")
        """, n_devices=4)
    assert "COMPRESSED_RAISES_OK" in out


# ---------------------------------------------------------------------------
# golden parse: real lowered train-step modules (tests/fixtures)
# ---------------------------------------------------------------------------

def test_golden_preopt_zero1_det_module():
    """Pre-optimization print of the zero1 + deterministic_reduce step
    (micro llama, (2,2) mesh, 2 buckets): donation offers, the sealing
    opt-barrier, gather-only collectives."""
    m = ir.parse(_fixture("train_step_zero1_det.pre.hlo.gz"))
    assert m.entry is not None and m.entry.name.startswith("main")
    # donate_argnums=(0,1): every params/opt leaf offered, batch not
    assert len(m.buffer_donors()) == 18
    barriers = [(c, o) for c, o in m.ops() if o.opcode == "opt-barrier"]
    assert len(barriers) == 1
    # deterministic contract already visible pre-opt: gathers, no raw
    # cross-replica reductions
    kinds = {o.collective_kind for _, o in m.ops() if o.is_collective}
    assert kinds == {"all-gather"}
    assert sum(1 for _, o in m.ops()
               if o.collective_kind == "all-gather") == 8


def test_golden_postopt_overlap_module():
    """Post-optimization print of the hier_bucketed + overlap step:
    realized aliasing, fusions, trip-counted whiles, and the slow-chain
    independence the overlap mode promises."""
    m = ir.parse(_fixture("train_step_hier_bucketed_overlap.post.hlo.gz"))
    assert m.entry is not None
    assert len(m.aliased_param_numbers()) == 45
    assert all(a.kind == "may-alias" for a in m.input_output_aliases())
    stats = hlo.analyze(m, chips_per_pod=2)
    # 3 collectives per bucket x 2 buckets + loss/gnorm all-reduce
    assert stats.collective_ops == {"reduce-scatter": 2, "all-reduce": 4,
                                    "all-gather": 2}
    assert stats.dot_flops > 0 and stats.hbm_bytes > 0
    trips = sorted({m.trip_count(o) for _, o in m.ops()
                    if o.opcode == "while"})
    assert 8 in trips                       # the microbatch/layer scans
    ch = hlo.slow_collective_chains(m, chips_per_pod=2)
    assert ch.n_slow == 3 and ch.independent


def test_golden_budget_cells_cover_matrix():
    """budgets.json declares every canonical matrix cell (the CI lint
    job would silently skip an undeclared cell's budget rule)."""
    budgets = load_budgets()
    for cell in ("xla", "hier", "hier_bucketed", "hier_bucketed_overlap",
                 "hier_bucketed_det", "zero1", "zero1_overlap",
                 "zero1_det"):
        b = budget_for(budgets, cell)
        assert b is not None, cell
        assert b.get("fixed") or b.get("per_bucket"), cell
    # the hier_bucketed contract from the ISSUE: 3 collectives per bucket
    hb = budget_for(budgets, "hier_bucketed")
    assert sum(hb["per_bucket"].values()) == 3
    # det cells must be all-gather-only by construction
    for cell in ("hier_bucketed_det", "zero1_det"):
        b = budget_for(budgets, cell)
        kinds = set(b["fixed"]) | set(b["per_bucket"])
        assert kinds == {"all-gather"}, (cell, kinds)
