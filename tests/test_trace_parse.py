"""CSV trace parsing + tenancy columns (repro.core.traces).

The multi-tenant columns are strictly additive: old trace files (no
``tenant``/``priority_tier`` columns) must parse to byte-identical
Jobs, and the tenant-labelled generator must change nothing but the
labels.
"""
import dataclasses

import pytest

from repro.core.job import DEFAULT_TENANT, TIER_HIGH, TIER_NORMAL
from repro.core.traces import (TraceCategory, generate_trace,
                               load_trace, parse_trace, trace_to_csv)

LEGACY = """\
job_id,model,kind,size,batch,base_duration,submit_time
a,bert-large,train,4,32,1200.0,0.0
b,resnet50,inference,1,8,600.0,30.0
"""

TENANTED = """\
job_id,model,kind,size,batch,base_duration,submit_time,tenant,priority_tier
a,bert-large,train,4,32,1200.0,0.0,acme,0
b,resnet50,inference,1,8,600.0,30.0,beta,1
"""


def test_legacy_trace_gets_single_tenant_defaults():
    jobs = parse_trace(LEGACY)
    assert [j.job_id for j in jobs] == ["a", "b"]
    assert all(j.tenant == DEFAULT_TENANT for j in jobs)
    assert all(j.priority_tier == TIER_NORMAL for j in jobs)
    assert jobs[0].size == 4 and jobs[0].base_duration == 1200.0


def test_tenanted_trace_parses_optional_columns():
    jobs = parse_trace(TENANTED)
    assert jobs[0].tenant == "acme"
    assert jobs[0].priority_tier == TIER_HIGH
    assert jobs[1].tenant == "beta"
    assert jobs[1].priority_tier == TIER_NORMAL


def test_roundtrip_preserves_tenancy(tmp_path):
    jobs = parse_trace(TENANTED)
    path = tmp_path / "trace.csv"
    path.write_text(trace_to_csv(jobs))
    again = load_trace(str(path))
    assert again == jobs


def test_roundtrip_single_tenant_keeps_legacy_columns():
    jobs = parse_trace(LEGACY)
    out = trace_to_csv(jobs)
    # auto-detect: all-default tenancy stays on the original column set
    assert "tenant" not in out.splitlines()[0]
    assert parse_trace(out) == jobs


def test_parse_rejects_malformed():
    with pytest.raises(ValueError, match="missing columns"):
        parse_trace("job_id,model\nx,y\n")
    with pytest.raises(ValueError, match="unknown columns"):
        parse_trace(LEGACY.replace("submit_time",
                                   "submit_time,color").
                    replace(",0.0\n", ",0.0,red\n", 1))
    with pytest.raises(ValueError, match="fields"):
        parse_trace(LEGACY + "c,only,three\n")
    assert parse_trace("") == []


def test_generator_tenant_labels_change_nothing_else():
    cat = TraceCategory("philly", "balanced", "train")
    base = generate_trace(cat, seed=3)
    multi = generate_trace(cat, seed=3, n_tenants=3)
    assert len(base) == len(multi)
    tenants = {j.tenant for j in multi}
    assert tenants == {"t0", "t1", "t2"}
    for a, b in zip(base, multi):
        # every field but the painted-on tenant label is bit-identical
        assert dataclasses.replace(b, tenant=DEFAULT_TENANT) == a
