"""Reshard-on-restore acceptance: bitwise elastic continuation.

Save a ``hier_bucketed_zero1`` + ``deterministic_reduce`` training run's
sharded checkpoint at step 10 on a (2, 2) pod x data mesh, restore onto
(4, 1) and (1, 4) re-factorizations, continue to step 20 — losses and
final params must be bitwise-identical to the uninterrupted 20-step run,
with and without the int8 error-feedback slow hop.  Along the way the
test asserts the sharded-memory guarantee: saved shard files and
restored per-device shards are always 1/F-sized, never a full gathered
bucket.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.train import make_train_step
from tests.conftest import run_multidevice


def test_deterministic_reduce_rejected_outside_bucketed_modes():
    with pytest.raises(ValueError, match="deterministic_reduce"):
        make_train_step(object(), optim.AdamWConfig(),
                        cross_pod_mode="hier", deterministic_reduce=True)
    with pytest.raises(ValueError, match="overlap"):
        make_train_step(object(), optim.AdamWConfig(),
                        cross_pod_mode="hier_bucketed",
                        deterministic_reduce=True, overlap=True)


def test_reshard_continuation_bitwise_multidevice():
    """The PR-4 acceptance criterion, end to end."""
    out = run_multidevice("""
        import os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import ckpt, optim
        from repro.data import DataConfig, SyntheticCorpus
        from repro.models.registry import get_config, build_model, \\
            reduced_config
        from repro.sharding import make_rules
        from repro.train import (EFState, init_sharded_zero1,
                                 init_slow_residuals,
                                 make_jitted_train_step,
                                 make_bucket_layout)

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=16, global_batch=8))
        ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                 total_steps=20)
        bb = 64 << 10                 # multi-bucket layout

        def batches(lo, hi):
            for i in range(lo, hi):
                yield {k: jnp.asarray(v)
                       for k, v in corpus.batch(i).items()}

        def setup(shape, ef):
            mesh = jax.make_mesh(shape, ('pod', 'data'))
            rules = make_rules(mesh, fsdp=False)
            p = model.init(jax.random.key(0))
            layout = make_bucket_layout(p, mesh, bucket_bytes=bb,
                                        deterministic=True)
            st, opt_sh = init_sharded_zero1(ocfg, p, layout, mesh)
            if ef:
                rshard = NamedSharding(mesh, P(('pod', 'data')))
                res = tuple(jax.device_put(r, rshard)
                            for r in init_slow_residuals(
                                p, mesh, bucket_bytes=bb,
                                deterministic=True))
                st = EFState(st, res)
                opt_sh = EFState(opt_sh, (rshard,) * layout.n_buckets)
            step = make_jitted_train_step(
                model, ocfg, accum=1, rules=rules,
                cross_pod_mode='hier_bucketed_zero1', bucket_bytes=bb,
                slow_compress_bits=8 if ef else 0,
                slow_error_feedback=ef, deterministic_reduce=True)
            return mesh, layout, p, st, opt_sh, step

        def train(mesh, step, p, st, lo, hi):
            losses = []
            with mesh:
                for b in batches(lo, hi):
                    p, st, m = step(p, st, b)
                    losses.append(float(m['loss']))
            return losses, p, st

        for ef in (False, True):
            tag = 'ef' if ef else 'noef'
            # uninterrupted 20-step reference on (2, 2)
            mesh, layout, p, st, opt_sh, step = setup((2, 2), ef)
            ref_losses, ref_p, _ = train(mesh, step, p, st, 0, 20)

            # interrupted leg: 10 steps on (2, 2), sharded save
            mesh, layout, p, st, opt_sh, step = setup((2, 2), ef)
            first, p, st = train(mesh, step, p, st, 0, 10)
            assert first == ref_losses[:10], (tag, 'prefix')
            d = tempfile.mkdtemp()
            sdir = ckpt.step_dir(d, 10)
            ckpt.save_sharded(sdir, 10, (p, st), layout=layout,
                              mesh=mesh)
            # no rank ever wrote a full gathered bucket: every shard
            # file of the flat zero1 state spans exactly C/F elements
            man = ckpt.read_manifest(sdir)
            n_sharded = 0
            for key, e in man.leaves.items():
                if e.kind != 'sharded' or len(e.shape) != 1:
                    continue
                n_sharded += 1
                # EF residuals ("[1][1][i]" under EFState) shard over
                # (pod, data) = 4 ways; flat opt buckets over data = 2
                F = 4 if (ef and key.startswith('[1][1]')) else 2
                for s in e.shards:
                    ext = s.index[0][1] - s.index[0][0]
                    assert ext == e.shape[0] // F, (key, s.index,
                                                    e.shape)
            assert n_sharded >= 3 * layout.n_buckets, n_sharded

            # restore onto both re-factorizations and continue
            for shape in ((4, 1), (1, 4)):
                mesh2, layout2, p2, st2, opt_sh2, step2 = setup(shape,
                                                               ef)
                assert layout2.bucket_sizes == layout.bucket_sizes
                rstep, (p2, st2) = ckpt.restore_sharded(
                    sdir, (p2, st2), shardings=(None, opt_sh2),
                    layout=layout2)
                assert rstep == 10
                # each restored device shard is 1/F' of the bucket —
                # restore never materialized a gathered bucket either
                opt2 = st2.opt if ef else st2
                F2 = mesh2.shape['data']
                for x in opt2.master:
                    for sh in x.addressable_shards:
                        (a, b), = [(sl.indices(x.shape[0])[0],
                                    sl.indices(x.shape[0])[1])
                                   for sl in sh.index]
                        assert b - a == x.shape[0] // F2, (shape,
                                                          sh.index)
                cont, p2, _ = train(mesh2, step2, p2, st2, 10, 20)
                assert cont == ref_losses[10:], (tag, shape, cont,
                                                 ref_losses[10:])
                for a, b in zip(jax.tree.leaves(ref_p),
                                jax.tree.leaves(p2)):
                    assert np.array_equal(np.asarray(a),
                                          np.asarray(b)), (tag, shape)
            print(f'CONTINUATION_{tag.upper()}_OK')
        print('RESHARD_BITWISE_OK')
        """, n_devices=4)
    assert "CONTINUATION_NOEF_OK" in out
    assert "CONTINUATION_EF_OK" in out
    assert "RESHARD_BITWISE_OK" in out
