"""Trace-replay regression: golden numbers per OperationMode x policy.

The simulator's O(1)-drain bookkeeping (``_Running.finish_at``), cached
idle-slice sums, and reconfiguration paths are pure refactor targets —
this test pins the end-to-end replay of one fixed trace so any behavioral
drift (as opposed to a speedup) shows up as a diff against these goldens.

The numbers were produced by the current implementation on the pinned
jax/numpy stack; the simulator is pure-Python float arithmetic, so they
are deterministic and exact up to float tolerance.  If a PR changes them
*intentionally* (a modeling change, not a refactor), regenerate and say
so in the PR.
"""
import pytest

from repro.core.simulator import simulate
from repro.core.traces import TraceCategory, generate_trace

GOLDEN = {
    ("FM", "fifo"): dict(makespan=10837.26421867104,
                         avg_jct=1872.2502029235643,
                         avg_wait=3521.3905893048386,
                         frag=0.0, util=0.8896557934142526,
                         n_reconfigs=0, n_drains=0),
    ("FM", "backfill"): dict(makespan=10940.805596136572,
                             avg_jct=1849.9780332670705,
                             avg_wait=3072.668295397557,
                             frag=0.0, util=0.8767286709849166,
                             n_reconfigs=0, n_drains=0),
    ("DM", "fifo"): dict(makespan=15297.269497626332,
                         avg_jct=1914.7769052604087,
                         avg_wait=6179.540084837227,
                         frag=493.9016722068024,
                         util=0.6360196041436966,
                         n_reconfigs=12, n_drains=9),
    ("DM", "backfill"): dict(makespan=13005.961373381286,
                             avg_jct=1920.5833568733121,
                             avg_wait=4494.699267800047,
                             frag=2552.584659606311,
                             util=0.7530132437723299,
                             n_reconfigs=11, n_drains=8),
    ("SM", "fifo"): dict(makespan=11112.661617302752,
                         avg_jct=1622.8848308179004,
                         avg_wait=3788.0336721802314,
                         frag=837.3283532341738,
                         util=0.8451210263096537,
                         n_reconfigs=0, n_drains=0),
    ("SM", "backfill"): dict(makespan=10588.82432352852,
                             avg_jct=1657.2080551997717,
                             avg_wait=3211.9444299310267,
                             frag=613.8954604205466,
                             util=0.886929814311741,
                             n_reconfigs=0, n_drains=0),
}


def _trace():
    return generate_trace(TraceCategory("philly", "balanced", "mixed"),
                          seed=7, double=False, max_size=4)


@pytest.mark.parametrize("mode,policy", sorted(GOLDEN))
def test_trace_replay_matches_golden(mode, policy):
    jobs = _trace()
    assert len(jobs) == 31                     # the trace itself is pinned
    r = simulate(jobs, mode, policy=policy)
    g = GOLDEN[(mode, policy)]
    rel = 1e-9
    assert r.makespan == pytest.approx(g["makespan"], rel=rel)
    assert r.avg_jct == pytest.approx(g["avg_jct"], rel=rel)
    assert r.avg_wait == pytest.approx(g["avg_wait"], rel=rel)
    assert r.avg_ext_frag_delay == pytest.approx(g["frag"], rel=rel,
                                                 abs=1e-9)
    assert r.utilization == pytest.approx(g["util"], rel=rel)
    assert r.n_reconfigs == g["n_reconfigs"]
    assert r.n_drains == g["n_drains"]
    assert r.n_jobs == len(jobs)
