"""Trace-replay regression: golden numbers per OperationMode x policy.

The simulator's O(1)-drain bookkeeping (``_Running.finish_at``), cached
idle-slice sums, and reconfiguration paths are pure refactor targets —
this test pins the end-to-end replay of one fixed trace so any behavioral
drift (as opposed to a speedup) shows up as a diff against these goldens.

Each golden row also pins the reconfiguration accounting: reconfig /
drain / handoff counts and the total suspension cost charged under each
operational model.  The ``handoff`` rows replay DM with the
software-coordinated handoff cost model (default calibration) instead of
the drain-required cycle — the ``reconfig_mode`` threading is itself a
refactor target.

The numbers were produced by the current implementation on the pinned
jax/numpy stack; the simulator is pure-Python float arithmetic, so they
are deterministic and exact up to float tolerance.  If a PR changes them
*intentionally* (a modeling change, not a refactor), regenerate and say
so in the PR.
"""
import pytest

from repro.core.simulator import simulate
from repro.core.traces import TraceCategory, generate_trace

# key: (mode, policy, reconfig_mode)
GOLDEN = {
    ("FM", "fifo", "drain"): dict(
        makespan=10837.26421867104,
        avg_jct=1872.2502029235643,
        avg_wait=3521.3905893048386,
        frag=0.0, util=0.8896557934142526,
        n_reconfigs=0, n_drains=0, n_handoffs=0,
        drain_cost_s=0.0, handoff_cost_s=0.0),
    ("FM", "backfill", "drain"): dict(
        makespan=10940.805596136572,
        avg_jct=1849.9780332670705,
        avg_wait=3072.668295397557,
        frag=0.0, util=0.8767286709849166,
        n_reconfigs=0, n_drains=0, n_handoffs=0,
        drain_cost_s=0.0, handoff_cost_s=0.0),
    ("DM", "fifo", "drain"): dict(
        makespan=15297.269497626332,
        avg_jct=1914.7769052604087,
        avg_wait=6179.540084837227,
        frag=493.9016722068024,
        util=0.6360196041436966,
        n_reconfigs=12, n_drains=9, n_handoffs=0,
        drain_cost_s=1500.0, handoff_cost_s=0.0),
    ("DM", "backfill", "drain"): dict(
        makespan=13005.961373381286,
        avg_jct=1920.5833568733121,
        avg_wait=4494.699267800047,
        frag=2552.584659606311,
        util=0.7530132437723299,
        n_reconfigs=11, n_drains=8, n_handoffs=0,
        drain_cost_s=1680.0, handoff_cost_s=0.0),
    ("DM", "fifo", "handoff"): dict(
        makespan=14944.588666785026,
        avg_jct=1869.672179453957,
        avg_wait=5992.156895591864,
        frag=460.4204483621651,
        util=0.6343115299834757,
        n_reconfigs=11, n_drains=0, n_handoffs=8,
        drain_cost_s=0.0, handoff_cost_s=101.75349999999999),
    ("DM", "backfill", "handoff"): dict(
        makespan=12848.013932791822,
        avg_jct=1872.3512009593335,
        avg_wait=4157.649819602748,
        frag=2421.757609743137,
        util=0.7396481577407791,
        n_reconfigs=12, n_drains=0, n_handoffs=9,
        drain_cost_s=0.0, handoff_cost_s=184.80316666666664),
    ("SM", "fifo", "drain"): dict(
        makespan=11112.661617302752,
        avg_jct=1622.8848308179004,
        avg_wait=3788.0336721802314,
        frag=837.3283532341738,
        util=0.8451210263096537,
        n_reconfigs=0, n_drains=0, n_handoffs=0,
        drain_cost_s=0.0, handoff_cost_s=0.0),
    ("SM", "backfill", "drain"): dict(
        makespan=10588.82432352852,
        avg_jct=1657.2080551997717,
        avg_wait=3211.9444299310267,
        frag=613.8954604205466,
        util=0.886929814311741,
        n_reconfigs=0, n_drains=0, n_handoffs=0,
        drain_cost_s=0.0, handoff_cost_s=0.0),
}


def _trace():
    return generate_trace(TraceCategory("philly", "balanced", "mixed"),
                          seed=7, double=False, max_size=4)


@pytest.mark.parametrize("mode,policy,reconfig", sorted(GOLDEN))
def test_trace_replay_matches_golden(mode, policy, reconfig):
    jobs = _trace()
    assert len(jobs) == 31                     # the trace itself is pinned
    r = simulate(jobs, mode, policy=policy, reconfig_mode=reconfig)
    g = GOLDEN[(mode, policy, reconfig)]
    rel = 1e-9
    assert r.makespan == pytest.approx(g["makespan"], rel=rel)
    assert r.avg_jct == pytest.approx(g["avg_jct"], rel=rel)
    assert r.avg_wait == pytest.approx(g["avg_wait"], rel=rel)
    assert r.avg_ext_frag_delay == pytest.approx(g["frag"], rel=rel,
                                                 abs=1e-9)
    assert r.utilization == pytest.approx(g["util"], rel=rel)
    assert r.n_reconfigs == g["n_reconfigs"]
    assert r.n_drains == g["n_drains"]
    assert r.n_handoffs == g["n_handoffs"]
    assert r.drain_cost_s == pytest.approx(g["drain_cost_s"], abs=1e-9)
    assert r.handoff_cost_s == pytest.approx(g["handoff_cost_s"],
                                             abs=1e-9)
    assert r.n_jobs == len(jobs)
    # the event records mirror the counters they aggregate
    assert len(r.reconfig_events) == r.n_reconfigs
    kinds = [e.kind for e in r.reconfig_events]
    assert kinds.count("drain") == r.n_drains
    assert kinds.count("handoff") == r.n_handoffs
    assert sum(e.charged_s for e in r.reconfig_events) == pytest.approx(
        r.drain_cost_s + r.handoff_cost_s)


def test_handoff_never_charges_more_per_event():
    """On the pinned trace, DM-handoff's total charged suspension is far
    below DM-drain's — the operational claim the cost model encodes."""
    jobs = _trace()
    drain = simulate(jobs, "DM", policy="fifo")
    handoff = simulate(jobs, "DM", policy="fifo",
                       reconfig_mode="handoff")
    assert drain.n_handoffs == 0 and handoff.n_drains == 0
    assert handoff.handoff_cost_s < drain.drain_cost_s
