"""DevicePool ledger + placement geometry (repro.cluster.pool).

Host-side units: deterministic round-robin/packed placements, the
equal-per-host shape invariant, fragmentation detection, single-victim
defrag planning, and ledger errors (overlap, double-allocate, bad
factorizations).
"""
import pytest

from repro.cluster import DevicePool, PoolError
from repro.core.policy import cluster_placement, defrag_victims
from repro.core.job import TIER_HIGH, TIER_NORMAL, Job


def _job(jid, size, tier=TIER_NORMAL, tenant="t0"):
    return Job(job_id=jid, model="m", kind="train", size=size, batch=8,
               base_duration=1.0, tenant=tenant, priority_tier=tier)


# ---------------------------------------------------------- planning

def test_round_robin_prefers_widest_split():
    pool = DevicePool(2, 4)
    devices, shape = pool.plan(4)
    assert shape == (2, 2)                    # one row per host
    assert devices == (0, 1, 4, 5)            # lowest ids on each host


def test_packed_prefers_narrowest_span():
    pool = DevicePool(2, 4)
    devices, shape = pool.plan(4, strategy="packed")
    assert shape == (1, 4)
    assert devices == (0, 1, 2, 3)


def test_round_robin_spreads_to_emptiest_hosts():
    pool = DevicePool(2, 4)
    pool.allocate("a", (0, 1, 2), (1, 3))     # host 0 nearly full
    devices, shape = pool.plan(2)
    # widest split (2 hosts) impossible: host 0 has 1 free but span 2
    # needs 1 per host — still valid, and it picks host 1's slot too
    assert shape == (2, 1)
    assert devices == (3, 4)


def test_packed_fills_fullest_host_first():
    pool = DevicePool(2, 4)
    pool.allocate("a", (0, 1), (1, 2))
    devices, shape = pool.plan(2, strategy="packed")
    assert shape == (1, 2)
    assert devices == (2, 3)                  # host 0: fullest with room


def test_require_span_filters_factorizations():
    pool = DevicePool(2, 4)
    devices, shape = pool.plan(4, strategy="packed", require_span=1)
    assert shape == (1, 4)
    pool.allocate("a", (0, 1), (1, 2))
    pool.allocate("b", (4, 5), (1, 2))
    # 4 devices free ({2,3} + {6,7}) but no host has 4 contiguous free
    assert pool.plan(4, strategy="packed", require_span=1) is None


def test_plan_none_when_no_fit():
    pool = DevicePool(2, 2)
    pool.allocate("a", (0, 1, 2), (1, 3)) if False else None
    assert pool.plan(8) is None               # wider than the pool
    assert pool.plan(3) is None               # no equal split exists


def test_plan_rejects_bad_inputs():
    pool = DevicePool(2, 4)
    with pytest.raises(PoolError):
        pool.plan(4, strategy="nope")
    with pytest.raises(PoolError):
        pool.plan(0)


# ------------------------------------------------------------ ledger

def test_allocate_release_reassign_roundtrip():
    pool = DevicePool(2, 4)
    a = pool.allocate("j", (0, 1, 4, 5), (2, 2))
    assert a.size == 4 and pool.total_free() == 4
    pool.reassign("j", (0, 1, 2, 3), (1, 4))
    assert pool.allocs["j"].shape == (1, 4)
    freed = pool.release("j")
    assert freed.devices == (0, 1, 2, 3)
    assert pool.total_free() == 8


def test_ledger_rejects_overlap_and_double_alloc():
    pool = DevicePool(2, 4)
    pool.allocate("a", (0, 1), (1, 2))
    with pytest.raises(PoolError):
        pool.allocate("b", (1, 2), (1, 2))    # device 1 held by a
    with pytest.raises(PoolError):
        pool.allocate("a", (2, 3), (1, 2))    # already allocated
    with pytest.raises(PoolError):
        pool.release("ghost")
    with pytest.raises(PoolError):
        pool.reassign("ghost", (2, 3), (1, 2))


def test_ledger_rejects_bad_geometry():
    pool = DevicePool(2, 4)
    with pytest.raises(PoolError):            # shape does not factor
        pool.allocate("a", (0, 1), (1, 3))
    with pytest.raises(PoolError):            # unequal per-host split
        pool.allocate("b", (0, 1, 2, 4), (2, 2))
    with pytest.raises(PoolError):            # out of range
        pool.allocate("c", (7, 8), (1, 2))
    with pytest.raises(PoolError):            # duplicate devices
        pool.allocate("d", (0, 0), (1, 2))
    assert pool.allocs == {}                  # nothing leaked


def test_free_by_host_exclude_is_hypothetical():
    pool = DevicePool(2, 4)
    pool.allocate("a", (0, 1, 4, 5), (2, 2))
    assert pool.free_by_host() == [[2, 3], [6, 7]]
    assert pool.free_by_host(exclude=("a",)) == [[0, 1, 2, 3],
                                                 [4, 5, 6, 7]]
    assert pool.allocs["a"].devices == (0, 1, 4, 5)   # ledger untouched


# ----------------------------------------------- fragmentation/defrag

def _fragmented_pool():
    """j0 (2,2) split across hosts; 2 free per host — a span-1 width-4
    arrival is blocked by fragmentation alone."""
    pool = DevicePool(2, 4)
    pool.allocate("j0", (0, 1, 4, 5), (2, 2))
    return pool


def test_fragmented_for_detects_split_capacity():
    pool = _fragmented_pool()
    assert pool.total_free() == 4
    assert pool.fragmented_for(4, strategy="packed", require_span=1)
    # without the span constraint (2,2) fits — not fragmentation
    assert not pool.fragmented_for(4)
    # more devices than exist free: capacity, not fragmentation
    assert not pool.fragmented_for(6, strategy="packed", require_span=1)


def test_defrag_plan_moves_single_victim_packed():
    pool = _fragmented_pool()
    move = pool.defrag_plan("j2", 4, require_span=1, victims=["j0"])
    assert move is not None and move.victim == "j0"
    assert move.victim_to.shape == (1, 4)     # consolidated
    assert move.requester_to.shape == (1, 4)
    assert not (set(move.victim_to.devices)
                & set(move.requester_to.devices))


def test_defrag_plan_none_when_no_victim_helps():
    pool = DevicePool(2, 4)
    pool.allocate("j0", (0, 1, 4, 5), (2, 2))
    pool.allocate("j1", (2, 6), (2, 1))
    # only 2 free; no single move admits a span-1 width-4 job
    move = pool.defrag_plan("jx", 4, require_span=1,
                            victims=["j1", "j0"])
    assert move is None
    # unknown victims are skipped, not fatal
    assert pool.defrag_plan("jx", 4, require_span=1,
                            victims=["ghost"]) is None


# -------------------------------------------------- placement policy

def test_cluster_placement_tier0_pins_single_host():
    assert cluster_placement(TIER_HIGH, 4, 4) == ("packed", 1)
    # tier-0 wider than a host cannot be pinned — falls back to spread
    assert cluster_placement(TIER_HIGH, 8, 4) == ("round_robin", None)
    assert cluster_placement(TIER_NORMAL, 4, 4) == ("round_robin", None)


def test_defrag_victims_policy_order():
    j_hi = _job("hi", 4, tier=TIER_HIGH)
    small = _job("small", 2)
    big = _job("big", 4)
    # only tiers at-or-below the requester are eligible; lowest tier
    # first, then smallest (cheapest state to hand off)
    assert [j.job_id for j in
            defrag_victims([j_hi, big, small], j_hi)] \
        == ["small", "big", "hi"]
    norm = _job("req", 4)
    assert [j.job_id for j in defrag_victims([j_hi, big, small], norm)] \
        == ["small", "big"]
