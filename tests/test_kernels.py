"""Per-kernel shape/dtype sweeps + allclose vs pure-jnp oracles
(interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no-network env: deterministic example-based shim
    from tests._hypothesis_stub import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import ssd
from repro.kernels.mamba_scan.ref import ssd_ref
from repro.kernels.mlstm.ops import mlstm
from repro.kernels.mlstm.ref import mlstm_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,H,Kv,D", [
    (128, 4, 4, 64),      # MHA
    (256, 4, 2, 64),      # GQA 2:1
    (128, 8, 2, 128),     # GQA 4:1, MXU-width head
    (192, 2, 1, 32),      # non-pow2 seq, MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, Kv, D, causal, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_softcap():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = flash_attention(q, k, v, causal=True, softcap=20.0,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64]))
def test_flash_attention_block_invariance(bq, bk):
    """Property: output is independent of the BlockSpec tiling."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    a = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    b = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("T,H,P,G,N,chunk", [
    (128, 4, 32, 1, 16, 32),
    (128, 4, 32, 2, 16, 64),
    (64, 2, 64, 2, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(T, H, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(3), 5)
    B = 2
    x = jax.random.normal(ks[0], (B, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))).astype(
        jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, T, G, N), dtype)
    y, s = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm)
    tol = dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("T,H,D,chunk", [
    (128, 2, 32, 32),
    (64, 4, 16, 16),
    (96, 2, 64, 32),
])
def test_mlstm_kernel_sweep(T, H, D, chunk):
    ks = jax.random.split(jax.random.key(4), 5)
    B = 2
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    i_raw = jax.random.normal(ks[3], (B, T, H)) * 2
    f_raw = jax.random.normal(ks[4], (B, T, H)) * 2 + 3
    h, (C, n, m) = mlstm(q, k, v, i_raw, f_raw, chunk=chunk)
    hr, (Cr, nr, mr) = mlstm_ref(q, k, v, i_raw, f_raw)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("R,D", [(64, 128), (256, 512), (100, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(R, D, dtype):
    ks = jax.random.split(jax.random.key(5), 2)
    x = jax.random.normal(ks[0], (R, D), dtype)
    w = jax.random.normal(ks[1], (D,), jnp.float32)
    out = rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=10, deadline=None)
@given(logf=st.floats(-5.0, 5.0), logi=st.floats(-5.0, 5.0))
def test_mlstm_gate_stability_property(logf, logi):
    """Property: extreme gate magnitudes never produce NaN/Inf (the
    max-stabilizer contract)."""
    B, T, H, D = 1, 32, 1, 8
    ks = jax.random.split(jax.random.key(6), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    i_raw = jnp.full((B, T, H), logi)
    f_raw = jnp.full((B, T, H), logf)
    h, _ = mlstm(q, k, v, i_raw, f_raw, chunk=16)
    assert bool(jnp.all(jnp.isfinite(h)))
