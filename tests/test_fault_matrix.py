"""Driver kill matrix: SIGKILL a real 3-handoff ElasticDriver run at
sampled injection points, relaunch with ``resume=True``, and require the
continued run to be bitwise-identical to an uninterrupted reference —
losses AND the final committed checkpoint bytes.

Sampled windows (the PR-7 acceptance): mid-save (``sharded.write``),
inside the commit marker window (``sharded.manifest`` — manifest
written, renames pending), mid-restore (``sharded.read``), and the
recompile window of a fresh mesh segment (``driver.first_step``).
``sharded.between_renames`` has no driver-path arrival (the driver never
re-saves a committed step) and is covered by the save crash matrix in
test_faults.py.
"""
import hashlib
import os
import re

import pytest

from repro import ckpt as ckpt_lib
from repro.faults import FaultPlan, FaultSpec
from repro.faults import harness

N_STEPS = 8
# (2,2) -> (4,1) -> (1,4) -> (2,2): three handoffs on 8 forced devices
SCHEDULE = "[ReconfigEvent(step=2, mesh_shape=(4, 1)), " \
           "ReconfigEvent(step=4, mesh_shape=(1, 4)), " \
           "ReconfigEvent(step=6, mesh_shape=(2, 2))]"

CHILD = """
import numpy as np
from repro import optim
from repro.data import DataConfig
from repro.elastic_driver import ElasticDriver, ReconfigEvent
from repro.models.registry import get_config, build_model, reduced_config

cfg = reduced_config(get_config('llama3.2-1b'))
model = build_model(cfg, remat=False)
ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=%(n)d)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
drv = ElasticDriver(model, ocfg, dcfg, base_dir=%(base)r,
                    bucket_bytes=64 << 10)
out = drv.run(%(n)d, %(schedule)s, initial_shape=(2, 2),
              resume=%(resume)s, final_save=True)
print('START', out.start_step)
for i, loss in enumerate(out.losses, start=out.start_step):
    print('LOSS %%d %%r' %% (i, loss))
print('DRIVER_DONE')
"""


def _child_code(base, resume):
    return CHILD % dict(n=N_STEPS, base=base, schedule=SCHEDULE,
                        resume=resume)


def _losses(stdout):
    return dict(re.findall(r"LOSS (\d+) (\S+)", stdout))


def _hash_dir(path):
    out = {}
    for name in sorted(os.listdir(path)):
        with open(os.path.join(path, name), "rb") as f:
            out[name] = hashlib.sha256(f.read()).hexdigest()
    return out


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """One uninterrupted 3-handoff run: losses + final checkpoint."""
    base = str(tmp_path_factory.mktemp("ref"))
    res = harness.run_child(_child_code(base, resume=False), n_devices=8)
    out = harness.expect_clean(res)
    assert "DRIVER_DONE" in out
    losses = _losses(out)
    assert sorted(map(int, losses)) == list(range(N_STEPS))
    final = ckpt_lib.step_dir(base, N_STEPS)
    assert ckpt_lib.latest_step(base) == N_STEPS
    return {"losses": losses, "final_hash": _hash_dir(final)}


# (point, hit, committed step the relaunch must resume from)
KILL_POINTS = [
    ("sharded.write", 3, 0),     # mid-save of handoff 1: no commit yet
    ("sharded.manifest", 2, 2),  # handoff 2's commit window: tmp only
    ("sharded.read", 2, 2),      # mid-restore of handoff 1
    ("driver.first_step", 3, 4), # recompile window of mesh segment 3
]


@pytest.mark.parametrize("point,hit,resume_from", KILL_POINTS,
                         ids=[p for p, _, _ in KILL_POINTS])
def test_kill_and_resume_bitwise(tmp_path, reference, point, hit,
                                 resume_from):
    base = str(tmp_path)
    plan = FaultPlan([FaultSpec(point, "crash", hit=hit)])
    killed = harness.run_child(_child_code(base, resume=False),
                               plan=plan, n_devices=8)
    harness.expect_sigkill(killed)

    # never a torn dir: whatever latest_step names must be committed
    last = ckpt_lib.latest_step(base)
    assert last == (resume_from or None), \
        f"kill at {point} left latest_step={last}"

    resumed = harness.run_child(_child_code(base, resume=True),
                                n_devices=8)
    out = harness.expect_clean(resumed)
    assert "DRIVER_DONE" in out
    assert re.search(rf"^START {resume_from}$", out, re.M), out

    got = _losses(out)
    assert sorted(map(int, got)) == list(range(resume_from, N_STEPS))
    ref = reference["losses"]
    for step, loss in got.items():
        assert loss == ref[step], \
            (point, step, loss, ref[step])       # bitwise (repr) equal

    # the resumed run's final commit is byte-identical to the reference
    assert ckpt_lib.latest_step(base) == N_STEPS
    assert _hash_dir(ckpt_lib.step_dir(base, N_STEPS)) == \
        reference["final_hash"]

    # the dead child's in-flight tmp debris was swept by a later commit
    debris = [d for d in os.listdir(base)
              if ".tmp-" in d or ".old-" in d]
    assert debris == [], debris
