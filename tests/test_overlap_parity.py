"""Overlapped (software-pipelined) bucket sync: parity + pipelinability.

The overlapped schedule must be bitwise-identical to the serial one (it
reorders collective *issue*, never per-bucket arithmetic), silently
no-op in the degenerate cases, keep its slow collectives data-independent
in the lowered HLO (the pipelinability invariant), and — with the int8
slow hop — error feedback must pull the loss curve back toward the
uncompressed one.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.train import make_train_step
from tests.conftest import run_multidevice


def test_overlap_rejected_outside_bucketed_modes():
    with pytest.raises(ValueError, match="overlap"):
        make_train_step(object(), optim.AdamWConfig(),
                        cross_pod_mode="xla", overlap=True)
    with pytest.raises(ValueError, match="overlap"):
        make_train_step(object(), optim.AdamWConfig(),
                        cross_pod_mode="hier", slow_error_feedback=True,
                        slow_compress_bits=8)


def test_error_feedback_requires_int8():
    with pytest.raises(ValueError, match="slow_compress_bits=8"):
        make_train_step(object(), optim.AdamWConfig(),
                        cross_pod_mode="hier_bucketed",
                        slow_error_feedback=True)


def test_overlap_bitwise_parity_10_steps_multidevice():
    """Acceptance: overlap=True vs overlap=False is bitwise-identical in
    loss and params over 10 steps on a (2,2) pod x data mesh, for both
    hier_bucketed and hier_bucketed_zero1, on a multi-bucket layout —
    with and without the int8+error-feedback slow hop (which exercises
    the pipelined-with-residuals schedule and the zero1 EFState specs).
    """
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import optim
        from repro.data import DataConfig, SyntheticCorpus
        from repro.models.registry import get_config, build_model, \\
            reduced_config
        from repro.sharding import make_rules
        from repro.train import (EFState, init_slow_residuals,
                                 make_jitted_train_step,
                                 make_bucket_layout)

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        rules = make_rules(mesh, fsdp=False)
        corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=16, global_batch=8))
        ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                 total_steps=20)
        bb = 64 << 10          # small buckets -> a real multi-bucket pipe
        layout = make_bucket_layout(model.init(jax.random.key(0)), mesh,
                                    bucket_bytes=bb)
        assert layout.n_buckets >= 2, layout.n_buckets

        results = {}
        for mode in ('hier_bucketed', 'hier_bucketed_zero1'):
            for ef in (False, True):
                for overlap in (False, True):
                    p = model.init(jax.random.key(0))
                    st = (optim.init_bucketed(ocfg, p, layout)
                          if mode == 'hier_bucketed_zero1'
                          else optim.init(ocfg, p))
                    if ef:
                        st = EFState(st, init_slow_residuals(
                            p, mesh, bucket_bytes=bb))
                    step = make_jitted_train_step(
                        model, ocfg, accum=1, rules=rules,
                        cross_pod_mode=mode, bucket_bytes=bb,
                        slow_compress_bits=8 if ef else 0,
                        slow_error_feedback=ef, overlap=overlap)
                    losses = []
                    with mesh:
                        for i in range(10):
                            b = {k: jnp.asarray(v)
                                 for k, v in corpus.batch(i).items()}
                            p, st, m = step(p, st, b)
                            losses.append(float(m['loss']))
                    results[(mode, ef, overlap)] = (losses, p, st)

        for mode in ('hier_bucketed', 'hier_bucketed_zero1'):
            for ef in (False, True):
                serial, p_s, st_s = results[(mode, ef, False)]
                piped, p_o, st_o = results[(mode, ef, True)]
                assert serial == piped, (mode, ef, serial, piped)
                assert serial[0] != serial[-1]   # it actually trained
                for a, b in zip(jax.tree.leaves(p_s),
                                jax.tree.leaves(p_o)):
                    assert np.array_equal(np.asarray(a),
                                          np.asarray(b)), (mode, ef)
                if ef:
                    # carried residuals are live and themselves bitwise
                    # identical across the two schedules
                    assert any(float(jnp.sum(jnp.abs(r))) > 0
                               for r in st_s.residuals)
                    for a, b in zip(st_s.residuals, st_o.residuals):
                        assert np.array_equal(np.asarray(a),
                                              np.asarray(b)), mode
        print("OVERLAP_PARITY_OK")
        """, n_devices=4)
    assert "OVERLAP_PARITY_OK" in out


def test_overlap_degenerate_noop_multidevice():
    """Single-bucket layouts and size-1 meshes must take the serial path
    under overlap=True — same losses, and (size-1) no collectives at
    all."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro import optim, parallel as PX
        from repro.models.registry import get_config, build_model, \\
            reduced_config
        from repro.sharding import make_rules
        from repro.train import make_jitted_train_step, make_bucket_layout

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        rng = jax.random.key(1)
        batch = {'tokens': jax.random.randint(rng, (4, 32), 0,
                                              cfg.vocab_size),
                 'targets': jax.random.randint(rng, (4, 32), 0,
                                               cfg.vocab_size)}
        ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=1,
                                 total_steps=10)

        # (2,2) mesh, one giant bucket: pipeline degenerates to serial
        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        rules = make_rules(mesh, fsdp=False)
        losses = {}
        for overlap in (False, True):
            p = model.init(jax.random.key(0))
            st = optim.init(ocfg, p)
            step = make_jitted_train_step(
                model, ocfg, accum=1, rules=rules,
                cross_pod_mode='hier_bucketed',
                bucket_bytes=1 << 30, overlap=overlap)
            with mesh:
                for _ in range(2):
                    p, st, m = step(p, st, batch)
            losses[overlap] = float(m['loss'])
        assert losses[False] == losses[True], losses

        # (1,1) mesh: overlap=True must run the local (collective-free)
        # path without touching axis names
        mesh1 = PX.make_device_mesh((1, 1), ('pod', 'data'),
                                    devices=jax.devices()[:1])
        rules1 = make_rules(mesh1, fsdp=False)
        for mode in ('hier_bucketed', 'hier_bucketed_zero1'):
            p = model.init(jax.random.key(0))
            st = (optim.init_bucketed(
                      ocfg, p, make_bucket_layout(p, mesh1))
                  if mode == 'hier_bucketed_zero1'
                  else optim.init(ocfg, p))
            step = make_jitted_train_step(
                model, ocfg, accum=1, rules=rules1,
                cross_pod_mode=mode, overlap=True)
            with mesh1:
                p, st, m = step(p, st, batch)
            assert jnp.isfinite(m['loss'])
        print("OVERLAP_DEGENERATE_OK")
        """, n_devices=4)
    assert "OVERLAP_DEGENERATE_OK" in out


def test_overlap_hlo_slow_collectives_independent_multidevice():
    """Pipelinability, proven from lowered HLO: the overlapped schedule
    emits one slow collective per bucket and none of them data-depends
    on another (``analysis.hlo.slow_collective_chains``)."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro import parallel as PX
        from repro.analysis.hlo import slow_collective_chains
        from repro.collectives import bucketing as BK

        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        grads = {f't{i}': jax.ShapeDtypeStruct((256,), jnp.float32)
                 for i in range(6)}
        layout = BK.plan_buckets(grads, bucket_bytes=2048, align=2)
        assert layout.n_buckets >= 2

        def fn(g):
            b = BK.flatten_to_buckets(layout, g)
            s = BK.hier_reduce_bucket_shards(
                b, fast_axis='data', slow_axis='pod', overlap=True)
            full = BK.all_gather_buckets(s, fast_axis='data')
            return BK.unflatten_from_buckets(layout, full,
                                             dtype=jnp.float32)

        specs = jax.tree.map(lambda _: P(), grads)
        txt = jax.jit(PX.shard_map(
            fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False, axis_names={'pod', 'data'},
        )).lower(grads).compile().as_text()
        chain = slow_collective_chains(txt, chips_per_pod=2)
        assert chain.n_slow == layout.n_buckets, chain
        assert chain.independent, chain.dependent_pairs
        print("OVERLAP_HLO_OK")
        """, n_devices=4)
    assert "OVERLAP_HLO_OK" in out


def test_int8_error_feedback_converges_closer_multidevice():
    """int8 + error feedback tracks the uncompressed loss curve strictly
    closer than int8 alone (summed |deviation| over 15 steps)."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import optim
        from repro.data import DataConfig, SyntheticCorpus
        from repro.models.registry import get_config, build_model, \\
            reduced_config
        from repro.sharding import make_rules
        from repro.train import (EFState, init_slow_residuals,
                                 make_jitted_train_step)

        cfg = reduced_config(get_config('llama3.2-1b'))
        model = build_model(cfg, remat=False)
        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        rules = make_rules(mesh, fsdp=False)
        corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=16, global_batch=8))
        ocfg = optim.AdamWConfig(peak_lr=3e-3, warmup_steps=2,
                                 total_steps=20)
        bb = 64 << 10

        def run(bits, ef):
            p = model.init(jax.random.key(0))
            st = optim.init(ocfg, p)
            if ef:
                st = EFState(st, init_slow_residuals(p, mesh,
                                                     bucket_bytes=bb))
            step = make_jitted_train_step(
                model, ocfg, accum=1, rules=rules,
                cross_pod_mode='hier_bucketed', bucket_bytes=bb,
                slow_compress_bits=bits, slow_error_feedback=ef)
            losses = []
            with mesh:
                for i in range(15):
                    b = {k: jnp.asarray(v)
                         for k, v in corpus.batch(i).items()}
                    p, st, m = step(p, st, b)
                    losses.append(float(m['loss']))
            if ef:
                # residuals are live state: quantization error is
                # actually being carried
                assert any(float(jnp.sum(jnp.abs(r))) > 0
                           for r in st.residuals)
            return np.asarray(losses)

        ref = run(0, False)
        q = run(8, False)
        qef = run(8, True)
        dev_q = float(np.abs(q - ref).sum())
        dev_qef = float(np.abs(qef - ref).sum())
        print('dev int8', dev_q, 'dev int8+EF', dev_qef)
        assert dev_q > 0.0                      # int8 does perturb
        assert dev_qef < dev_q, (dev_qef, dev_q)
        print("EF_CONVERGENCE_OK")
        """, n_devices=4)
    assert "EF_CONVERGENCE_OK" in out
