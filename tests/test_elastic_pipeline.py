"""Elasticity, straggler detection, pipeline parallelism, aggregation."""
import numpy as np
import pytest

from repro.core.aggregation import packed_order, round_robin_order
from repro.core.leaves import TpuLeaf, TpuSliceTopology
from repro.elastic import (HeartbeatMonitor, StragglerDetector,
                           plan_elastic_remesh)
from tests.conftest import run_multidevice


def test_heartbeat_detects_dead_worker():
    hb = HeartbeatMonitor(timeout_s=10.0)
    hb.beat(0, t=100.0)
    hb.beat(1, t=100.0)
    hb.beat(0, t=118.0)
    assert hb.dead_workers(now=120.0) == [1]


def test_straggler_detector():
    sd = StragglerDetector(k=5.0)
    for _ in range(20):
        sd.record(0.1)
    assert sd.record(1.5)                      # clear outlier flagged
    assert not sd.record(0.11)
    assert sd.summary()["stragglers"] == 1


def test_elastic_remesh_drops_failed_hosts():
    topo = TpuSliceTopology(n_pods=1, hosts_per_pod=4, chips_per_host=4)
    leaves = topo.leaves()
    plan = plan_elastic_remesh(leaves, [(0, 1)], model_parallel=4)
    assert plan.mesh_shape == (3, 4)           # 12 survivors / mp=4
    assert all((l.pod, l.host) != (0, 1) for l in plan.surviving)


def test_elastic_remesh_insufficient():
    topo = TpuSliceTopology(n_pods=1, hosts_per_pod=1, chips_per_host=4)
    with pytest.raises(RuntimeError):
        plan_elastic_remesh(topo.leaves(), [(0, 0)], model_parallel=4)


def test_round_robin_vs_packed_order():
    leaves = [TpuLeaf(0, h, c) for h in range(2) for c in range(3)]
    rr = round_robin_order(leaves)
    assert [(l.host, l.chip) for l in rr[:4]] == [
        (0, 0), (1, 0), (0, 1), (1, 1)]        # alternating hosts (§3.2)
    pk = packed_order(leaves)
    assert [(l.host) for l in pk[:3]] == [0, 0, 0]


def test_leaf_mesh_and_elastic_restore_multidevice():
    """One-to-many leaf mesh + checkpoint resharding onto a shrunk mesh."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.leaves import TpuSliceTopology
        from repro.core.aggregation import leaves_to_mesh
        from repro.elastic import plan_elastic_remesh
        from repro import checkpoint as ckpt
        from jax.sharding import NamedSharding, PartitionSpec as P
        import tempfile, os

        topo = TpuSliceTopology(n_pods=1, hosts_per_pod=2,
                                chips_per_host=4)
        leaves = topo.leaves()
        mesh = leaves_to_mesh(leaves, (4, 2), ("data", "model"))
        params = {"w": jnp.arange(32.0).reshape(8, 4)}
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        params = jax.device_put(params, sh)
        d = tempfile.mkdtemp()
        ckpt.save(d, 5, params)

        # host (0,1) fails: re-mesh over 4 surviving chips
        plan = plan_elastic_remesh(leaves, [(0, 1)], model_parallel=2)
        assert plan.mesh_shape == (2, 2)
        new_mesh = leaves_to_mesh(plan.surviving, plan.mesh_shape,
                                  plan.axis_names)
        new_sh = {"w": NamedSharding(new_mesh, P("data", "model"))}
        step, restored = ckpt.restore(d, params, shardings=new_sh)
        assert step == 5
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.arange(32.0).reshape(8, 4))
        assert len(restored["w"].sharding.device_set) == 4
        print("ELASTIC_OK")
        """)
    assert "ELASTIC_OK" in out


def test_gpipe_matches_sequential_multidevice():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.pipeline import gpipe_forward
        mesh = jax.make_mesh((4,), ("stage",))
        S, D, n_micro, mb = 4, 16, 6, 2
        ks = jax.random.split(jax.random.key(0), 2)
        w = jax.random.normal(ks[0], (S, D, D)) * 0.3
        x = jax.random.normal(ks[1], (n_micro, mb, D))

        def layer(wp, h):
            return jnp.tanh(h @ wp[0])

        got = gpipe_forward(layer, w, x, mesh=mesh)
        ref = x
        for i in range(S):
            ref = jnp.tanh(ref @ w[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        print("GPIPE_OK")
        """)
    assert "GPIPE_OK" in out


def test_flash_decode_sharded_matches_dense_multidevice():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.attention import sharded_decode_attention
        from repro.models.layers import decode_attention
        from repro.sharding import make_rules, use_rules
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, seq_shard=True)
        B, S, H, Kv, Dh = 2, 64, 4, 2, 16
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, 1, H, Dh))
        k = jax.random.normal(ks[1], (B, S, Kv, Dh))
        v = jax.random.normal(ks[2], (B, S, Kv, Dh))
        pos = jnp.int32(37)
        ref = decode_attention(q, k, v, pos + 1)
        with mesh:
            with use_rules(rules):
                out = jax.jit(lambda q, k, v: sharded_decode_attention(
                    q, k, v, pos))(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)
        print("FLASH_DECODE_OK")
        """)
    assert "FLASH_DECODE_OK" in out
