"""Property-based tests for the bucket layout planner + flatten/unflatten.

Uses real ``hypothesis`` when installed, else the deterministic shim in
``tests/_hypothesis_stub.py`` (same strategy API) — either way each
property runs over many random leaf shape/dtype trees.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no-network env: deterministic example-based shim
    from tests._hypothesis_stub import given, settings, st

from repro.collectives import bucketing as BK

_FLOAT_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def _random_tree(seed: int, n_leaves: int):
    """A nested dict of float leaves with random shapes/dtypes."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(n_leaves):
        ndim = int(rng.integers(0, 4))
        shape = tuple(int(rng.integers(1, 7)) for _ in range(ndim))
        dtype = _FLOAT_DTYPES[int(rng.integers(len(_FLOAT_DTYPES)))]
        # bf16/f16 values must survive the f32 round-trip bitwise, which
        # any representable value does; use small integers + halves
        vals = rng.integers(-8, 9, size=shape).astype(np.float32) / 2.0
        leaf = jnp.asarray(vals, dtype)
        if i % 3 == 2:
            tree.setdefault("nested", {})[f"l{i}"] = leaf
        else:
            tree[f"l{i}"] = leaf
    return tree


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), n_leaves=st.integers(1, 8),
       bucket_bytes=st.sampled_from([4, 64, 512, 1 << 20]),
       align=st.integers(1, 8))
def test_layout_slots_nonoverlapping_and_aligned(seed, n_leaves,
                                                 bucket_bytes, align):
    tree = _random_tree(seed, n_leaves)
    layout = BK.plan_buckets(tree, bucket_bytes=bucket_bytes, align=align)
    # every bucket size is a multiple of align (fast-axis divisible)
    assert all(c % align == 0 for c in layout.bucket_sizes)
    assert layout.n_buckets == len(layout.bucket_sizes) >= 1
    # slots tile each bucket contiguously: first-fit in flatten order
    # means offsets are exactly the running fill, no overlaps, no holes
    fill = [0] * layout.n_buckets
    for slot in layout.slots:
        assert slot.offset == fill[slot.bucket]
        assert slot.size == int(np.prod(slot.shape))   # prod(()) == 1
        fill[slot.bucket] += slot.size
    for b, f in enumerate(fill):
        assert f <= layout.bucket_sizes[b]
    assert layout.n_elements() == sum(fill)
    assert layout.n_padded_elements() >= layout.n_elements()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), n_leaves=st.integers(1, 8),
       bucket_bytes=st.sampled_from([4, 64, 512, 1 << 20]),
       align=st.integers(1, 8))
def test_flatten_unflatten_roundtrip_exact(seed, n_leaves, bucket_bytes,
                                           align):
    tree = _random_tree(seed, n_leaves)
    layout = BK.plan_buckets(tree, bucket_bytes=bucket_bytes, align=align)
    buckets = BK.flatten_to_buckets(layout, tree)
    assert all(b.dtype == jnp.float32 and b.ndim == 1 for b in buckets)
    assert tuple(b.shape[0] for b in buckets) == layout.bucket_sizes
    back = BK.unflatten_from_buckets(layout, buckets)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), n_leaves=st.integers(1, 8),
       bucket_bytes=st.sampled_from([64, 512]))
def test_layout_deterministic_and_shape_only(seed, n_leaves,
                                             bucket_bytes):
    """Planning is a pure function of (structure, shapes, dtypes) —
    identical for concrete arrays, avals, and across repeated calls."""
    tree = _random_tree(seed, n_leaves)
    l1 = BK.plan_buckets(tree, bucket_bytes=bucket_bytes, align=2)
    l2 = BK.plan_buckets(tree, bucket_bytes=bucket_bytes, align=2)
    l3 = BK.plan_buckets(jax.eval_shape(lambda: tree),
                         bucket_bytes=bucket_bytes, align=2)
    assert l1.slots == l2.slots == l3.slots
    assert l1.bucket_sizes == l2.bucket_sizes == l3.bucket_sizes


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), n_leaves=st.integers(2, 6),
       bucket_bytes=st.sampled_from([64, 256]))
def test_first_fit_invariant_to_equal_leaf_swaps(seed, n_leaves,
                                                 bucket_bytes):
    """Swapping two leaves with identical shape/dtype yields the same
    first-fit layout geometry (slots differ only in which leaf they
    name, not in placement)."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(1, 5)) for _ in range(2))
    # keys sort alphabetically in flatten order; a/b are the equal pair
    tree = {"a": jnp.zeros(shape, jnp.float32),
            "b": jnp.ones(shape, jnp.float32)}
    for i in range(n_leaves):
        sz = int(rng.integers(1, 30))
        tree[f"c{i}"] = jnp.full((sz,), float(i), jnp.float32)
    swapped = dict(tree)
    swapped["a"], swapped["b"] = tree["b"], tree["a"]
    l1 = BK.plan_buckets(tree, bucket_bytes=bucket_bytes, align=2)
    l2 = BK.plan_buckets(swapped, bucket_bytes=bucket_bytes, align=2)
    assert l1.slots == l2.slots            # placement is shape-driven
    assert l1.bucket_sizes == l2.bucket_sizes
    # and the values still round-trip to their own leaves
    back = BK.unflatten_from_buckets(l2,
                                     BK.flatten_to_buckets(l2, swapped))
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(swapped["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]),
                                  np.asarray(swapped["b"]))
