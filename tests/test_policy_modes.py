"""Instance-selection policy (§3.2) and FM/DM/SM operation modes."""
import pytest

from repro.core.job import Job
from repro.core.leaves import Cluster
from repro.core.modes import (CKPT_LOAD_S, CKPT_SAVE_S, POD_CHURN_S,
                              RECONFIGURE_S, DynamicMIG, FlexMIG,
                              Placement, ReconfigPlan, StaticMIG)
from repro.core.policy import select_instances, size_aware_priority


def _job(size, kind="train", jid="j1"):
    return Job(jid, "resnet50", kind, size, 256, 1000.0)


def _fm_cluster():
    c = Cluster(n_hosts=1, gpus_per_host=2)
    FlexMIG().setup(c)
    return c


def test_size_aware_prioritization():
    assert size_aware_priority(1)[0] == "1g.10gb"   # 10-30% JCT win
    assert size_aware_priority(2)[0] == "1g.5gb"    # sync caps at slowest
    assert size_aware_priority(8)[0] == "1g.5gb"


def test_topology_aware_round_robin():
    c = _fm_cluster()
    chosen = select_instances(c, 0, 6, round_robin=True)
    per_gpu = {}
    for i in chosen:
        per_gpu[i.gpu_id] = per_gpu.get(i.gpu_id, 0) + 1
    assert sorted(per_gpu.values()) == [3, 3]       # the Fig. 9 optimum


def test_packed_placement_is_uneven():
    c = _fm_cluster()
    chosen = select_instances(c, 6, round_robin=False) if False else \
        select_instances(c, 0, 6, round_robin=False)
    per_gpu = {}
    for i in chosen:
        per_gpu[i.gpu_id] = per_gpu.get(i.gpu_id, 0) + 1
    assert max(per_gpu.values()) > 3                 # packs one GPU first


def test_size1_gets_1g10gb():
    c = _fm_cluster()
    chosen = select_instances(c, 0, 1)
    assert chosen[0].profile == "1g.10gb"


def test_fm_placement_and_release():
    c = _fm_cluster()
    fm = FlexMIG()
    pl = fm.try_place(_job(4), c)
    assert isinstance(pl, Placement)
    assert len(pl.instances) == 4
    assert pl.transport == "SHM"
    assert sorted(pl.leaves_per_gpu()) == [2, 2]
    fm.release(pl, c)
    assert len(c.idle_instances()) == 14


def test_fm_never_needs_reconfig():
    c = _fm_cluster()
    fm = FlexMIG()
    placements = []
    for i, size in enumerate([6, 4, 2, 1]):
        res = fm.try_place(_job(size, jid=f"j{i}"), c)
        assert isinstance(res, Placement) or res is None
        if isinstance(res, Placement):
            placements.append(res)
    # 6+4+2+1 = 13 <= 14 leaves: everything placed without reconfig
    assert len(placements) == 4


def test_sm_upgrade_rule():
    c = Cluster(n_hosts=1, gpus_per_host=1)
    sm = StaticMIG()
    sm.setup(c)
    p1 = sm.try_place(_job(1, jid="a"), c)
    assert p1.instances[0].profile == "1g.10gb"     # exact fit first
    p2 = sm.try_place(_job(1, jid="b"), c)
    assert p2.instances[0].profile == "2g.10gb"     # upgrade to larger idle
    assert sm.try_place(_job(6, jid="c"), c) is None  # unsupported size


def test_dm_creates_then_drains():
    c = Cluster(n_hosts=1, gpus_per_host=1)
    dm = DynamicMIG()
    dm.setup(c)
    dm.register_inference([])
    r1 = dm.try_place(_job(4, jid="a"), c)
    assert isinstance(r1, ReconfigPlan)             # geometry change = drain
    assert r1.affected_jobs == ()                   # idle GPU: cheap drain
    pl1 = dm.apply_reconfig(r1, c)
    assert pl1.instances[0].profile == "4g.20gb"
    # a size-2 job now needs another repartition while 'a' runs
    r2 = dm.try_place(_job(2, jid="b"), c)
    assert isinstance(r2, ReconfigPlan)
    assert r2.affected_jobs == ("a",)
    assert r2.duration == pytest.approx(
        RECONFIGURE_S + CKPT_SAVE_S + CKPT_LOAD_S + POD_CHURN_S)


def test_dm_inference_never_drained():
    c = Cluster(n_hosts=1, gpus_per_host=1)
    dm = DynamicMIG()
    dm.setup(c)
    dm.register_inference(["inf"])
    r1 = dm.apply_reconfig(
        dm.try_place(_job(4, kind="inference", jid="inf"), c), c)
    # the only GPU hosts an inference job -> no drain allowed
    assert dm.try_place(_job(2, jid="b"), c) is None


def test_reconfig_cost_structure():
    plan = ReconfigPlan(0, 0, _job(2), ("a", "b", "c"))
    assert plan.duration == pytest.approx(
        RECONFIGURE_S + 3 * (CKPT_SAVE_S + CKPT_LOAD_S + POD_CHURN_S))
    assert 100.0 <= RECONFIGURE_S <= 120.0          # §2.3.3 measurement
