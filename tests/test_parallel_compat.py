"""The SPMD runtime layer: shard_map resolution, meshes, collectives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import parallel as PX
from tests.conftest import run_multidevice


def test_shard_map_resolves_on_this_jax():
    assert PX.SHARD_MAP_IMPL in (
        "jax.shard_map", "jax.experimental.shard_map.shard_map"), (
        f"no usable shard_map on jax {jax.__version__}: "
        f"{PX.SHARD_MAP_IMPL}")


def test_shard_map_single_device_identity():
    mesh = PX.make_device_mesh((1,), ("d",), devices=jax.devices()[:1])
    from jax.sharding import PartitionSpec as P
    out = PX.shard_map(lambda x: x * 2, mesh=mesh,
                       in_specs=P(), out_specs=P(),
                       check_vma=False)(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(out), [0.0, 2.0, 4.0, 6.0])


def test_axis_helpers():
    assert PX.axis_tuple(None) == ()
    assert PX.axis_tuple("data") == ("data",)
    assert PX.axis_tuple(("pod", "data")) == ("pod", "data")
    mesh = PX.make_device_mesh((1,), ("d",), devices=jax.devices()[:1])
    assert PX.axes_size(mesh, "d") == 1
    assert PX.axes_size(mesh, None) == 1
    assert PX.axes_size(None, "d") == 1


def test_transport_tiers_consistent():
    # the analytic model and the runtime layer must price the same numbers
    from repro.collectives import transport as analytic
    assert analytic.SHM_STREAM_GBPS == PX.TIERS["SHM"].gbps
    assert analytic.NET_GBPS == PX.TIERS["NET"].gbps
    assert analytic.DCN_GBPS_PER_HOST == PX.TIERS["DCN"].gbps
    fast, slow = PX.fast_slow_axes(("pod", "data", "model"))
    assert fast == ("data", "model") and slow == "pod"
    assert PX.is_slow_axis("pod") and not PX.is_slow_axis("data")


def test_mesh_construction_multidevice():
    """1-, 2- and 4-device meshes on fake CPU devices."""
    out = run_multidevice("""
        import jax
        from repro import parallel as PX
        devs = jax.devices()
        for shape, names, n in (((1,), ("data",), 1),
                                ((2,), ("data",), 2),
                                ((2, 2), ("data", "model"), 4)):
            mesh = PX.make_device_mesh(shape, names, devices=devs[:n])
            assert tuple(mesh.axis_names) == names
            assert PX.axes_size(mesh, names) == n
        full = PX.make_device_mesh((2, 2), ("data", "model"))
        assert PX.axes_size(full, ("data", "model")) == 4
        print("MESH_OK")
        """, n_devices=4)
    assert "MESH_OK" in out


def test_psum_roundtrip_through_wrappers_multidevice():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import parallel as PX
        mesh = PX.make_device_mesh((4,), ("d",))

        def body(x):
            n = PX.axis_size("d")
            assert isinstance(n, int) and n == 4
            i = PX.axis_index("d")
            s = PX.psum(x, "d")
            m = PX.pmean(x, "d")
            hi = PX.pmax(x, "d")
            g = PX.all_gather(x, "d", gather_axis=0, tiled=False)
            shifted = PX.ppermute(x, "d", [(j, (j + 1) % 4)
                                           for j in range(4)])
            return s, m, hi, g.reshape(-1), shifted, i.astype(jnp.int32)[None]

        x = jnp.arange(4.0)
        s, m, hi, g, shifted, i = jax.jit(PX.shard_map(
            body, mesh=mesh, in_specs=P("d"),
            out_specs=(P("d"), P("d"), P("d"), P("d"), P("d"), P("d")),
            check_vma=False))(x)
        np.testing.assert_array_equal(np.asarray(s), [6.0] * 4)
        np.testing.assert_array_equal(np.asarray(m), [1.5] * 4)
        np.testing.assert_array_equal(np.asarray(hi), [3.0] * 4)
        # every shard gathered the full vector: 4 shards x 4 values
        np.testing.assert_array_equal(
            np.asarray(g), np.tile(np.arange(4.0), 4))
        np.testing.assert_array_equal(np.asarray(shifted),
                                      [3.0, 0.0, 1.0, 2.0])
        np.testing.assert_array_equal(np.asarray(i), [0, 1, 2, 3])
        print("PSUM_OK")
        """, n_devices=4)
    assert "PSUM_OK" in out


def test_psum_scatter_wrapper_multidevice():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import parallel as PX
        mesh = PX.make_device_mesh((4,), ("d",))

        def body(x):   # x: (4, k) per shard -> each shard keeps its row sum
            return PX.psum_scatter(x, "d", scatter_dimension=0, tiled=False)

        x = jnp.arange(32.0).reshape(4, 8)   # sharded: each shard (1, 8)
        y = jax.jit(PX.shard_map(
            body, mesh=mesh, in_specs=P("d"), out_specs=P("d"),
            check_vma=False))(jnp.tile(x, (4, 1)).reshape(16, 8))
        print("SCATTER_OK", np.asarray(y).shape)
        """, n_devices=4)
    assert "SCATTER_OK" in out
