"""Optimizer, grad accumulation, trainer loop, checkpoint/restart."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import optim
from repro.data import DataConfig, Prefetcher, SyntheticCorpus
from repro.models.registry import build_model, get_config, reduced_config
from repro.train import (Trainer, TrainerConfig, make_jitted_train_step,
                         make_loss_and_grad)


@pytest.fixture()
def small_model():
    cfg = reduced_config(get_config("llama3.2-1b"))
    return cfg, build_model(cfg, remat=False)


def _batch(cfg, B=4, S=32, seed=0):
    rng = jax.random.key(seed)
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(rng, (B, S), 0,
                                          cfg.vocab_size)}


def test_adamw_decreases_loss(small_model):
    cfg, model = small_model
    ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=30)
    params = model.init(jax.random.key(0))
    state = optim.init(ocfg, params)
    step = make_jitted_train_step(model, ocfg, accum=1, rules=None)
    losses = []
    for i in range(10):
        params, state, m = step(params, state, _batch(cfg, seed=i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert int(state.step) == 10


def test_grad_accumulation_invariance(small_model):
    """accum=1 vs accum=4 produce the same accumulated gradients."""
    cfg, model = small_model
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, B=8)
    l1, g1 = jax.jit(make_loss_and_grad(model, accum=1))(params, batch)
    l4, g4 = jax.jit(make_loss_and_grad(model, accum=4))(params, batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=2e-5)
    # bf16 forward + different reduction orders: tolerance reflects the
    # grads' own magnitude (~1e-3).  atol also covers the thread-pool
    # retiling under --xla_force_host_platform_device_count=8 (the CI
    # device matrix), which shifts f32 summation order by up to ~6e-4
    # on 0.1% of elements; the bf16-rounding bug this test guards
    # against produces errors well over 1e-2
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=1e-3)


def test_grad_clipping():
    ocfg = optim.AdamWConfig(clip_norm=1e-6)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    grads = {"w": jnp.full((4,), 100.0)}
    state = optim.init(ocfg, params)
    p2, state, m = optim.apply(ocfg, params, grads, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    # clipped to tiny norm: params barely move beyond lr*wd
    assert float(jnp.max(jnp.abs(
        p2["w"].astype(jnp.float32) - 1.0))) < 0.01


def test_lr_schedule_shape():
    ocfg = optim.AdamWConfig(peak_lr=1.0, warmup_steps=10,
                             total_steps=100, min_lr_frac=0.1)
    lrs = [float(optim.lr_schedule(ocfg, jnp.int32(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


def test_trainer_checkpoint_restart(tmp_path, small_model):
    cfg, model = small_model
    ocfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=4)
    ckdir = str(tmp_path / "ck")
    tcfg = TrainerConfig(n_steps=6, ckpt_every=3, ckpt_dir=ckdir,
                         log_every=1, async_ckpt=False)
    t1 = Trainer(model, ocfg, tcfg, dcfg)
    out1 = t1.run(resume=False)
    assert ckpt.latest_step(ckdir) == 6

    # simulated failure + restart: resumes from step 6, not 0
    tcfg2 = TrainerConfig(n_steps=8, ckpt_every=3, ckpt_dir=ckdir,
                          log_every=1, async_ckpt=False)
    t2 = Trainer(model, ocfg, tcfg2, dcfg)
    out2 = t2.run(resume=True)
    assert out2["history"][0]["step"] == 6


def test_failure_injection_then_recovery(tmp_path, small_model):
    cfg, model = small_model
    ocfg = optim.AdamWConfig()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=4)
    ckdir = str(tmp_path / "ck")
    tcfg = TrainerConfig(n_steps=6, ckpt_every=2, ckpt_dir=ckdir,
                         log_every=1, async_ckpt=False)

    t = Trainer(model, ocfg, tcfg, dcfg,
                failure_hook=lambda s: s == 4)
    with pytest.raises(RuntimeError, match="injected failure"):
        t.run(resume=False)
    assert ckpt.latest_step(ckdir) == 4          # progress survived
    t2 = Trainer(model, ocfg, tcfg, dcfg)
    out = t2.run(resume=True)
    assert out["history"][0]["step"] == 4


def test_data_determinism_and_sharding():
    dcfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    c0 = SyntheticCorpus(dcfg, shard=0, n_shards=2)
    c1 = SyntheticCorpus(dcfg, shard=1, n_shards=2)
    b0a, b0b = c0.batch(3), c0.batch(3)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    assert not np.array_equal(c0.batch(3)["tokens"],
                              c1.batch(3)["tokens"])
    assert b0a["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b0a["tokens"][:, 1:],
                                  b0a["targets"][:, :-1])


def test_prefetcher():
    dcfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    pf = Prefetcher(SyntheticCorpus(dcfg), depth=2)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    assert (s0, s1) == (0, 1)
    pf.close()


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"a": jnp.arange(8, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16)}}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    step, restored = ckpt.restore(d, tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(8, dtype=np.float32))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    # corrupt a leaf on disk
    import glob
    fn = sorted(glob.glob(os.path.join(d, "a*.npy")))[0]
    arr = np.load(fn)
    arr[0] = 999.0
    np.save(fn, arr)
    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.restore(d, tree)
