"""Sharding rules, HLO analysis parser, serve batcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo as H
from repro.models.registry import ARCH_IDS, build_model, get_config, \
    reduced_config
from repro.serve import BatchedServer, Request
from repro.sharding import MeshRules, single_device_rules, use_rules
from tests.conftest import run_multidevice


def test_type_bytes():
    assert H.type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert H.type_bytes("bf16[2,3]") == 12
    assert H.type_bytes("(s32[], f32[8])") == 4 + 32
    assert H.type_bytes("pred[]") == 1


def test_hlo_analysis_counts_while_trip():
    """dot inside a scanned body must be multiplied by the trip count."""
    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32))
    stats = H.analyze(lowered.compile().as_text())
    want = 7 * 2 * 8 * 64 * 64
    assert stats.dot_flops == pytest.approx(want, rel=0.01)


def test_hlo_analysis_collectives_multidevice():
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis import hlo as H
        mesh = jax.make_mesh((8,), ("d",))
        def f(x):
            return jax.lax.with_sharding_constraint(
                x.sum(axis=0, keepdims=True), NamedSharding(mesh, P()))
        j = jax.jit(f, in_shardings=NamedSharding(mesh, P("d")),
                    out_shardings=NamedSharding(mesh, P()))
        txt = j.lower(jax.ShapeDtypeStruct((8, 1024), jnp.float32)
                      ).compile().as_text()
        stats = H.analyze(txt)
        assert stats.collective_bytes > 0, txt[:2000]
        assert any("all-reduce" in k or "all-gather" in k
                   for k in stats.collective_ops), stats.collective_ops
        print("HLO_COLL_OK")
        """)
    assert "HLO_COLL_OK" in out


def test_rules_divisibility_dropping():
    """Non-dividing dims silently stay replicated (whisper's 6 heads on a
    16-way axis)."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.sharding import make_rules, use_rules, shard
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        with mesh:
            with use_rules(rules):
                def f(x):
                    return shard(x, "batch", None, "heads", None)
                x = jnp.ones((4, 8, 6, 16))    # 6 heads !% 4
                y = jax.jit(f)(x)
                assert y.shape == x.shape
                x2 = jnp.ones((4, 8, 8, 16))   # 8 heads % 4 == 0
                y2 = jax.jit(f)(x2)
        print("RULES_OK")
        """, n_devices=8)
    assert "RULES_OK" in out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_tree_shardings_cover_params(arch):
    """tree_shardings produces a NamedSharding for every param leaf on the
    production mesh shape (checked abstractly via rules=None here; the
    full-mesh check runs inside the dry-run)."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    axes = model.param_logical_axes()
    n_p = len(jax.tree.leaves(params))
    n_a = len(jax.tree.leaves(
        axes, is_leaf=lambda v: isinstance(v, tuple)))
    assert n_p == n_a


def test_single_device_rules_noop():
    with use_rules(single_device_rules()):
        x = jnp.ones((4, 4))
        from repro.sharding import shard
        y = shard(x, "batch", "heads")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_batched_server_continuous_batching():
    cfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    srv = BatchedServer(model, params, max_batch=2, max_seq=32)
    for i in range(3):                        # 3 requests, 2 slots
        srv.submit(Request(i, np.array([5 + i, 6, 7], np.int32),
                           max_new=4))
    srv.run_until_drained()
    assert len(srv.completed) == 3
    assert all(len(r.out) == 4 for r in srv.completed)
