"""Sharding rules, HLO analysis parser, serve batcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo as H
from repro.models.registry import ARCH_IDS, build_model, get_config, \
    reduced_config
from repro.serve import BatchedServer, Request
from repro.sharding import MeshRules, single_device_rules, use_rules
from tests.conftest import run_multidevice


def test_type_bytes():
    assert H.type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert H.type_bytes("bf16[2,3]") == 12
    assert H.type_bytes("(s32[], f32[8])") == 4 + 32
    assert H.type_bytes("pred[]") == 1


def test_hlo_analysis_counts_while_trip():
    """dot inside a scanned body must be multiplied by the trip count."""
    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32))
    stats = H.analyze(lowered.compile().as_text())
    want = 7 * 2 * 8 * 64 * 64
    assert stats.dot_flops == pytest.approx(want, rel=0.01)


def test_hlo_analysis_collectives_multidevice():
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis import hlo as H
        mesh = jax.make_mesh((8,), ("d",))
        def f(x):
            return jax.lax.with_sharding_constraint(
                x.sum(axis=0, keepdims=True), NamedSharding(mesh, P()))
        j = jax.jit(f, in_shardings=NamedSharding(mesh, P("d")),
                    out_shardings=NamedSharding(mesh, P()))
        txt = j.lower(jax.ShapeDtypeStruct((8, 1024), jnp.float32)
                      ).compile().as_text()
        stats = H.analyze(txt)
        assert stats.collective_bytes > 0, txt[:2000]
        assert any("all-reduce" in k or "all-gather" in k
                   for k in stats.collective_ops), stats.collective_ops
        print("HLO_COLL_OK")
        """)
    assert "HLO_COLL_OK" in out


_SYNTH_HLO_HEADER = """
HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""


def test_slow_chain_independent_collectives():
    """Two cross-pod all-reduces on disjoint data: depth 1, pipelinable."""
    txt = _SYNTH_HLO_HEADER + """
ENTRY %main (p0: f32[8], p1: f32[8]) -> (f32[8], f32[8]) {
  %p0 = f32[8] parameter(0)
  %p1 = f32[8] parameter(1)
  %ar0 = f32[8] all-reduce(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  %ar1 = f32[8] all-reduce(%p1), replica_groups={{0,2},{1,3}}, to_apply=%add
  ROOT %t = (f32[8], f32[8]) tuple(%ar0, %ar1)
}
"""
    ch = H.slow_collective_chains(txt, chips_per_pod=2)
    assert ch.n_slow == 2
    assert ch.max_depth == 1 and ch.independent
    assert ch.dependent_pairs == []


def test_slow_chain_detects_data_dependence():
    """A slow collective fed (transitively) by another slow collective's
    result is a depth-2 chain — not pipelinable."""
    txt = _SYNTH_HLO_HEADER + """
ENTRY %main (p0: f32[8], p1: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %p1 = f32[8] parameter(1)
  %ar0 = f32[8] all-reduce(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  %mix = f32[8] add(%ar0, %p1)
  ROOT %ar1 = f32[8] all-reduce(%mix), replica_groups={{0,2},{1,3}}, to_apply=%add
}
"""
    ch = H.slow_collective_chains(txt, chips_per_pod=2)
    assert ch.n_slow == 2
    assert ch.max_depth == 2 and not ch.independent
    assert any(a.endswith("ar0") and b.endswith("ar1")
               for a, b in ch.dependent_pairs), ch.dependent_pairs


def test_slow_chain_ignores_fast_collectives_and_done_halves():
    """Intra-pod collectives are not slow nodes, and the -done half of an
    async pair passes its cone through without counting twice — a slow
    hop chained only through *fast* collectives stays depth 1."""
    txt = _SYNTH_HLO_HEADER + """
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %rs = f32[4] reduce-scatter(%p0), replica_groups={{0,1},{2,3}}, dimensions={0}, to_apply=%add
  %ars = f32[4] all-reduce-start(%rs), replica_groups={{0,2},{1,3}}, to_apply=%add
  %ard = f32[4] all-reduce-done(%ars)
  ROOT %ag = f32[8] all-gather(%ard), replica_groups={{0,1},{2,3}}, dimensions={0}
}
"""
    ch = H.slow_collective_chains(txt, chips_per_pod=2)
    assert ch.n_slow == 1
    assert ch.max_depth == 1 and ch.independent


def test_slow_chain_follows_called_computations():
    """Slow collectives inside a called computation chain with ones that
    consume the call's result."""
    txt = _SYNTH_HLO_HEADER + """
%inner (q0: f32[8]) -> f32[8] {
  %q0 = f32[8] parameter(0)
  ROOT %arin = f32[8] all-reduce(%q0), replica_groups={{0,2},{1,3}}, to_apply=%add
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %c = f32[8] call(%p0), to_apply=%inner
  ROOT %ar1 = f32[8] all-reduce(%c), replica_groups={{0,2},{1,3}}, to_apply=%add
}
"""
    ch = H.slow_collective_chains(txt, chips_per_pod=2)
    assert ch.n_slow == 2
    assert ch.max_depth == 2 and not ch.independent


def test_slow_chain_dependence_entering_called_computation():
    """A slow collective feeding a call whose body holds another slow
    collective is a depth-2 chain: the `parameter(i)` op inside the
    callee must inherit the call operand's cone, not reset it."""
    txt = _SYNTH_HLO_HEADER + """
%inner (q0: f32[8]) -> f32[8] {
  %q0 = f32[8] parameter(0)
  ROOT %arin = f32[8] all-reduce(%q0), replica_groups={{0,2},{1,3}}, to_apply=%add
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %ar0 = f32[8] all-reduce(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  ROOT %c = f32[8] call(%ar0), to_apply=%inner
}
"""
    ch = H.slow_collective_chains(txt, chips_per_pod=2)
    assert ch.n_slow == 2
    assert ch.max_depth == 2 and not ch.independent
    assert any(a.endswith("ar0") and b.endswith("arin")
               for a, b in ch.dependent_pairs), ch.dependent_pairs


def test_slow_chain_respects_root_marker_not_print_order():
    """The callee's result cone comes from its ROOT op even when the
    printed op order puts another (slow-free) op last."""
    txt = _SYNTH_HLO_HEADER + """
%inner (q0: f32[8]) -> f32[8] {
  %q0 = f32[8] parameter(0)
  ROOT %arin = f32[8] all-reduce(%q0), replica_groups={{0,2},{1,3}}, to_apply=%add
  %dead = f32[8] negate(%q0)
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %c = f32[8] call(%p0), to_apply=%inner
  ROOT %ar1 = f32[8] all-reduce(%c), replica_groups={{0,2},{1,3}}, to_apply=%add
}
"""
    ch = H.slow_collective_chains(txt, chips_per_pod=2)
    assert ch.n_slow == 2
    assert ch.max_depth == 2 and not ch.independent


def test_slow_chain_while_body_counted_once():
    """A slow collective inside a while body registers once — the
    cone-propagation second pass must not double n_slow."""
    txt = _SYNTH_HLO_HEADER + """
%cond (cv: f32[8]) -> pred[] {
  %cv = f32[8] parameter(0)
  ROOT %lt = pred[] constant(0)
}

%body (bv: f32[8]) -> f32[8] {
  %bv = f32[8] parameter(0)
  ROOT %arb = f32[8] all-reduce(%bv), replica_groups={{0,2},{1,3}}, to_apply=%add
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  ROOT %w = f32[8] while(%p0), condition=%cond, body=%body
}
"""
    ch = H.slow_collective_chains(txt, chips_per_pod=2)
    assert ch.n_slow == 1, ch


def test_rules_divisibility_dropping():
    """Non-dividing dims silently stay replicated (whisper's 6 heads on a
    16-way axis)."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.sharding import make_rules, use_rules, shard
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        with mesh:
            with use_rules(rules):
                def f(x):
                    return shard(x, "batch", None, "heads", None)
                x = jnp.ones((4, 8, 6, 16))    # 6 heads !% 4
                y = jax.jit(f)(x)
                assert y.shape == x.shape
                x2 = jnp.ones((4, 8, 8, 16))   # 8 heads % 4 == 0
                y2 = jax.jit(f)(x2)
        print("RULES_OK")
        """, n_devices=8)
    assert "RULES_OK" in out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_tree_shardings_cover_params(arch):
    """tree_shardings produces a NamedSharding for every param leaf on the
    production mesh shape (checked abstractly via rules=None here; the
    full-mesh check runs inside the dry-run)."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    axes = model.param_logical_axes()
    n_p = len(jax.tree.leaves(params))
    n_a = len(jax.tree.leaves(
        axes, is_leaf=lambda v: isinstance(v, tuple)))
    assert n_p == n_a


def test_single_device_rules_noop():
    with use_rules(single_device_rules()):
        x = jnp.ones((4, 4))
        from repro.sharding import shard
        y = shard(x, "batch", "heads")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_batched_server_continuous_batching():
    cfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    srv = BatchedServer(model, params, max_batch=2, max_seq=32)
    for i in range(3):                        # 3 requests, 2 slots
        srv.submit(Request(i, np.array([5 + i, 6, 7], np.int32),
                           max_new=4))
    srv.run_until_drained()
    assert len(srv.completed) == 3
    assert all(len(r.out) == 4 for r in srv.completed)
